"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; fixed cases pin the exact
configurations the AOT artifacts use.
"""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import consmax as k
from compile.kernels import lut as lutk
from compile.kernels import ref

def rnd(shape, seed=0, lo=-4.0, hi=4.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.uniform(lo, hi, shape).astype(np.float32))


shapes = st.sampled_from(
    [(1, 8), (3, 17), (2, 2, 64), (4, 6, 16, 16), (128, 256), (5, 300)]
)


class TestConsmaxKernel:
    @given(shape=shapes, seed=st.integers(0, 10_000))
    def test_matches_ref(self, shape, seed):
        s = rnd(shape, seed)
        beta, gamma = 1.5, 100.0
        c = ref.merge_beta_gamma(jnp.float32(beta), jnp.float32(gamma))
        got = k.consmax_pallas(s, c)
        want = ref.consmax_ref(s, beta, gamma)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)

    @given(seed=st.integers(0, 10_000),
           beta=st.floats(0.25, 4.0), gamma=st.floats(1.0, 500.0))
    def test_beta_gamma_sweep(self, seed, beta, gamma):
        s = rnd((4, 32), seed)
        c = ref.merge_beta_gamma(jnp.float32(beta), jnp.float32(gamma))
        np.testing.assert_allclose(
            k.consmax_pallas(s, c), ref.consmax_ref(s, beta, gamma),
            rtol=1e-5, atol=1e-7)

    def test_per_head_constants(self):
        """Per-head C broadcasting - the layout attention actually uses."""
        s = rnd((2, 6, 16, 16), 7)
        beta = jnp.linspace(0.5, 2.5, 6)[None, :, None, None]
        gamma = jnp.full((1, 6, 1, 1), 100.0)
        c = ref.merge_beta_gamma(beta, gamma)
        got = k.consmax_pallas(s, jnp.broadcast_to(c, s.shape))
        np.testing.assert_allclose(
            got, ref.consmax_ref(s, beta, gamma), rtol=1e-5, atol=1e-7)

    def test_training_vs_inference_form(self):
        """Eq. 2 (train) == Eq. 3 (merged-C inference) algebraically."""
        s = rnd((8, 64), 3)
        beta, gamma = jnp.float32(1.7), jnp.float32(88.0)
        train = ref.consmax_ref(s, beta, gamma)
        infer = ref.consmax_inference_ref(s, ref.merge_beta_gamma(beta, gamma))
        np.testing.assert_allclose(train, infer, rtol=1e-6)

    def test_masked_scores_give_zero_probability(self):
        """-inf masking must yield exactly 0 (causal mask correctness)."""
        s = jnp.array([[0.5, -jnp.inf, 1.0, -jnp.inf]], jnp.float32)
        out = k.consmax_pallas(s, jnp.float32(0.01))
        assert out[0, 1] == 0.0 and out[0, 3] == 0.0
        assert out[0, 0] > 0.0 and out[0, 2] > 0.0

    def test_no_reduction_property(self):
        """THE ConSmax property: each element depends only on itself -
        perturbing one score never changes any other output."""
        s = rnd((2, 32), 11)
        c = jnp.float32(0.02)
        base = np.asarray(k.consmax_pallas(s, c))
        s2 = s.at[0, 5].set(99.0)
        pert = np.asarray(k.consmax_pallas(s2, c))
        mask = np.ones_like(base, bool)
        mask[0, 5] = False
        np.testing.assert_array_equal(base[mask], pert[mask])

    def test_softmax_lacks_that_property(self):
        """Sanity check of the test above: softmax outputs DO couple."""
        s = rnd((2, 32), 11)
        base = np.asarray(k.softmax_pallas(s))
        pert = np.asarray(k.softmax_pallas(s.at[0, 5].set(99.0)))
        assert not np.allclose(base[0, :5], pert[0, :5])

    @pytest.mark.parametrize("rb,sb", [(8, 8), (32, 16), (128, 128)])
    def test_block_shape_invariance(self, rb, sb):
        """Output must not depend on the tiling choice."""
        s = rnd((100, 200), 5)
        c = jnp.float32(0.015)
        a = k.consmax_pallas(s, c, row_block=rb, seq_block=sb)
        b = ref.consmax_inference_ref(s, c)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)


class TestSoftmaxBaselines:
    @given(shape=shapes, seed=st.integers(0, 10_000))
    def test_softmax_matches_ref(self, shape, seed):
        s = rnd(shape, seed)
        np.testing.assert_allclose(
            k.softmax_pallas(s), ref.softmax_ref(s), rtol=1e-5, atol=1e-7)

    @given(shape=shapes, seed=st.integers(0, 10_000))
    def test_softermax_matches_ref(self, shape, seed):
        s = rnd(shape, seed)
        np.testing.assert_allclose(
            k.softermax_pallas(s), ref.softermax_ref(s), rtol=1e-5, atol=1e-7)

    @given(seed=st.integers(0, 10_000), n_chunks=st.sampled_from([1, 2, 4, 8]))
    def test_partial_softmax_is_exact(self, seed, n_chunks):
        """Fig 3(b): partial softmax + sync == monolithic softmax."""
        s = rnd((3, 64), seed)
        np.testing.assert_allclose(
            ref.partial_softmax_ref(s, n_chunks), ref.softmax_ref(s),
            rtol=1e-5, atol=1e-7)

    def test_softmax_rows_sum_to_one(self):
        s = rnd((16, 33), 2, -10, 10)
        out = np.asarray(k.softmax_pallas(s))
        np.testing.assert_allclose(out.sum(-1), 1.0, rtol=1e-5)

    def test_consmax_rows_need_not_sum_to_one(self):
        """The paper's relaxation: the probability vector is NOT unit."""
        s = rnd((4, 64), 9)
        out = np.asarray(k.consmax_pallas(s, jnp.float32(0.01)))
        assert not np.allclose(out.sum(-1), 1.0)

    def test_softmax_invariant_to_shift(self):
        s = rnd((4, 32), 1)
        np.testing.assert_allclose(
            ref.softmax_ref(s), ref.softmax_ref(s + 123.0), rtol=1e-4)

    def test_softmax_extreme_values_stable(self):
        s = jnp.array([[1e4, -1e4, 0.0, 5e3]], jnp.float32)
        out = np.asarray(k.softmax_pallas(s))
        assert np.isfinite(out).all()


class TestFusedConsmaxPV:
    @given(seed=st.integers(0, 1000),
           tq=st.sampled_from([16, 50, 128]),
           tk=st.sampled_from([32, 96]),
           d=st.sampled_from([8, 64]))
    def test_matches_two_step(self, seed, tq, tk, d):
        r = np.random.default_rng(seed)
        s = jnp.asarray(r.normal(size=(tq, tk)).astype(np.float32))
        v = jnp.asarray(r.normal(size=(tk, d)).astype(np.float32))
        c = jnp.float32(0.02)
        got = k.consmax_pv_pallas(s, c, v, row_block=16, seq_block=16)
        want = ref.consmax_inference_ref(s, c) @ v
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-4)

    def test_causal_masked_input(self):
        """-inf masked scores contribute exactly zero to the PV output."""
        t, d = 32, 16
        r = np.random.default_rng(0)
        s = jnp.asarray(r.normal(size=(t, t)).astype(np.float32))
        mask = jnp.tril(jnp.ones((t, t), bool))
        sm = jnp.where(mask, s, -jnp.inf)
        v = jnp.asarray(r.normal(size=(t, d)).astype(np.float32))
        c = jnp.float32(0.02)
        got = k.consmax_pv_pallas(sm, c, v, row_block=16, seq_block=16)
        p = np.asarray(ref.consmax_inference_ref(sm, c))
        assert np.isfinite(got).all()
        np.testing.assert_allclose(got, p @ np.asarray(v),
                                   rtol=5e-4, atol=1e-4)


class TestDtypes:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_consmax_dtypes(self, dtype):
        s = rnd((8, 32), 0).astype(dtype)
        c = jnp.asarray(0.02, dtype)
        got = k.consmax_pallas(s, c)
        assert got.dtype == dtype
        want = ref.consmax_inference_ref(
            s.astype(jnp.float32), jnp.float32(0.02))
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(got.astype(jnp.float32), want,
                                   rtol=tol, atol=tol)


class TestGradients:
    def test_consmax_ref_grad(self):
        """beta and gamma must receive gradients (they are learnable)."""
        s = rnd((4, 16), 0)

        def f(beta, gamma):
            return jnp.sum(ref.consmax_ref(s, beta, gamma) ** 2)

        gb, gg = jax.grad(f, argnums=(0, 1))(jnp.float32(1.5),
                                             jnp.float32(100.0))
        assert np.isfinite(gb) and np.isfinite(gg)
        assert gb != 0.0 and gg != 0.0

    def test_consmax_grad_matches_finite_difference(self):
        s = rnd((2, 8), 1)

        def f(beta):
            return jnp.sum(ref.consmax_ref(s, beta, jnp.float32(50.0)))

        b0 = jnp.float32(1.2)
        g = jax.grad(f)(b0)
        eps = 1e-3
        fd = (f(b0 + eps) - f(b0 - eps)) / (2 * eps)
        np.testing.assert_allclose(g, fd, rtol=1e-2)
