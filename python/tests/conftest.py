import os
import sys

# Make `compile` importable when pytest runs from python/ or repo root.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import hypothesis

# JAX JIT-compiles on first call, so wall-clock deadlines misfire.
hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("ci")
