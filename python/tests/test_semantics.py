"""Semantic properties the paper argues for in §III — the *reasons*
ConSmax can replace Softmax — tested quantitatively.
"""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given

from compile.kernels import ref


def rnd(shape, seed=0, lo=-4.0, hi=4.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.uniform(lo, hi, shape).astype(np.float32))


class TestOrderPreservation:
    """ConSmax must keep the relevance ranking softmax induces (it is a
    monotone map of the scores)."""

    @given(seed=st.integers(0, 10_000))
    def test_ranking_identical_to_softmax(self, seed):
        s = rnd((4, 32), seed)
        sm = np.argsort(np.asarray(ref.softmax_ref(s)), axis=-1)
        cm = np.argsort(np.asarray(ref.consmax_ref(s, 1.5, 100.0)), axis=-1)
        np.testing.assert_array_equal(sm, cm)

    @given(beta=st.floats(0.1, 4.0), gamma=st.floats(1.0, 1000.0))
    def test_ranking_invariant_to_beta_gamma(self, beta, gamma):
        s = rnd((2, 16), 3)
        base = np.argsort(np.asarray(ref.consmax_ref(s, 1.0, 100.0)), axis=-1)
        other = np.argsort(np.asarray(ref.consmax_ref(s, beta, gamma)), axis=-1)
        np.testing.assert_array_equal(base, other)


class TestDiscrimination:
    """§III-A: 'as long as the probability distribution can magnify the
    small differences in input scores, the LLM performance remains
    robust' — exp amplifies differences multiplicatively."""

    def test_score_gap_becomes_probability_ratio(self):
        # a score gap of d becomes a probability RATIO of e^d, regardless
        # of beta/gamma - same separation softmax provides
        d = 1.0
        s = jnp.array([[0.0, d]], jnp.float32)
        p = np.asarray(ref.consmax_ref(s, 1.5, 100.0))[0]
        assert abs(p[1] / p[0] - np.exp(d)) < 1e-5

    def test_uniform_scores_give_uniform_probs(self):
        s = jnp.full((1, 8), 0.7, jnp.float32)
        p = np.asarray(ref.consmax_ref(s, 1.0, 50.0))[0]
        assert np.allclose(p, p[0])


class TestGammaScale:
    """§III-A overflow/degeneracy argument: gamma -> 0 or inf destroys
    the distribution's usefulness; the PxV output scales by 1/gamma."""

    def test_pv_output_scales_inversely_with_gamma(self):
        r = np.random.default_rng(0)
        s = rnd((1, 8), 1)
        v = jnp.asarray(r.normal(size=(8, 4)).astype(np.float32))
        out1 = np.asarray(ref.consmax_ref(s, 1.0, 10.0) @ v)
        out2 = np.asarray(ref.consmax_ref(s, 1.0, 1000.0) @ v)
        np.testing.assert_allclose(out1, out2 * 100.0, rtol=1e-4)

    def test_extreme_gamma_underflows_probabilities(self):
        s = rnd((1, 8), 2)
        p = np.asarray(ref.consmax_ref(s, 1.0, 1e30))
        assert p.max() < 1e-25  # relevance signal destroyed


class TestNonUnitNormalization:
    """The paper's relaxation: the probability vector need not sum to 1,
    but must stay FINITE and positive for in-range scores."""

    @given(seed=st.integers(0, 1000))
    def test_row_sums_bounded_not_unit(self, seed):
        s = rnd((4, 64), seed)
        p = np.asarray(ref.consmax_ref(s, 1.5, 100.0))
        sums = p.sum(-1)
        assert np.isfinite(sums).all()
        assert (p > 0).all()
        assert not np.allclose(sums, 1.0)

    def test_int8_range_never_overflows_exp(self):
        """The hardware operating point (scores in [-8, 8)): exp stays
        inside fp16 range after the C-multiply for sane beta/gamma."""
        s = jnp.linspace(-8.0, 7.9375, 256)[None]
        p = np.asarray(ref.consmax_ref(s, 0.5, 10.0))
        assert np.isfinite(p).all()
        assert p.max() < 65504  # fp16 max


class TestInferenceMergeAcrossGrid:
    """Eq. 2 == Eq. 3 for every (beta, gamma) the sweep explores."""

    @given(
        beta=st.sampled_from([0.5, 1.0, 1.5, 2.0, 2.5]),
        gamma=st.sampled_from([10.0, 100.0, 300.0]),
        seed=st.integers(0, 1000),
    )
    def test_merge_equivalence(self, beta, gamma, seed):
        s = rnd((2, 16), seed)
        train = ref.consmax_ref(s, beta, gamma)
        c = ref.merge_beta_gamma(jnp.float32(beta), jnp.float32(gamma))
        infer = ref.consmax_inference_ref(s, c)
        np.testing.assert_allclose(train, infer, rtol=1e-5)


class TestTrainingDynamicsClaims:
    """Fig 6/7 mechanism checks at tiny scale (fast)."""

    def test_consmax_grad_flows_through_scores(self):
        """The attention scores receive gradient through ConSmax (no
        stop-gradient pathology from removing normalization)."""
        s = rnd((2, 8), 0)

        def f(s):
            return jnp.sum(ref.consmax_ref(s, 1.0, 100.0) ** 2)

        g = np.asarray(jax.grad(f)(s))
        assert np.isfinite(g).all() and (np.abs(g) > 0).any()

    def test_beta_gradient_sign_is_meaningful(self):
        """dL/dbeta < 0 when larger probabilities reduce loss: beta
        scales all probs by e^-beta, so its gradient is the negated
        sum of prob-weighted output grads."""
        s = rnd((1, 8), 1)

        def loss(beta):
            return -jnp.sum(ref.consmax_ref(s, beta, 100.0))

        g = float(jax.grad(loss)(jnp.float32(1.0)))
        assert g > 0  # increasing beta decreases probs, increases -sum
