"""AOT export integrity: manifest consistency, HLO text parseability by
the target XLA version's constraints, golden-vector self-consistency."""

import json
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def golden():
    with open(os.path.join(ART, "golden.json")) as f:
        return json.load(f)


class TestManifest:
    def test_all_files_exist(self, manifest):
        for name, e in manifest["entries"].items():
            assert os.path.exists(os.path.join(ART, e["file"])), name

    def test_sha_matches(self, manifest):
        import hashlib
        for name, e in manifest["entries"].items():
            text = open(os.path.join(ART, e["file"])).read()
            assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"], name

    def test_train_step_io_counts(self, manifest):
        """train_step: 3n params + step + x + y in, 3n + loss + gnorm out."""
        for key, cfg in manifest["configs"].items():
            n = len(cfg["param_order"])
            e = manifest["entries"][f"{key}_train_step"]
            assert len(e["inputs"]) == 3 * n + 3
            assert len(e["outputs"]) == 3 * n + 2

    def test_param_shapes_cover_order(self, manifest):
        for cfg in manifest["configs"].values():
            assert set(cfg["param_order"]) == set(cfg["param_shapes"])

    def test_paper_config_recorded(self, manifest):
        c = manifest["configs"]["paper_consmax"]
        assert c["n_layer"] == 6 and c["n_head"] == 6 and c["n_embd"] == 384

    def test_entry_docs_nonempty(self, manifest):
        for name, e in manifest["entries"].items():
            assert e["doc"], name


class TestHloText:
    def test_hlo_parses_as_module(self, manifest):
        """Every artifact must start with an HloModule header (the text
        format the 0.5.1 parser accepts)."""
        for name, e in manifest["entries"].items():
            head = open(os.path.join(ART, e["file"])).read(200)
            assert head.startswith("HloModule"), name

    def test_root_is_tuple(self, manifest):
        """return_tuple=True lowering: ENTRY root must be a tuple so the
        Rust side can to_tuple() uniformly."""
        for name, e in manifest["entries"].items():
            text = open(os.path.join(ART, e["file"])).read()
            m = re.search(r"ENTRY[^{]*\{(.*?)\n\}", text, re.S)
            assert m, name
            assert "tuple(" in m.group(1) or "tuple database" not in text, name

    def test_entry_parameter_count_matches_manifest(self, manifest):
        """The HLO ENTRY signature must declare exactly the manifest's
        inputs — jit's default unused-arg pruning (e.g. beta/gamma in the
        softmax variants) would silently break the Rust input contract."""
        for name, e in manifest["entries"].items():
            text = open(os.path.join(ART, e["file"])).read()
            m = re.search(r"ENTRY[^{]*\{(.*)", text, re.S)
            assert m, name
            n_params = len(re.findall(r"=\s*\S+\s+parameter\(", m.group(1)))
            assert n_params == len(e["inputs"]), (
                f"{name}: HLO has {n_params} parameters, manifest says "
                f"{len(e['inputs'])}"
            )

    def test_no_custom_calls_in_op_kernels(self, manifest):
        """interpret=True must have erased Mosaic custom-calls: a
        custom-call in the HLO would be unloadable on CPU PJRT."""
        for name, e in manifest["entries"].items():
            if not name.startswith("op_"):
                continue
            text = open(os.path.join(ART, e["file"])).read()
            assert "custom-call" not in text, name


class TestGolden:
    def test_consmax_golden_reproduces(self, golden):
        g = golden["consmax"]
        s = jnp.asarray(np.array(g["s"], np.float32).reshape(g["shape"]))
        out = ref.consmax_ref(s, np.float32(g["beta"]), np.float32(g["gamma"]))
        np.testing.assert_allclose(np.asarray(out).ravel(), g["out"],
                                   rtol=1e-6)

    def test_softmax_golden_reproduces(self, golden):
        g = golden["softmax"]
        s = jnp.asarray(np.array(g["s"], np.float32).reshape(g["shape"]))
        np.testing.assert_allclose(
            np.asarray(ref.softmax_ref(s)).ravel(), g["out"], rtol=1e-6)

    def test_lut_golden_bits(self, golden):
        g = golden["lut_exp_s16"]
        q = jnp.asarray(np.array(g["q"], np.int8))
        got = np.asarray(ref.lut_exp_ref(q, g["scale"])).view(np.uint16)
        np.testing.assert_array_equal(got.astype(int), g["out_bits"])

    def test_lut_tables_golden_bits(self, golden):
        g = golden["lut_tables_s16"]
        msb, lsb = (np.asarray(t).view(np.uint16).astype(int)
                    for t in ref.lut_tables(1 / 16))
        assert msb.tolist() == g["msb_bits"]
        assert lsb.tolist() == g["lsb_bits"]

    def test_golden_c_merges(self, golden):
        g = golden["consmax"]
        assert abs(g["c"] - np.exp(-g["beta"]) / g["gamma"]) < 1e-9


class TestSpecs:
    def test_spec_of(self):
        s = aot.spec_of(jnp.zeros((2, 3), jnp.int8))
        assert s == {"shape": [2, 3], "dtype": "int8"}

    def test_hlo_text_roundtrip_smoke(self):
        """Lower a trivial fn and confirm to_hlo_text output is parseable
        text with the right parameter count."""
        lowered = jax.jit(lambda a, b: (a + b,)).lower(
            jnp.zeros((2,)), jnp.zeros((2,)))
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert text.count("parameter(") >= 2
