"""Deployment-form accuracy: the INT8 bitwidth-split normalizer inside
full attention (paper §IV-A: lossless LUTs + quantized scores maintain
accuracy)."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given

from compile.kernels import quant_attn, ref


def qkv(seed, b=2, h=2, t=16, hd=8):
    r = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(r.normal(0, 1, (b, h, t, hd)).astype(np.float32))
    return mk(), mk(), mk()


BETA = jnp.array([1.0, 2.0])
GAMMA = jnp.array([100.0, 100.0])


class TestQuantConsmaxKernel:
    def test_bits_equal_lut_path(self):
        """quantize+LUT kernel == quantize then lut_consmax, bitwise."""
        r = np.random.default_rng(0)
        s = jnp.asarray(r.uniform(-6, 6, (128,)).astype(np.float32))
        c = jnp.float32(0.013)
        got = np.asarray(quant_attn.quant_consmax_pallas(s, c))
        q = ref.quantize_int8(s)
        want = np.asarray(ref.lut_consmax_ref(q, c))
        np.testing.assert_array_equal(
            got.view(np.uint16), want.view(np.uint16))

    @given(seed=st.integers(0, 1000))
    def test_close_to_float_consmax(self, seed):
        r = np.random.default_rng(seed)
        s = jnp.asarray(r.uniform(-4, 4, (64,)).astype(np.float32))
        got = np.asarray(
            quant_attn.quant_consmax_pallas(s, jnp.float32(0.01)),
            dtype=np.float32,
        )
        want = 0.01 * np.exp(np.asarray(s))
        np.testing.assert_allclose(got, want, rtol=0.05, atol=1e-5)


class TestQuantizedAttention:
    @given(seed=st.integers(0, 200))
    def test_matches_float_attention(self, seed):
        """The deployment path tracks the training path within the
        quantization error budget - the §V accuracy claim's mechanism."""
        q, k, v = qkv(seed)
        fl = np.asarray(quant_attn.float_consmax_attention(q, k, v, BETA, GAMMA))
        hw = np.asarray(
            quant_attn.quantized_consmax_attention(q, k, v, BETA, GAMMA))
        # probs err ~ 3.2% relative -> attention output absolute error is
        # bounded by that times sum|p||v|; use a generous combined bound
        denom = np.abs(fl).max() + 1e-3
        rel = np.abs(hw - fl).max() / denom
        assert rel < 0.08, rel

    def test_causality_preserved(self):
        q, k, v = qkv(7)
        out1 = np.asarray(
            quant_attn.quantized_consmax_attention(q, k, v, BETA, GAMMA))
        k2 = k.at[:, :, -1].set(99.0)  # tamper with the LAST key
        v2 = v.at[:, :, -1].set(99.0)
        out2 = np.asarray(
            quant_attn.quantized_consmax_attention(q, k2, v2, BETA, GAMMA))
        # all but the last query position must be unchanged
        np.testing.assert_array_equal(out1[:, :, :-1], out2[:, :, :-1])

    def test_masked_positions_contribute_zero(self):
        q, k, v = qkv(3, t=8)
        # poison future values: if masking leaked even slightly, the huge
        # magnitude would dominate the output (0 * 1e30 == 0 exactly)
        vbad = v.at[:, :, 5:].set(1e30)
        out = np.asarray(quant_attn.quantized_consmax_attention(
            q, k, vbad, BETA, GAMMA))
        assert np.isfinite(out[:, :, :5]).all()
        assert np.abs(out[:, :, :5]).max() < 1e6

    def test_output_fp16_dynamic_range_safe(self):
        """Scores clamp to ±8; with paper-scale beta/gamma the fp16
        probability stream cannot overflow."""
        q, k, v = qkv(11)
        q = q * 100.0  # extreme logits -> saturating quantizer
        out = np.asarray(quant_attn.quantized_consmax_attention(
            q, k, v, BETA, GAMMA))
        assert np.isfinite(out).all()

    @given(scale=st.sampled_from([1 / 8, 1 / 16, 1 / 32]))
    def test_finer_scale_tracks_float_better(self, scale):
        q, k, v = qkv(5)
        fl = np.asarray(quant_attn.float_consmax_attention(q, k, v, BETA, GAMMA))
        hw = np.asarray(quant_attn.quantized_consmax_attention(
            q, k, v, BETA, GAMMA, scale=scale))
        denom = np.abs(fl).max() + 1e-3
        rel = np.abs(hw - fl).max() / denom
        # error budget shrinks with the quantization step (until clipping
        # bites at 1/32: range ±4 only covers these normalized scores)
        budget = {1 / 8: 0.12, 1 / 16: 0.08, 1 / 32: 0.08}[scale]
        assert rel < budget, (scale, rel)
