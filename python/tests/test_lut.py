"""The paper's "lossless" hardware claim, proven exhaustively.

§IV-A: the bitwidth-split unit must produce the exact exponential (up to
fp16 representation) for EVERY input code - not a piecewise-linear
approximation. These tests enumerate the full INT8 (and INT16-reduction)
input space.
"""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given

from compile.kernels import lut as lutk
from compile.kernels import ref

ALL_INT8 = jnp.arange(-128, 128, dtype=jnp.int8)


class TestBitwidthSplit:
    def test_split_int8_roundtrip(self):
        """q == 16*(msb_index - 8) + lsb for every code."""
        mi, li = (np.asarray(a) for a in ref.split_int8(ALL_INT8))
        q = 16 * (mi - 8) + li
        np.testing.assert_array_equal(q, np.arange(-128, 128))

    def test_split_ranges(self):
        mi, li = (np.asarray(a) for a in ref.split_int8(ALL_INT8))
        assert mi.min() == 0 and mi.max() == 15
        assert li.min() == 0 and li.max() == 15

    @pytest.mark.parametrize("scale", [1 / 16, 1 / 32, 1 / 8, 1 / 64])
    def test_eq4_identity_fp32(self, scale):
        """Eq. 4: exp(q*s) == exp(16*s*m) * exp(s*l) exactly in exact math;
        verify in fp32 to tight tolerance for all 256 codes."""
        q = np.arange(-128, 128)
        m, l = q >> 4, q & 0xF
        lhs = np.exp(q * scale)
        rhs = np.exp(16 * scale * m) * np.exp(scale * l)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-6)

    @pytest.mark.parametrize("scale", [1 / 16, 1 / 32])
    def test_lossless_vs_fp16_exp_grid(self, scale):
        """The hardware's fp16 LUT path vs direct fp16(exp(x)): the only
        divergence allowed is one fp16 rounding in the multiply. This is
        the 'lossless non-linear operation' claim quantified."""
        direct = np.exp(np.arange(-128, 128) * scale).astype(np.float16)
        got = np.asarray(ref.lut_exp_ref(ALL_INT8, scale))
        # one ulp of fp16 multiply rounding max
        d = got.astype(np.float64)
        t = direct.astype(np.float64)
        rel = np.abs(d - t) / np.maximum(t, 1e-30)
        assert rel.max() <= 2 ** -10, f"max rel err {rel.max()}"

    def test_lut_pallas_bit_exact_vs_ref(self):
        """Pallas kernel == numpy oracle, bit for bit, full grid."""
        c = jnp.float16(0.013)
        got = np.asarray(lutk.lut_consmax_pallas(ALL_INT8, c))
        want = np.asarray(ref.lut_consmax_ref(ALL_INT8, c))
        np.testing.assert_array_equal(got.view(np.uint16),
                                      want.view(np.uint16))

    @given(seed=st.integers(0, 10_000))
    def test_lut_consmax_matches_float_path(self, seed):
        """Quantize -> LUT path approximates the float consmax within
        quantization error (scale/2 on scores)."""
        r = np.random.default_rng(seed)
        s = r.uniform(-4, 4, (64,)).astype(np.float32)
        scale = 1 / 16
        q = ref.quantize_int8(jnp.asarray(s), scale)
        c = jnp.float32(np.exp(-1.5) / 100.0)
        hw = np.asarray(lutk.lut_consmax_pallas(q, c, scale=scale),
                        dtype=np.float32)
        sw = np.asarray(ref.consmax_ref(jnp.asarray(s), 1.5, 100.0))
        # max quantization-induced relative error: exp(scale/2)-1 ~ 3.2%
        np.testing.assert_allclose(hw, sw, rtol=0.04, atol=1e-6)

    def test_msb_lut_contains_e_2_4_projection(self):
        """§IV-A: the MSB LUT directly stores e^(2^4 * x) so no non-linear
        (e)^16 hardware is needed - check the table contents."""
        msb, lsb = (np.asarray(t) for t in ref.lut_tables(1 / 16))
        m = np.arange(-8, 8)
        np.testing.assert_array_equal(
            msb.view(np.uint16),
            np.exp(16 * (1 / 16) * m).astype(np.float16).view(np.uint16))
        l = np.arange(16)
        np.testing.assert_array_equal(
            lsb.view(np.uint16),
            np.exp((1 / 16) * l).astype(np.float16).view(np.uint16))

    def test_lut_sizes_are_16_entries(self):
        """The whole point of the split: 2x16 entries, not 256."""
        msb, lsb = ref.lut_tables()
        assert msb.shape == (16,) and lsb.shape == (16,)


class TestInt16ReductionUnit:
    def test_split_int16_roundtrip(self):
        q = np.arange(-32768, 32768, 257)          # stride keeps test fast
        hi, lo = (np.asarray(a) for a in
                  ref.split_int16(jnp.asarray(q, jnp.int16)))
        np.testing.assert_array_equal(256 * hi + lo, q)

    def test_int16_path_matches_direct_exp(self):
        """Reduction-unit chain (4 fp16 factors) vs direct exp; tolerance
        is a few fp16 roundings."""
        q = jnp.asarray(np.arange(-2048, 2048, 7), jnp.int16)
        scale = 1 / 256
        got = np.asarray(ref.lut_exp16_ref(q, scale), dtype=np.float64)
        want = np.exp(np.asarray(q, np.float64) * scale)
        rel = np.abs(got - want) / want
        assert rel.max() < 2e-3, rel.max()

    def test_int16_lsb_byte_nonnegative_exponents(self):
        """The low byte is unsigned: its factors are all >= 1."""
        q = jnp.asarray([-1, -255, -256, 255, 511], jnp.int16)
        hi, lo = ref.split_int16(q)
        assert np.asarray(lo).min() >= 0


class TestQuantizer:
    @given(seed=st.integers(0, 1000), scale=st.sampled_from([1/8, 1/16, 1/32]))
    def test_quantize_bounds(self, seed, scale):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(0, 10, (256,)).astype(np.float32))
        q = np.asarray(ref.quantize_int8(x, scale))
        assert q.dtype == np.int8

    @given(seed=st.integers(0, 1000))
    def test_quantize_roundtrip_error_bound(self, seed):
        r = np.random.default_rng(seed)
        scale = 1 / 16
        x = r.uniform(-7.9, 7.9, (512,)).astype(np.float32)
        q = np.asarray(ref.quantize_int8(jnp.asarray(x), scale), np.float32)
        err = np.abs(q * scale - x)
        assert err.max() <= scale / 2 + 1e-6

    def test_quantize_saturates(self):
        x = jnp.asarray([1e9, -1e9], jnp.float32)
        q = np.asarray(ref.quantize_int8(x))
        assert q[0] == 127 and q[1] == -128
