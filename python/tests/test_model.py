"""L2 model tests: shapes, normalizer plumbing, gradients, optimizer,
decode-vs-forward consistency, paper-specific behaviours."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

CFG = model.config_by_name("tiny")


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    r = np.random.default_rng(0)
    x = jnp.asarray(r.integers(0, CFG.vocab, (4, CFG.ctx)), jnp.int32)
    return x, jnp.roll(x, -1, axis=1)


class TestConfig:
    def test_paper_config_matches_paper(self):
        """§V-A: 6 layers, 6 heads, embd 384, ctx 256."""
        c = model.config_by_name("paper")
        assert (c.n_layer, c.n_head, c.n_embd, c.ctx) == (6, 6, 384, 256)
        assert c.gamma_init == 100.0
        assert c.beta_init == 2.5

    def test_head_dim(self):
        assert model.config_by_name("paper").head_dim == 64
        assert CFG.head_dim == CFG.n_embd // CFG.n_head

    def test_overrides(self):
        c = model.config_by_name("tiny", normalizer="softmax")
        assert c.normalizer == "softmax"

    def test_param_count_paper_scale(self):
        """~10.7M params for the paper model (sanity on architecture)."""
        c = model.config_by_name("paper")
        p = model.init_params(c, jax.random.PRNGKey(0))
        total = sum(int(np.prod(v.shape)) for v in p.values())
        assert 10e6 < total < 12e6, total


class TestParams:
    def test_flatten_roundtrip(self, params):
        flat = model.flatten_params(CFG, params)
        back = model.unflatten_params(CFG, flat)
        assert set(back) == set(params)
        for k in params:
            np.testing.assert_array_equal(back[k], params[k])

    def test_order_is_stable(self):
        assert model.param_order(CFG) == model.param_order(
            model.config_by_name("paper"))

    def test_beta_init_range(self, params):
        b = np.asarray(params["beta"])
        assert b.shape == (CFG.n_layer, CFG.n_head)
        assert (b >= 0.5).all() and (b <= 2.5).all()

    def test_gamma_init_value(self, params):
        np.testing.assert_array_equal(np.asarray(params["gamma"]), 100.0)

    def test_heads_start_at_different_betas(self, params):
        """Fig 7 shows traces from different starting values."""
        assert len(np.unique(np.asarray(params["beta"]))) > 1


class TestForward:
    def test_logits_shape(self, params, batch):
        x, _ = batch
        lg = model.forward(CFG, params, x)
        assert lg.shape == (4, CFG.ctx, CFG.vocab)

    def test_forward_finite(self, params, batch):
        x, _ = batch
        assert np.isfinite(np.asarray(model.forward(CFG, params, x))).all()

    @pytest.mark.parametrize("norm", ["softmax", "consmax", "softermax"])
    def test_all_normalizers_run(self, batch, norm):
        cfg = model.config_by_name("tiny", normalizer=norm)
        p = model.init_params(cfg, jax.random.PRNGKey(1))
        x, _ = batch
        lg = model.forward(cfg, p, x)
        assert np.isfinite(np.asarray(lg)).all()

    def test_pallas_path_matches_jnp_path(self, params, batch):
        x, _ = batch
        a = model.forward(CFG, params, x)
        b = model.forward(CFG, params, x, use_pallas=True)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_causality(self, params):
        """Changing token t must not change logits at positions < t."""
        r = np.random.default_rng(1)
        x = jnp.asarray(r.integers(0, CFG.vocab, (1, CFG.ctx)), jnp.int32)
        base = np.asarray(model.forward(CFG, params, x))
        x2 = x.at[0, 10].set((int(x[0, 10]) + 1) % CFG.vocab)
        pert = np.asarray(model.forward(CFG, params, x2))
        np.testing.assert_allclose(base[0, :10], pert[0, :10],
                                   rtol=1e-5, atol=1e-5)
        assert not np.allclose(base[0, 10:], pert[0, 10:])

    def test_shorter_context(self, params):
        x = jnp.zeros((2, CFG.ctx // 2), jnp.int32)
        lg = model.forward(CFG, params, x)
        assert lg.shape == (2, CFG.ctx // 2, CFG.vocab)


class TestNormalizeScores:
    def test_consmax_uses_beta_gamma(self):
        cfg = model.config_by_name("tiny", normalizer="consmax")
        s = jnp.zeros((1, cfg.n_head, 4, 4))
        beta = jnp.array([1.0, 2.0])
        gamma = jnp.array([100.0, 100.0])
        out = model.normalize_scores(cfg, s, beta, gamma)
        want = np.exp(-np.asarray(beta)) / np.asarray(gamma)
        np.testing.assert_allclose(out[0, :, 0, 0], want, rtol=1e-6)

    def test_unknown_normalizer_raises(self):
        cfg = model.config_by_name("tiny", normalizer="nope")
        with pytest.raises(ValueError):
            model.normalize_scores(cfg, jnp.zeros((1, 2, 4, 4)),
                                   jnp.zeros(2), jnp.ones(2))


class TestTraining:
    def test_loss_decreases(self, batch):
        x, y = batch
        p = model.init_params(CFG, jax.random.PRNGKey(0))
        m = jax.tree.map(jnp.zeros_like, p)
        v = jax.tree.map(jnp.zeros_like, p)
        ts = jax.jit(lambda p, m, v, s: model.train_step(CFG, p, m, v, s, x, y))
        losses = []
        for i in range(8):
            p, m, v, loss, _ = ts(p, m, v, jnp.float32(i))
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_initial_loss_near_uniform(self, params, batch):
        """Untrained byte-vocab model: loss ~ ln(256) = 5.545."""
        x, y = batch
        loss = float(model.eval_step(CFG, params, x, y))
        assert abs(loss - np.log(256)) < 0.3

    def test_beta_gamma_receive_updates(self, batch):
        """Fig 7 precondition: beta/gamma actually move during training."""
        x, y = batch
        p = model.init_params(CFG, jax.random.PRNGKey(0))
        m = jax.tree.map(jnp.zeros_like, p)
        v = jax.tree.map(jnp.zeros_like, p)
        b0 = np.asarray(p["beta"]).copy()
        g0 = np.asarray(p["gamma"]).copy()
        for i in range(3):
            p, m, v, _, _ = model.train_step(CFG, p, m, v,
                                             jnp.float32(i), x, y)
        assert not np.array_equal(np.asarray(p["beta"]), b0)
        # gamma moves slowly (Fig 7: "low % change") but must not be frozen
        assert not np.array_equal(np.asarray(p["gamma"]), g0)

    def test_softmax_model_has_no_beta_grad_effect(self, batch):
        """With softmax normalizer, beta/gamma are dead params: grads 0."""
        cfg = model.config_by_name("tiny", normalizer="softmax")
        x, y = batch
        p = model.init_params(cfg, jax.random.PRNGKey(0))
        g = jax.grad(lambda pp: model.loss_fn(cfg, pp, x, y))(p)
        np.testing.assert_array_equal(np.asarray(g["beta"]), 0.0)
        np.testing.assert_array_equal(np.asarray(g["gamma"]), 0.0)

    def test_gradients_finite_all_normalizers(self, batch):
        x, y = batch
        for norm in ["softmax", "consmax", "softermax"]:
            cfg = model.config_by_name("tiny", normalizer=norm)
            p = model.init_params(cfg, jax.random.PRNGKey(2))
            g = jax.grad(lambda pp: model.loss_fn(cfg, pp, x, y))(p)
            for k, gv in g.items():
                assert np.isfinite(np.asarray(gv)).all(), (norm, k)

    def test_grad_clip_engages(self, batch):
        """gnorm output reflects the pre-clip global norm."""
        x, y = batch
        p = model.init_params(CFG, jax.random.PRNGKey(0))
        m = jax.tree.map(jnp.zeros_like, p)
        v = jax.tree.map(jnp.zeros_like, p)
        _, _, _, _, gnorm = model.train_step(CFG, p, m, v,
                                             jnp.float32(0), x, y)
        assert float(gnorm) > 0


class TestLrSchedule:
    def test_warmup_then_decay(self):
        lrs = [float(model.lr_schedule(CFG, jnp.float32(s)))
               for s in range(0, CFG.total_steps, 10)]
        peak = max(lrs)
        assert abs(peak - CFG.lr_max) / CFG.lr_max < 0.15
        assert lrs[-1] < peak
        assert lrs[0] < peak

    def test_floor(self):
        lr = float(model.lr_schedule(CFG, jnp.float32(CFG.total_steps * 2)))
        assert lr >= CFG.lr_min * 0.99


class TestDecode:
    @pytest.mark.parametrize("norm", ["softmax", "consmax"])
    def test_decode_matches_forward(self, norm):
        cfg = model.config_by_name("tiny", normalizer=norm)
        p = model.init_params(cfg, jax.random.PRNGKey(0))
        r = np.random.default_rng(3)
        toks = jnp.asarray(r.integers(0, cfg.vocab, (1, 12)), jnp.int32)
        kc, vc = model.init_kv_cache(cfg, 1)
        outs = []
        for t in range(12):
            lg, kc, vc = model.decode_step(cfg, p, kc, vc,
                                           jnp.int32(t), toks[:, t])
            outs.append(lg)
        full = model.forward(cfg, p, toks)
        np.testing.assert_allclose(jnp.stack(outs, 1), full,
                                   rtol=2e-3, atol=2e-3)

    def test_decode_batch(self):
        cfg = model.config_by_name("tiny")
        p = model.init_params(cfg, jax.random.PRNGKey(0))
        kc, vc = model.init_kv_cache(cfg, 4)
        lg, kc2, vc2 = model.decode_step(
            cfg, p, kc, vc, jnp.int32(0), jnp.zeros((4,), jnp.int32))
        assert lg.shape == (4, cfg.vocab)
        assert kc2.shape == kc.shape

    def test_cache_written_at_pos(self):
        cfg = model.config_by_name("tiny")
        p = model.init_params(cfg, jax.random.PRNGKey(0))
        kc, vc = model.init_kv_cache(cfg, 1)
        _, kc2, _ = model.decode_step(cfg, p, kc, vc, jnp.int32(5),
                                      jnp.ones((1,), jnp.int32))
        kc2 = np.asarray(kc2)
        assert np.abs(kc2[:, :, :, 5]).sum() > 0
        assert np.abs(kc2[:, :, :, 6:]).sum() == 0


class TestMergeForInference:
    def test_merged_constant_reproduces_training_form(self, params, batch):
        """Eq. 3 deployment path: merging per-head beta/gamma into C gives
        identical attention probabilities."""
        s = jnp.asarray(np.random.default_rng(0).normal(
            size=(2, CFG.n_head, 8, 8)).astype(np.float32))
        beta, gamma = params["beta"][0], params["gamma"][0]
        train = ref.consmax_ref(s, beta[None, :, None, None],
                                gamma[None, :, None, None])
        c = ref.merge_beta_gamma(beta, gamma)[None, :, None, None]
        infer = ref.consmax_inference_ref(s, c)
        np.testing.assert_allclose(train, infer, rtol=1e-5)
