"""AOT export: lower every entry point to HLO *text* + write the manifest.

HLO text (NOT ``lowered.compile().serialize()`` / HloModuleProto bytes) is
the interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Run as ``python -m compile.aot --out ../artifacts`` (from python/), or via
``make artifacts``. Python never runs again after this: the Rust
coordinator reads ``manifest.json`` for shapes/ordering and executes the
``.hlo.txt`` modules through PJRT.

Also emits ``golden.json``: concrete input/output vectors for a selection
of entry points, consumed by the Rust integration tests to pin the
cross-language numerics.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import consmax as kernels
from .kernels import lut as lutk
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_of(x) -> dict:
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


@dataclasses.dataclass
class Entry:
    name: str
    fn: object
    example_args: tuple
    doc: str


def build_entries(cfg: model.GPTConfig, cfg_name: str, batch: int,
                  decode_batches: list[int]) -> list[Entry]:
    """Entry points for one (config, normalizer) pair."""
    key = jax.random.PRNGKey(0)
    params = model.init_params(cfg, key)
    flat = model.flatten_params(cfg, params)
    zeros = [jnp.zeros_like(p) for p in flat]
    x = jnp.zeros((batch, cfg.ctx), jnp.int32)
    y = jnp.zeros((batch, cfg.ctx), jnp.int32)
    step = jnp.zeros((), jnp.float32)
    order = model.param_order(cfg)
    n = len(order)

    def train_fn(*args):
        p = model.unflatten_params(cfg, list(args[:n]))
        m = model.unflatten_params(cfg, list(args[n:2 * n]))
        v = model.unflatten_params(cfg, list(args[2 * n:3 * n]))
        st, xx, yy = args[3 * n], args[3 * n + 1], args[3 * n + 2]
        p2, m2, v2, loss, gnorm = model.train_step(cfg, p, m, v, st, xx, yy)
        return (*model.flatten_params(cfg, p2),
                *model.flatten_params(cfg, m2),
                *model.flatten_params(cfg, v2), loss, gnorm)

    def eval_fn(*args):
        p = model.unflatten_params(cfg, list(args[:n]))
        return (model.eval_step(cfg, p, args[n], args[n + 1]),)

    def forward_fn(*args):
        p = model.unflatten_params(cfg, list(args[:n]))
        return (model.forward(cfg, p, args[n], use_pallas=True),)

    def eval_quant_fn(*args):
        p = model.unflatten_params(cfg, list(args[:n]))
        return (model.eval_step_quant(cfg, p, args[n], args[n + 1]),)

    entries = [
        Entry(f"{cfg_name}_{cfg.normalizer}_train_step", train_fn,
              (*flat, *zeros, *zeros, step, x, y),
              "fused fwd+bwd+AdamW; inputs params|m|v|step|x|y, "
              "outputs params'|m'|v'|loss|gnorm"),
        Entry(f"{cfg_name}_{cfg.normalizer}_eval_step", eval_fn,
              (*flat, x, y), "mean NLL over a batch"),
        Entry(f"{cfg_name}_{cfg.normalizer}_forward", forward_fn,
              (*flat, jnp.zeros((1, cfg.ctx), jnp.int32)),
              "full-context logits (B=1), pallas normalizer kernels"),
    ]
    if cfg.normalizer == "consmax":
        entries.append(Entry(
            f"{cfg_name}_consmax_eval_quant", eval_quant_fn,
            (*flat, x, y),
            "mean NLL with the INT8 bitwidth-split hardware normalizer "
            "(deployment-form accuracy, Fig 4a datapath)"))

    for db in decode_batches:
        kc, vc = model.init_kv_cache(cfg, db)
        tok = jnp.zeros((db,), jnp.int32)
        pos = jnp.zeros((), jnp.int32)

        def decode_fn(*args, _db=db):
            p = model.unflatten_params(cfg, list(args[:n]))
            return model.decode_step(cfg, p, args[n], args[n + 1],
                                     args[n + 2], args[n + 3])

        entries.append(Entry(
            f"{cfg_name}_{cfg.normalizer}_decode_b{db}", decode_fn,
            (*flat, kc, vc, pos, tok),
            f"KV-cached single-token decode, batch {db}; "
            "inputs params|kc|vc|pos|token, outputs logits|kc'|vc'"))
    return entries


def op_entries() -> list[Entry]:
    """Standalone normalizer ops (quickstart + runtime microbench)."""
    s = jnp.zeros((64, 256), jnp.float32)
    c = jnp.zeros((64, 256), jnp.float32)
    q = jnp.zeros((64, 256), jnp.int8)
    return [
        Entry("op_consmax", lambda a, b: (kernels.consmax_pallas(a, b),),
              (s, c), "pallas ConSmax: C*exp(s), tiled, reduction-free"),
        Entry("op_softmax", lambda a: (kernels.softmax_pallas(a),),
              (s,), "pallas row softmax baseline"),
        Entry("op_softermax", lambda a: (kernels.softermax_pallas(a),),
              (s,), "pallas base-2 softermax baseline"),
        Entry("op_lut_consmax",
              lambda a, b: (lutk.lut_consmax_pallas(a, b),),
              (q, c), "bit-exact bitwidth-split LUT ConSmax on INT8 codes"),
        Entry("op_consmax_pv",
              lambda a, b, v: (kernels.consmax_pv_pallas(a, b, v),),
              (jnp.zeros((256, 256), jnp.float32),
               jnp.zeros((256, 256), jnp.float32),
               jnp.zeros((256, 64), jnp.float32)),
              "fused ConSmax + PxV streaming tail (element-wise pipeline)"),
    ]


# ---------------------------------------------------------------------------
# Golden vectors for Rust integration tests
# ---------------------------------------------------------------------------

def golden_vectors() -> dict:
    """Small concrete cases pinning cross-language numerics."""
    rng = np.random.default_rng(42)
    out = {}

    s = rng.normal(size=(4, 8)).astype(np.float32)
    beta, gamma = np.float32(1.5), np.float32(100.0)
    c = float(np.exp(-beta) / gamma)
    out["consmax"] = {
        "s": s.ravel().tolist(), "shape": [4, 8],
        "beta": float(beta), "gamma": float(gamma), "c": c,
        "out": np.asarray(
            ref.consmax_ref(jnp.asarray(s), beta, gamma)).ravel().tolist(),
    }

    out["softmax"] = {
        "s": s.ravel().tolist(), "shape": [4, 8],
        "out": np.asarray(ref.softmax_ref(jnp.asarray(s))).ravel().tolist(),
    }

    # exhaustive INT8 LUT grid - THE lossless-hardware golden
    q = np.arange(-128, 128, dtype=np.int8)
    for scale_name, scale in [("s16", 1.0 / 16.0), ("s32", 1.0 / 32.0)]:
        e = np.asarray(ref.lut_exp_ref(jnp.asarray(q), scale),
                       dtype=np.float16)
        out[f"lut_exp_{scale_name}"] = {
            "scale": scale,
            "q": q.astype(int).tolist(),
            # bit pattern, not value: the Rust model must match EXACTLY
            "out_bits": e.view(np.uint16).astype(int).tolist(),
        }
    msb, lsb = (np.asarray(t) for t in ref.lut_tables(1.0 / 16.0))
    out["lut_tables_s16"] = {
        "msb_bits": msb.view(np.uint16).astype(int).tolist(),
        "lsb_bits": lsb.view(np.uint16).astype(int).tolist(),
    }
    return out


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def export(outdir: str, configs: list[str], normalizers: list[str],
           batch: int | None, skip_unchanged: bool = True) -> None:
    os.makedirs(outdir, exist_ok=True)
    manifest = {"format": "hlo-text-v1", "entries": {}, "configs": {}}

    all_entries: list[Entry] = op_entries()
    for cfg_name in configs:
        for norm in normalizers:
            cfg = model.config_by_name(cfg_name, normalizer=norm)
            b = batch or (8 if cfg_name == "paper" else 4)
            decode_b = [1, 4] if cfg_name == "paper" else [1]
            all_entries += build_entries(cfg, cfg_name, b, decode_b)
            key = f"{cfg_name}_{norm}"
            manifest["configs"][key] = {
                **{f.name: getattr(cfg, f.name)
                   for f in dataclasses.fields(cfg)},
                "param_order": model.param_order(cfg),
                "param_shapes": {
                    k: list(v.shape) for k, v in
                    model.init_params(cfg, jax.random.PRNGKey(0)).items()
                },
                "train_batch": b,
            }

    for e in all_entries:
        path = os.path.join(outdir, f"{e.name}.hlo.txt")
        # keep_unused=True: softmax/softermax variants never read beta/gamma,
        # and jit would silently prune those parameters from the HLO
        # signature, breaking the manifest's input contract with Rust.
        lowered = jax.jit(e.fn, keep_unused=True).lower(*e.example_args)
        text = to_hlo_text(lowered)
        if not (skip_unchanged and os.path.exists(path)
                and open(path).read() == text):
            with open(path, "w") as f:
                f.write(text)
        outs = jax.eval_shape(e.fn, *e.example_args)
        manifest["entries"][e.name] = {
            "file": f"{e.name}.hlo.txt",
            "doc": e.doc,
            "inputs": [spec_of(a) for a in e.example_args],
            "outputs": [spec_of(o) for o in outs],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"  exported {e.name}: {len(e.example_args)} inputs, "
              f"{len(text)} chars")

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    with open(os.path.join(outdir, "golden.json"), "w") as f:
        json.dump(golden_vectors(), f)
    print(f"wrote manifest with {len(manifest['entries'])} entries")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,paper")
    ap.add_argument("--normalizers", default="consmax,softmax")
    ap.add_argument("--batch", type=int, default=None)
    args = ap.parse_args()
    export(args.out, args.configs.split(","), args.normalizers.split(","),
           args.batch)


if __name__ == "__main__":
    main()
