"""Quantized ConSmax attention (paper §IV-A / Fig 4a deployment form).

The accelerator's actual dataflow: the QxK tensor core emits INT8 scores,
the ConSmax unit turns each code into an fp16 probability through the
bitwidth-split LUTs, and the PV core consumes the fp16 stream. This
module implements that pipeline as a Pallas kernel (bit-faithful to the
hardware) plus a model-level helper to measure the accuracy cost of
deploying a trained float model with the quantized normalizer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _quant_consmax_kernel(s_ref, c_ref, msb_ref, lsb_ref, o_ref, *, scale):
    """Float scores -> INT8 quantize -> LUT exp -> xC, all hardware-exact."""
    s = s_ref[...]
    q = jnp.clip(jnp.round(s / scale), -128, 127).astype(jnp.int32)
    mi = (q >> 4) + 8
    li = q & 0xF
    e = (msb_ref[mi] * lsb_ref[li]).astype(jnp.float16)
    o_ref[...] = (e * c_ref[...].astype(jnp.float16)).astype(jnp.float16)


@functools.partial(jax.jit, static_argnames=("scale", "block"))
def quant_consmax_pallas(
    s: jax.Array, c: jax.Array, *, scale: float = 1.0 / 16.0, block: int = 256
) -> jax.Array:
    """End-to-end hardware normalizer: float scores in, fp16 probs out.

    Models the full Fig 4(a) unit including the INT8 quantization that the
    QxK core performs; output bits equal BitSplitLut::consmax(quantize(s)).
    """
    orig_shape = s.shape
    n = s.size
    sf = s.reshape(-1)
    cf = jnp.broadcast_to(c, orig_shape).reshape(-1)
    pad = (-n) % block
    if pad:
        sf = jnp.pad(sf, (0, pad))
        cf = jnp.pad(cf, (0, pad))
    msb, lsb = ref.lut_tables(scale)

    out = pl.pallas_call(
        functools.partial(_quant_consmax_kernel, scale=scale),
        out_shape=jax.ShapeDtypeStruct((sf.size,), jnp.float16),
        grid=(sf.size // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((16,), lambda i: (0,)),
            pl.BlockSpec((16,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(sf, cf, msb, lsb)
    return out[:n].reshape(orig_shape)


def quantized_consmax_attention(
    q: jax.Array,            # (B, H, T, hd)
    k: jax.Array,            # (B, H, T, hd)
    v: jax.Array,            # (B, H, T, hd)
    beta: jax.Array,         # (H,)
    gamma: jax.Array,        # (H,)
    *,
    scale: float = 1.0 / 16.0,
) -> jax.Array:
    """Causal attention with the hardware-quantized ConSmax normalizer.

    Everything outside the normalizer stays float (the tensor cores run
    int8/bf16 in a real accelerator, but score quantization is the paper's
    focus and the only accuracy-relevant change ConSmax introduces).
    """
    bsz, h, t, hd = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    # hardware masking: masked positions force probability to exactly 0
    # AFTER the unit (a gate on the output stream), since -inf cannot be
    # represented in INT8
    c = ref.merge_beta_gamma(beta, gamma)[None, :, None, None]
    probs = quant_consmax_pallas(scores, c, scale=scale).astype(jnp.float32)
    probs = jnp.where(mask[None, None], probs, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def float_consmax_attention(q, k, v, beta, gamma):
    """Float reference for the same attention (training-time semantics)."""
    bsz, h, t, hd = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = ref.consmax_ref(
        scores, beta[None, :, None, None], gamma[None, :, None, None]
    )
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)
