"""Layer-1 Pallas kernels: ConSmax, Softmax and Softermax score normalizers.

The ConSmax kernel is the paper's compute contribution mapped to TPU idiom
(DESIGN.md §Hardware-Adaptation): because ConSmax(S_i) = C * exp(S_i - beta)
has **no reduction over the score axis**, every (query-block, key-block)
tile is independent - the BlockSpec grid carries no cross-tile state, no
online-max running maximum, no second normalization pass. That is the TPU
translation of the paper's "synchronization-free" hardware property: the
HBM->VMEM schedule streams score tiles once and emits probability tiles
immediately, exactly like the element-wise pipeline of Fig. 4(b).

The softmax/softermax kernels exist as the baseline: they need the whole
score row in VMEM (or a two-pass/online schedule) before any output can be
produced - the stall the paper attacks.

All kernels use ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and correctness (not wallclock) is what the interpret
path validates. Real-TPU resource estimates live in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes for the (rows, seq) tiling. 128 matches the MXU/VPU lane
# width; on TPU a (128, 128) f32 tile is 64 KiB of VMEM, so a double-
# buffered in+out stream fits comfortably in the ~16 MiB VMEM budget.
ROW_BLOCK = 128
SEQ_BLOCK = 128


def _consmax_kernel(s_ref, c_ref, o_ref):
    """Tile-local ConSmax: o = C * exp(s). No cross-tile state (the point)."""
    o_ref[...] = c_ref[...] * jnp.exp(s_ref[...])


def _pad_to(x: jax.Array, mult_rows: int, mult_cols: int, fill: float):
    r, c = x.shape
    pr = (-r) % mult_rows
    pc = (-c) % mult_cols
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)), constant_values=fill)
    return x, r, c


@functools.partial(jax.jit, static_argnames=("row_block", "seq_block"))
def consmax_pallas(
    s: jax.Array,
    c: jax.Array,
    *,
    row_block: int = ROW_BLOCK,
    seq_block: int = SEQ_BLOCK,
) -> jax.Array:
    """ConSmax over the last axis of ``s`` with per-row merged constant ``C``.

    ``s``: (..., T) scores. ``c``: broadcastable to ``s`` (per-head scalar in
    the paper; here materialized per-row so one kernel serves every layout).

    The grid is (rows/row_block, T/seq_block); each program instance touches
    one tile and nothing else - contrast with softmax_pallas below.
    """
    orig_shape = s.shape
    t = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    s2 = s.reshape(rows, t)
    c2 = jnp.broadcast_to(c, orig_shape).reshape(rows, t)

    s2, r0, c0 = _pad_to(s2, row_block, seq_block, 0.0)
    c2, _, _ = _pad_to(c2, row_block, seq_block, 0.0)
    pr, pt = s2.shape

    out = pl.pallas_call(
        _consmax_kernel,
        out_shape=jax.ShapeDtypeStruct((pr, pt), s.dtype),
        grid=(pr // row_block, pt // seq_block),
        in_specs=[
            pl.BlockSpec((row_block, seq_block), lambda i, j: (i, j)),
            pl.BlockSpec((row_block, seq_block), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((row_block, seq_block), lambda i, j: (i, j)),
        interpret=True,
    )(s2, c2)
    return out[:r0, :c0].reshape(orig_shape)


def _softmax_kernel(s_ref, o_ref):
    """Whole-row softmax: needs the full score row resident (the baseline)."""
    s = s_ref[...]
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("row_block",))
def softmax_pallas(s: jax.Array, *, row_block: int = ROW_BLOCK) -> jax.Array:
    """Standard softmax over the last axis, one full row per program.

    The BlockSpec must span the entire score axis - the max/sum reductions
    couple every element of the row. This is the VMEM-resident requirement
    ConSmax removes.
    """
    orig_shape = s.shape
    t = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    s2 = s.reshape(rows, t)
    # pad rows to the block multiple; pad cols with -inf so they don't
    # perturb max or sum
    s2, r0, _ = _pad_to(s2, row_block, 1, -jnp.inf)
    pr = s2.shape[0]

    out = pl.pallas_call(
        _softmax_kernel,
        out_shape=jax.ShapeDtypeStruct((pr, t), s.dtype),
        grid=(pr // row_block,),
        in_specs=[pl.BlockSpec((row_block, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((row_block, t), lambda i: (i, 0)),
        interpret=True,
    )(s2)
    return out[:r0].reshape(orig_shape)


def _softermax_kernel(s_ref, o_ref):
    s = s_ref[...]
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp2(s - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("row_block",))
def softermax_pallas(s: jax.Array, *, row_block: int = ROW_BLOCK) -> jax.Array:
    """Softermax (base-2 softmax) over the last axis; same coupling as softmax."""
    orig_shape = s.shape
    t = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    s2 = s.reshape(rows, t)
    s2, r0, _ = _pad_to(s2, row_block, 1, -jnp.inf)
    pr = s2.shape[0]

    out = pl.pallas_call(
        _softermax_kernel,
        out_shape=jax.ShapeDtypeStruct((pr, t), s.dtype),
        grid=(pr // row_block,),
        in_specs=[pl.BlockSpec((row_block, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((row_block, t), lambda i: (i, 0)),
        interpret=True,
    )(s2)
    return out[:r0].reshape(orig_shape)


# ---------------------------------------------------------------------------
# Fused attention tail: ConSmax + P x V in one streaming kernel.
# ---------------------------------------------------------------------------

def _consmax_pv_kernel(s_ref, c_ref, v_ref, o_ref):
    """One (q-block, k-block) step of the element-wise pipeline of Fig. 4(b).

    Normalizes the score tile and immediately accumulates its P x V
    contribution - no waiting for the rest of the score row. The grid's
    k axis is the innermost (sequential) dimension, so o_ref accumulates
    across k-steps; this is legal because ConSmax needs no cross-k state.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    p = c_ref[...] * jnp.exp(s_ref[...])
    o_ref[...] += jnp.dot(p, v_ref[...], preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("row_block", "seq_block"))
def consmax_pv_pallas(
    s: jax.Array,
    c: jax.Array,
    v: jax.Array,
    *,
    row_block: int = ROW_BLOCK,
    seq_block: int = SEQ_BLOCK,
) -> jax.Array:
    """Fused ConSmax(S) @ V for 2-D ``s`` (Tq, Tk) and ``v`` (Tk, D).

    The TPU realization of the paper's integration claim: because the
    normalizer is element-local, the P x V matmul consumes probability
    tiles as they are produced (k-axis accumulation), never materializing
    the full P row - the software analogue of the back-end tensor core
    starting before the score row is complete.
    """
    tq, tk = s.shape
    d = v.shape[1]
    c2 = jnp.broadcast_to(c, s.shape)

    s2, q0, _ = _pad_to(s, row_block, seq_block, -jnp.inf)
    c2, _, _ = _pad_to(c2, row_block, seq_block, 0.0)
    # -inf scores pad to p = c*exp(-inf) = 0 contribution; c pad 0 makes the
    # padded columns contribute exactly zero even where s pad is 0.
    v2, _, _ = _pad_to(v, seq_block, 1, 0.0)
    pq, pk = s2.shape

    out = pl.pallas_call(
        _consmax_pv_kernel,
        out_shape=jax.ShapeDtypeStruct((pq, d), jnp.float32),
        grid=(pq // row_block, pk // seq_block),
        in_specs=[
            pl.BlockSpec((row_block, seq_block), lambda i, k: (i, k)),
            pl.BlockSpec((row_block, seq_block), lambda i, k: (i, k)),
            pl.BlockSpec((seq_block, d), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, d), lambda i, k: (i, 0)),
        interpret=True,
    )(s2, c2, v2)
    return out[:q0].astype(s.dtype)
