"""Pure-jnp correctness oracles for the L1 kernels.

These are the ground-truth implementations every Pallas kernel (and the
Rust `quant/` bit-exact model, via golden vectors) is validated against.

All functions operate on a score tensor ``s`` of shape ``(..., seq)`` where
the last axis is the key/score axis that Softmax normalizes over.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_ref(s: jax.Array, axis: int = -1) -> jax.Array:
    """Numerically-stable standard Softmax (Eq. 1 with beta = max)."""
    m = jnp.max(s, axis=axis, keepdims=True)
    e = jnp.exp(s - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def consmax_ref(s: jax.Array, beta: jax.Array, gamma: jax.Array) -> jax.Array:
    """ConSmax, training form (Eq. 2): exp(s - beta) / gamma.

    ``beta``/``gamma`` broadcast against ``s``; in the paper they are scalar
    per attention head, so for a ``(B, H, T, T)`` score tensor they have
    shape ``(H, 1, 1)`` (or scalar).
    """
    return jnp.exp(s - beta) / gamma


def consmax_inference_ref(s: jax.Array, c: jax.Array) -> jax.Array:
    """ConSmax, inference form (Eq. 3): C * exp(s), C = exp(-beta)/gamma.

    Note the paper's Eq. 3 prints ``C = -exp(beta)/gamma``; the sign (and
    the missing negation of beta in the exponent) is a typo - it
    contradicts Eq. 2 and would negate every probability - so we use
    ``C = exp(-beta)/gamma``, the form algebraically equal to Eq. 2.
    """
    return c * jnp.exp(s)


def merge_beta_gamma(beta: jax.Array, gamma: jax.Array) -> jax.Array:
    """Merge the two trained parameters into the single inference constant."""
    return jnp.exp(-beta) / gamma


def softermax_ref(s: jax.Array, axis: int = -1) -> jax.Array:
    """Softermax (Stevens et al., DAC'21): base-2 softmax.

    Computes 2^(s - max) / sum 2^(s - max). In hardware the max/sum are
    obtained by a chunked two-pass schedule (the partial-softmax structure
    of Fig. 3b); mathematically that equals this monolithic form, and the
    chunked dataflow itself is exercised by the pipeline simulator.
    """
    m = jnp.max(s, axis=axis, keepdims=True)
    e = jnp.exp2(s - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def partial_softmax_ref(s: jax.Array, n_chunks: int = 4) -> jax.Array:
    """Partial softmax (Fig. 3b): per-chunk local softmax + synchronization.

    Splits the last axis into ``n_chunks`` partial vectors, applies the
    standard softmax on each with its LOCAL max/sum, then rescales with the
    global max and global sum. Equals softmax_ref exactly; exists to model
    (and test) the synchronization structure FlashAttention-style schemes
    require and ConSmax eliminates.
    """
    t = s.shape[-1]
    assert t % n_chunks == 0, "chunk count must divide the score length"
    chunks = jnp.split(s, n_chunks, axis=-1)
    local_max = [jnp.max(c, axis=-1, keepdims=True) for c in chunks]
    local_exp = [jnp.exp(c - m) for c, m in zip(chunks, local_max)]
    local_sum = [jnp.sum(e, axis=-1, keepdims=True) for e in local_exp]
    # synchronization pass: global max, rescale local sums/exps
    g_max = jnp.max(jnp.concatenate(local_max, axis=-1), axis=-1, keepdims=True)
    scale = [jnp.exp(m - g_max) for m in local_max]
    g_sum = sum(sc * su for sc, su in zip(scale, local_sum))
    out = [e * sc / g_sum for e, sc in zip(local_exp, scale)]
    return jnp.concatenate(out, axis=-1)


# ---------------------------------------------------------------------------
# Bitwidth-split LUT path (paper Eq. 4) - the hardware-exact oracle.
# ---------------------------------------------------------------------------

def lut_tables(scale: float = 1.0 / 16.0) -> tuple[jax.Array, jax.Array]:
    """Build the two 16-entry FP16 LUTs of the bitwidth-split unit.

    An INT8 score code ``q`` (two's complement, value range [-128, 127])
    dequantizes to ``x = q * scale``.  Splitting ``q = 16*m + l`` with
    ``m`` the *signed* MSB nibble (-8..7) and ``l`` the unsigned LSB nibble
    (0..15) gives Eq. 4:

        exp(q*scale) = exp(16*scale*m) * exp(scale*l)

    MSB-LUT[m+8] = fp16(exp(16*scale*m)), LSB-LUT[l] = fp16(exp(scale*l)).
    """
    m = jnp.arange(-8, 8, dtype=jnp.float32)          # signed MSB nibble
    l = jnp.arange(0, 16, dtype=jnp.float32)          # unsigned LSB nibble
    msb = jnp.exp(16.0 * scale * m).astype(jnp.float16)
    lsb = jnp.exp(scale * l).astype(jnp.float16)
    return msb, lsb


def split_int8(q: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split signed INT8 codes into (MSB LUT index 0..15, LSB nibble 0..15).

    The MSB nibble is the arithmetic-shifted high nibble (-8..7); the LUT
    is laid out for m = -8..7 so the index is m + 8.
    """
    q = q.astype(jnp.int32)
    m = q >> 4                     # arithmetic shift: -8..7
    l = q & 0xF                    # 0..15
    return (m + 8).astype(jnp.int32), l.astype(jnp.int32)


def lut_exp_ref(q: jax.Array, scale: float = 1.0 / 16.0) -> jax.Array:
    """Bit-exact model of the bitwidth-split exponential: fp16 LUTs + fp16 mult.

    This is what the ConSmax hardware unit computes BEFORE the C-multiply.
    Lossless in the paper's sense: for every one of the 256 INT8 input
    codes the result is fp16(exp(16sm)) * fp16(exp(sl)) - no
    piecewise-linear approximation error, only fp16 representation
    rounding, identical between hardware and this model.
    """
    msb_lut, lsb_lut = lut_tables(scale)
    mi, li = split_int8(q)
    return (msb_lut[mi] * lsb_lut[li]).astype(jnp.float16)


def lut_consmax_ref(
    q: jax.Array, c: jax.Array, scale: float = 1.0 / 16.0
) -> jax.Array:
    """Full ConSmax hardware unit output: LUT-exp then multiply by C (fp16)."""
    e = lut_exp_ref(q, scale)
    return (e * c.astype(jnp.float16)).astype(jnp.float16)


def quantize_int8(x: jax.Array, scale: float = 1.0 / 16.0) -> jax.Array:
    """Symmetric INT8 quantizer used to feed the LUT path with real scores."""
    q = jnp.round(x / scale)
    return jnp.clip(q, -128, 127).astype(jnp.int8)


# INT16 path through the reduction unit (two 8-bit slices, Eq. 4 chained).

def split_int16(q: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split signed INT16 into (signed high byte -128..127, unsigned low byte)."""
    q = q.astype(jnp.int32)
    hi = q >> 8
    lo = q & 0xFF
    return hi, lo


def lut_exp16_ref(q: jax.Array, scale: float = 1.0 / 256.0) -> jax.Array:
    """INT16 exponential via the reduction unit: chain two bitwidth-split units.

    exp(q*scale) = exp(256*scale*hi) * exp(scale*lo); each byte-level factor
    is computed by a nibble-split LUT pair and the reduction unit's
    multiplier chain merges the partial factors (Eq. 4 chained).

    Precision note: the high-byte factor spans a much wider dynamic range
    than the low byte (its effective scale is 256x), so its LUT pair is
    stored in single precision and only the merged per-byte factor is
    rounded to fp16 - nibble-level fp16 rounding of the high byte would
    overflow fp16 for in-range inputs. This mirrors the paper's
    mixed-precision reduction unit, which allocates wider formats where
    the dynamic range demands them (§IV-A2).
    """
    hi, lo = split_int16(q)
    # high byte: signed nibble split, fp32 LUT entries, merged then rounded
    hs = 256.0 * scale
    m = hi >> 4                    # -8..7
    l_hi = hi & 0xF
    e_hi = (
        jnp.exp(16.0 * hs * m.astype(jnp.float32))
        * jnp.exp(hs * l_hi.astype(jnp.float32))
    ).astype(jnp.float16)
    # low byte: unsigned 0..255 - two unsigned nibbles with scale `scale`,
    # narrow dynamic range -> fp16 tables exactly as the 8-bit unit
    mi = (lo >> 4).astype(jnp.int32)
    li = (lo & 0xF).astype(jnp.int32)
    msb = jnp.exp(16.0 * scale * jnp.arange(0, 16, dtype=jnp.float32)).astype(
        jnp.float16
    )
    lsb = jnp.exp(scale * jnp.arange(0, 16, dtype=jnp.float32)).astype(jnp.float16)
    e_lo = (msb[mi] * lsb[li]).astype(jnp.float16)
    return (e_hi * e_lo).astype(jnp.float16)
