"""Pallas kernel for the bitwidth-split LUT ConSmax unit (paper §IV-A).

This is the *hardware-exact* kernel: it consumes INT8 quantized scores and
reproduces, bit for bit, what the two 16-entry FP16 LUTs + FP16 multiplier
chain of Fig. 4(a) emit. It exists to (1) prove the "lossless" claim on the
exhaustive input grid, and (2) produce golden vectors for the Rust `quant`
module so the three implementations (paper hardware, python model, rust
model) are pinned to identical bits.

TPU note: a 16-entry FP16 table lives in SMEM/VMEM trivially; the gather is
a vectorized table lookup. interpret=True as everywhere (CPU PJRT).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _lut_kernel(q_ref, c_ref, msb_ref, lsb_ref, o_ref):
    """o = fp16( fp16(MSB_LUT[q>>4]) * fp16(LSB_LUT[q&0xF]) * fp16(C) )."""
    q = q_ref[...].astype(jnp.int32)
    mi = (q >> 4) + 8          # signed high nibble -> LUT index 0..15
    li = q & 0xF
    e = (msb_ref[mi] * lsb_ref[li]).astype(jnp.float16)
    o_ref[...] = (e * c_ref[...].astype(jnp.float16)).astype(jnp.float16)


@functools.partial(jax.jit, static_argnames=("scale", "block"))
def lut_consmax_pallas(
    q: jax.Array, c: jax.Array, *, scale: float = 1.0 / 16.0, block: int = 256
) -> jax.Array:
    """Bitwidth-split ConSmax over INT8 codes ``q`` with merged constant ``c``.

    ``q``: int8 tensor of any shape; ``c``: broadcastable fp constant.
    Returns fp16, exactly the hardware datapath result.
    """
    orig_shape = q.shape
    n = q.size
    qf = q.reshape(-1)
    cf = jnp.broadcast_to(c, orig_shape).reshape(-1).astype(jnp.float16)
    pad = (-n) % block
    if pad:
        qf = jnp.pad(qf, (0, pad))
        cf = jnp.pad(cf, (0, pad))
    msb, lsb = ref.lut_tables(scale)

    out = pl.pallas_call(
        _lut_kernel,
        out_shape=jax.ShapeDtypeStruct((qf.size,), jnp.float16),
        grid=(qf.size // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            # the LUTs are tiny and replicated to every program instance
            pl.BlockSpec((16,), lambda i: (0,)),
            pl.BlockSpec((16,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(qf, cf, msb, lsb)
    return out[:n].reshape(orig_shape)
