"""Layer-2: the paper's benchmark GPT model in JAX, with pluggable score
normalizer (softmax | consmax | softermax).

Architecture = the paper's evaluation model (§V-A): a GPT-2-style decoder
with 6 transformer layers, 6 attention heads, embedding size 384, context
256, byte-level vocab (256). ConSmax replaces softmax *inside attention
only*; the LM-head cross-entropy keeps standard softmax, as in the paper.

beta and gamma are learnable per-(layer, head) scalars (§III-A: "the
combination of beta and gamma varies across different self-attention
heads"), initialized from the paper's sweep ranges (beta in [0.5, 2.5],
gamma = 100).

Layers are folded with ``lax.scan`` so the lowered HLO stays compact for
AOT export; per-layer parameters are stacked along a leading L axis.

Everything here is build-time Python: ``aot.py`` lowers the jitted entry
points to HLO text once, and the Rust coordinator owns them afterwards.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import consmax as kernels
from .kernels import ref

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GPTConfig:
    """Model + optimizer hyper-parameters (build-time constants)."""

    vocab: int = 256          # byte-level tokenizer
    ctx: int = 256            # paper: default token length 256
    n_layer: int = 6          # paper: 6 transformer layers
    n_head: int = 6           # paper: 6 self-attention heads
    n_embd: int = 384         # paper: embedding size 384
    normalizer: str = "consmax"   # softmax | consmax | softermax
    beta_init: float = 2.5    # paper Fig 6/7: beta in [0.5, 2.5]
    gamma_init: float = 100.0  # paper: gamma = 100
    # optimizer (GPT-2-small-style AdamW)
    lr_max: float = 1e-3
    lr_min: float = 1e-4
    warmup_steps: int = 100
    total_steps: int = 2000
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    @property
    def head_dim(self) -> int:
        assert self.n_embd % self.n_head == 0
        return self.n_embd // self.n_head


TINY = GPTConfig(ctx=64, n_layer=2, n_head=2, n_embd=64,
                 warmup_steps=10, total_steps=200)
PAPER = GPTConfig()


def config_by_name(name: str, **overrides) -> GPTConfig:
    base = {"tiny": TINY, "paper": PAPER}[name]
    return dataclasses.replace(base, **overrides) if overrides else base


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def init_params(cfg: GPTConfig, key: jax.Array) -> Params:
    """GPT-2 initialization: N(0, 0.02), residual projections scaled by
    1/sqrt(2L), LM head tied to the token embedding."""
    k = iter(jax.random.split(key, 16))
    d, h, l = cfg.n_embd, cfg.n_head, cfg.n_layer
    std = 0.02
    rstd = std / jnp.sqrt(2.0 * l)

    def norm(kk, shape, s=std):
        return (jax.random.normal(kk, shape) * s).astype(jnp.float32)

    # beta initialized uniformly over the paper's sweep range so different
    # heads start at different points (Fig 7 traces several starts).
    beta = jax.random.uniform(
        next(k), (l, h), minval=0.5, maxval=cfg.beta_init
    ).astype(jnp.float32)
    gamma = jnp.full((l, h), cfg.gamma_init, dtype=jnp.float32)

    return {
        "wte": norm(next(k), (cfg.vocab, d)),
        "wpe": norm(next(k), (cfg.ctx, d)),
        # stacked per-layer blocks (leading axis L) for lax.scan
        "ln1_g": jnp.ones((l, d)), "ln1_b": jnp.zeros((l, d)),
        "attn_qkv_w": norm(next(k), (l, d, 3 * d)),
        "attn_qkv_b": jnp.zeros((l, 3 * d)),
        "attn_proj_w": norm(next(k), (l, d, d), rstd),
        "attn_proj_b": jnp.zeros((l, d)),
        "beta": beta,
        "gamma": gamma,
        "ln2_g": jnp.ones((l, d)), "ln2_b": jnp.zeros((l, d)),
        "mlp_fc_w": norm(next(k), (l, d, 4 * d)),
        "mlp_fc_b": jnp.zeros((l, 4 * d)),
        "mlp_proj_w": norm(next(k), (l, 4 * d, d), rstd),
        "mlp_proj_b": jnp.zeros((l, d)),
        "lnf_g": jnp.ones((d,)), "lnf_b": jnp.zeros((d,)),
    }


def param_order(cfg: GPTConfig) -> list[str]:
    """Canonical flattening order shared with the Rust coordinator."""
    del cfg
    return [
        "wte", "wpe",
        "ln1_g", "ln1_b", "attn_qkv_w", "attn_qkv_b",
        "attn_proj_w", "attn_proj_b", "beta", "gamma",
        "ln2_g", "ln2_b", "mlp_fc_w", "mlp_fc_b",
        "mlp_proj_w", "mlp_proj_b", "lnf_g", "lnf_b",
    ]


def flatten_params(cfg: GPTConfig, params: Params) -> list[jax.Array]:
    return [params[n] for n in param_order(cfg)]


def unflatten_params(cfg: GPTConfig, leaves: list[jax.Array]) -> Params:
    return dict(zip(param_order(cfg), leaves))


def decayed_mask(cfg: GPTConfig, params: Params) -> Params:
    """AdamW weight-decay mask: decay matrices only - never layernorm,
    biases, embeddings' positional table, or the normalizer params
    beta/gamma (decaying those would fight the paper's convergence)."""
    decay = {"attn_qkv_w", "attn_proj_w", "mlp_fc_w", "mlp_proj_w", "wte"}
    return {n: jnp.float32(1.0 if n in decay else 0.0) * jnp.ones(())
            for n in params}


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def normalize_scores(
    cfg: GPTConfig,
    scores: jax.Array,            # (B, H, T, T), causal mask already applied
    beta: jax.Array,              # (H,)
    gamma: jax.Array,             # (H,)
    *,
    use_pallas: bool = False,
    quantized: bool = False,
) -> jax.Array:
    """Dispatch to the configured score normalizer.

    ``use_pallas=True`` routes through the L1 Pallas kernels (inference /
    AOT-export paths); the plain-jnp form is used inside the differentiable
    training step (interpret-mode pallas_call does not define a VJP).
    Both are validated against each other in python/tests.

    ``quantized=True`` (consmax only) runs the *deployment* datapath: INT8
    score quantization + the bitwidth-split LUT unit, exactly as the
    Fig 4(a) hardware computes it. Masked (-inf) scores saturate to the
    most negative code, so their probability is forced to exact zero
    afterwards by the caller's mask gate.
    """
    if quantized:
        if cfg.normalizer != "consmax":
            raise ValueError("quantized deployment path is consmax-only")
        from .kernels import quant_attn
        b = beta[None, :, None, None]
        g = gamma[None, :, None, None]
        c = ref.merge_beta_gamma(b, g)
        finite = jnp.isfinite(scores)
        q = jnp.where(finite, scores, 0.0)
        probs = quant_attn.quant_consmax_pallas(q, c).astype(scores.dtype)
        return jnp.where(finite, probs, 0.0)
    if cfg.normalizer == "softmax":
        if use_pallas:
            return kernels.softmax_pallas(scores)
        return ref.softmax_ref(scores)
    if cfg.normalizer == "softermax":
        if use_pallas:
            return kernels.softermax_pallas(scores)
        return ref.softermax_ref(scores)
    if cfg.normalizer == "consmax":
        b = beta[None, :, None, None]
        g = gamma[None, :, None, None]
        if use_pallas:
            c = ref.merge_beta_gamma(b, g)
            return kernels.consmax_pallas(scores, c)
        return ref.consmax_ref(scores, b, g)
    raise ValueError(f"unknown normalizer {cfg.normalizer!r}")


def attention(cfg: GPTConfig, x, lp, *, use_pallas=False, quantized=False):
    """One multi-head causal self-attention block (pre-LN)."""
    bsz, t, d = x.shape
    h, hd = cfg.n_head, cfg.head_dim
    xn = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
    qkv = xn @ lp["attn_qkv_w"] + lp["attn_qkv_b"]
    q, kk, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(bsz, t, h, hd).transpose(0, 2, 1, 3)
    kk = kk.reshape(bsz, t, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(bsz, t, h, hd).transpose(0, 2, 1, 3)

    scores = (q @ kk.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    # -inf masking works for every normalizer here: exp(-inf)=0 (consmax),
    # and softmax/softermax subtract the max first.
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    # consmax: exp(-inf - beta) = 0 exactly, but -inf * 0 NaN-guards below
    # are unnecessary since exp is applied directly.
    probs = normalize_scores(cfg, scores, lp["beta"], lp["gamma"],
                             use_pallas=use_pallas, quantized=quantized)
    y = (probs @ v).transpose(0, 2, 1, 3).reshape(bsz, t, d)
    return y @ lp["attn_proj_w"] + lp["attn_proj_b"]


def mlp(x, lp):
    hcur = x @ lp["mlp_fc_w"] + lp["mlp_fc_b"]
    hcur = jax.nn.gelu(hcur)
    return hcur @ lp["mlp_proj_w"] + lp["mlp_proj_b"]


_LAYER_KEYS = [
    "ln1_g", "ln1_b", "attn_qkv_w", "attn_qkv_b", "attn_proj_w",
    "attn_proj_b", "beta", "gamma", "ln2_g", "ln2_b",
    "mlp_fc_w", "mlp_fc_b", "mlp_proj_w", "mlp_proj_b",
]


def forward(cfg: GPTConfig, params: Params, tokens: jax.Array,
            *, use_pallas: bool = False, quantized: bool = False) -> jax.Array:
    """Token ids (B, T) -> logits (B, T, vocab). T must be <= cfg.ctx."""
    bsz, t = tokens.shape
    x = params["wte"][tokens] + params["wpe"][:t][None]

    stacked = {k: params[k] for k in _LAYER_KEYS}

    def body(carry, lp):
        y = carry
        y = y + attention(cfg, y, lp, use_pallas=use_pallas,
                          quantized=quantized)
        yn = layer_norm(y, lp["ln2_g"], lp["ln2_b"])
        y = y + mlp(yn, lp)
        return y, None

    if use_pallas or quantized:
        # pallas_call inside lax.scan lowers fine, but unrolling keeps the
        # interpret-mode callback count low; layer count is small (<=6).
        x2 = x
        for i in range(cfg.n_layer):
            lp = {k: stacked[k][i] for k in _LAYER_KEYS}
            x2, _ = body(x2, lp)
        x = x2
    else:
        x, _ = jax.lax.scan(body, x, stacked)

    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["wte"].T          # tied LM head


def loss_fn(cfg: GPTConfig, params: Params, x: jax.Array, y: jax.Array,
            *, use_pallas: bool = False, quantized: bool = False) -> jax.Array:
    """Mean next-token cross-entropy. x, y: (B, T) int32, y = x shifted."""
    logits = forward(cfg, params, x, use_pallas=use_pallas, quantized=quantized)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Optimizer: fused AdamW with warmup-cosine schedule and global-norm clip
# ---------------------------------------------------------------------------

def lr_schedule(cfg: GPTConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr_max * (step + 1.0) / float(cfg.warmup_steps)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / float(max(1, cfg.total_steps - cfg.warmup_steps)),
        0.0, 1.0,
    )
    cos = cfg.lr_min + 0.5 * (cfg.lr_max - cfg.lr_min) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def train_step(cfg: GPTConfig, params: Params, m: Params, v: Params,
               step: jax.Array, x: jax.Array, y: jax.Array):
    """One fused fwd+bwd+AdamW update. Everything in one HLO executable so
    the Rust hot loop makes a single PJRT execute() per step."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, x, y))(params)

    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-6))
    grads = {k: g * clip for k, g in grads.items()}

    lr = lr_schedule(cfg, step)
    t = step + 1.0
    bc1 = 1.0 - cfg.beta1 ** t
    bc2 = 1.0 - cfg.beta2 ** t
    decay = {"attn_qkv_w", "attn_proj_w", "mlp_fc_w", "mlp_proj_w", "wte"}

    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        m2 = cfg.beta1 * m[k] + (1 - cfg.beta1) * g
        v2 = cfg.beta2 * v[k] + (1 - cfg.beta2) * (g * g)
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        wd = cfg.weight_decay if k in decay else 0.0
        new_p[k] = params[k] - lr * (upd + wd * params[k])
        new_m[k] = m2
        new_v[k] = v2
    return new_p, new_m, new_v, loss, gnorm


def eval_step(cfg: GPTConfig, params: Params, x: jax.Array, y: jax.Array):
    return loss_fn(cfg, params, x, y)


def eval_step_quant(cfg: GPTConfig, params: Params, x: jax.Array, y: jax.Array):
    """Deployment-form evaluation: the trained float model scored with the
    INT8 bitwidth-split ConSmax hardware datapath in every attention block
    (the accuracy a Fig 4(b) accelerator would actually deliver)."""
    return loss_fn(cfg, params, x, y, quantized=True)


# ---------------------------------------------------------------------------
# KV-cached single-token decode (the serving hot path)
# ---------------------------------------------------------------------------

def decode_step(cfg: GPTConfig, params: Params,
                kc: jax.Array, vc: jax.Array,
                pos: jax.Array, token: jax.Array):
    """One autoregressive step with a KV cache.

    kc, vc: (L, B, H, ctx, hd) caches; pos: scalar int32 write index;
    token: (B,) int32. Returns (logits (B, vocab), kc', vc').

    The ConSmax advantage is concrete here: probabilities for the cached
    positions need no row-wide max/sum, so masking is a pure elementwise
    multiply by (index <= pos) - the synchronization-free form the
    accelerator of Fig. 4(b) exploits.
    """
    bsz = token.shape[0]
    d, h, hd = cfg.n_embd, cfg.n_head, cfg.head_dim
    x = params["wte"][token] + params["wpe"][pos][None]     # (B, d)

    valid = (jnp.arange(cfg.ctx) <= pos)                    # (ctx,)

    new_kc, new_vc = [], []
    for i in range(cfg.n_layer):
        lp = {k: params[k][i] for k in _LAYER_KEYS}
        xn = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
        qkv = xn @ lp["attn_qkv_w"] + lp["attn_qkv_b"]
        q, kk, vv = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(bsz, h, hd)
        kk = kk.reshape(bsz, h, hd)
        vv = vv.reshape(bsz, h, hd)
        kci = jax.lax.dynamic_update_slice_in_dim(
            kc[i], kk[:, :, None, :], pos, axis=2)
        vci = jax.lax.dynamic_update_slice_in_dim(
            vc[i], vv[:, :, None, :], pos, axis=2)
        new_kc.append(kci)
        new_vc.append(vci)

        scores = jnp.einsum("bhd,bhtd->bht", q, kci) / jnp.sqrt(jnp.float32(hd))
        if cfg.normalizer == "consmax":
            c = ref.merge_beta_gamma(lp["beta"], lp["gamma"])  # (H,)
            probs = c[None, :, None] * jnp.exp(scores) * valid[None, None, :]
        elif cfg.normalizer == "softermax":
            smask = jnp.where(valid[None, None, :], scores, -jnp.inf)
            probs = ref.softermax_ref(smask)
        else:
            smask = jnp.where(valid[None, None, :], scores, -jnp.inf)
            probs = ref.softmax_ref(smask)
        y = jnp.einsum("bht,bhtd->bhd", probs, vci).reshape(bsz, d)
        x = x + y @ lp["attn_proj_w"] + lp["attn_proj_b"]
        xn2 = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + mlp(xn2, lp)

    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["wte"].T
    return logits, jnp.stack(new_kc), jnp.stack(new_vc)


def init_kv_cache(cfg: GPTConfig, batch: int):
    shape = (cfg.n_layer, batch, cfg.n_head, cfg.ctx, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)
