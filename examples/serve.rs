//! Serving demo: continuous-batching generation behind a request queue,
//! with Poisson arrivals and honest per-request latency/throughput
//! reporting — the coordinator's "inference service" face.
//!
//! Runs on the **native KV-cached decode engine**, so it works from a
//! bare checkout: no Python, no PJRT, no artifacts. (The PJRT serving
//! path is reachable through `consmax serve-demo --backend pjrt`.)
//!
//! Two schedulers (DESIGN.md §Serving seam):
//!
//! * `continuous` (default) — requests join a persistent decode-session
//!   slot pool mid-flight and free their slot the step they finish; a
//!   2-token request never waits for a 64-token neighbor, and reported
//!   latency/TTFT are per request, not per batch.
//! * `static` — the vLLM-v0-style reference batcher (pop a batch, drain
//!   it); greedy outputs are identical, scheduling is not.
//!
//! Run: `cargo run --release --example serve -- [requests] [max_new] [ckpt] [decode] [threads] [sched] [kv_mem_mb] [kv_dtype] [max_batch] [prefill_chunk] [spec_k]`
//! where `decode` is `kv` (default) or `recompute` (the O(T²) oracle;
//! forces the static scheduler) and `threads` sizes the native worker
//! pool. `kv_mem_mb`/`kv_dtype` switch the continuous scheduler onto
//! the paged KV-cache pool (block tables, prefix sharing, byte-budget
//! admission — DESIGN.md §KV-memory seam); `max_batch` caps the slot
//! pool; `prefill_chunk` turns on chunked prefill and `spec_k` turns on
//! self-speculative decoding with a tiny self-draft proposing K tokens
//! per verify step (DESIGN.md §Speculation-and-chunking seam). Uses
//! runs/tiny_consmax.ckpt if present, otherwise serves from random
//! weights (still exercises the full path). `--help` prints this usage.

use anyhow::Result;
use consmax::config::{KvCacheConfig, KvDtype, ModelConfig, QuantMode};
use consmax::coordinator::{
    DecodeMode, GenRequest, Generator, ParamStore, Server, SpecConfig,
};
use consmax::runtime::backend::NativeModel;
use consmax::runtime::parallel;
use consmax::util::rng::Pcg32;

const USAGE: &str = "\
usage: serve [requests] [max_new] [ckpt] [decode] [threads] [sched] [kv_mem_mb] [kv_dtype] [max_batch] [prefill_chunk] [spec_k]

  requests   number of Poisson-arrival requests        (default 24)
  max_new    token budget of the *long* requests; the
             short ones get a quarter of it            (default 24)
  ckpt       checkpoint path                           (default runs/tiny_consmax.ckpt)
  decode     kv | recompute                            (default kv)
  threads    native worker-pool size; rows of a batch
             decode in parallel                        (default: CONSMAX_THREADS
                                                        env var, else all cores)
  sched      continuous | static                       (default continuous;
                                                        recompute forces static)
  kv_mem_mb  paged KV byte budget in MiB; 0 = paged
             without a cap; '-' = dense layout         (default '-')
  kv_dtype   f32 | f16 | bf16 KV storage (paged only)  (default f32)
  max_batch  serving slot cap; paged pools may raise
             it past the dense engine cap              (default: engine max)
  prefill_chunk
             chunked prefill: feed at most N prompt
             tokens per tick; '-' = monolithic         (default '-')
  spec_k     self-speculative decoding: a tiny
             self-draft proposes K greedy tokens per
             batched verify step; '-' = off. Greedy
             outputs stay bit-identical                (default '-')
";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        print!("{USAGE}");
        return Ok(());
    }
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let max_new: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(24);
    let ckpt = args
        .get(3)
        .cloned()
        .unwrap_or_else(|| "runs/tiny_consmax.ckpt".into());
    let mode = DecodeMode::parse(args.get(4).map(String::as_str).unwrap_or("kv"))?;
    if let Some(raw) = args.get(5) {
        match raw.parse::<usize>() {
            Ok(n) if n >= 1 => parallel::set_threads(n),
            _ => {
                eprintln!("error: threads must be an integer >= 1, got {raw:?}\n");
                eprint!("{USAGE}");
                std::process::exit(2);
            }
        }
    }
    let sched = args.get(6).map(String::as_str).unwrap_or("continuous");
    let continuous = match sched {
        "continuous" => mode == DecodeMode::Kv,
        "static" => false,
        other => {
            eprintln!("error: unknown scheduler {other:?}\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    if sched == "continuous" && !continuous {
        println!("note: recompute decode has no persistent session; using the static scheduler");
    }

    let cfg = ModelConfig::builtin("tiny", "consmax")?;
    let store = if std::path::Path::new(&ckpt).exists() {
        println!("loading checkpoint {ckpt}");
        ParamStore::load(std::path::Path::new(&ckpt), &cfg)?
    } else {
        println!("no checkpoint at {ckpt}; serving random weights");
        ParamStore::init(&cfg, 0)?
    };

    // optional paged-KV knobs: [kv_mem_mb] [kv_dtype] [max_batch]
    let kv = match args.get(7).map(String::as_str) {
        None | Some("-") => match args.get(8) {
            // a dtype alone still opts into paging (budgetless pool)
            Some(d) if d != "-" => Some(KvCacheConfig {
                dtype: KvDtype::parse(d)?,
                ..KvCacheConfig::default()
            }),
            _ => None,
        },
        Some(raw) => {
            let mb: usize = raw.parse().map_err(|_| {
                anyhow::anyhow!("kv_mem_mb must be an integer or '-', got {raw:?}")
            })?;
            let mut kv = KvCacheConfig::default();
            if let Some(d) = args.get(8).filter(|d| d.as_str() != "-") {
                kv.dtype = KvDtype::parse(d)?;
            }
            if mb > 0 {
                kv = kv.with_mem_mb(mb);
            }
            Some(kv)
        }
    };

    let generator = Generator::native_with(&cfg, &store, 7, mode)?;
    println!(
        "model {}: ctx {}, {} decode, {} scheduler, slots up to {}, {} threads",
        cfg.key,
        cfg.ctx,
        generator.decode_name(),
        if continuous { "continuous" } else { "static" },
        generator.max_batch(),
        parallel::current_threads()
    );
    let mut server = Server::new(generator);
    if let Some(kv) = kv {
        if continuous {
            server.set_kv_config(Some(kv))?;
            println!(
                "paged KV pool: dtype {}, {} tokens/block{}",
                kv.dtype.name(),
                kv.block_tokens,
                kv.mem_bytes
                    .map(|b| format!(", budget {} MiB", b / (1024 * 1024)))
                    .unwrap_or_default()
            );
        } else {
            println!(
                "note: kv knobs back the continuous scheduler's paged \
                 pool; this static run keeps the dense KV layout"
            );
        }
    }
    if let Some(raw) = args.get(9).filter(|r| r.as_str() != "-") {
        let mb: usize = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("max_batch must be an integer"))?;
        server.set_max_batch(mb)?;
    }
    if let Some(raw) = args.get(10).filter(|r| r.as_str() != "-") {
        let c: usize = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("prefill_chunk must be an integer or '-'"))?;
        server.set_prefill_chunk(Some(c))?;
        println!("chunked prefill: at most {c} prompt tokens per tick");
    }
    if let Some(raw) = args.get(11).filter(|r| r.as_str() != "-") {
        let k: usize = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("spec_k must be an integer or '-'"))?;
        // the tiny target drafts for itself: same weights, so greedy
        // rows accept every proposal — the upper bound of the technique
        let draft = NativeModel::from_params_quant(
            &cfg,
            &store.order,
            &store.params,
            QuantMode::Off,
        )?;
        server.set_spec(Some((SpecConfig { draft_k: k }, draft)))?;
        println!("self-speculative decoding: tiny self-draft, draft-k={k}");
    }
    println!();

    // Poisson arrival schedule: randomized prompt mix and a short/long
    // budget mix (3 short : 1 long) — the workload where static
    // batching head-of-line blocks and continuous batching does not
    let mut rng = Pcg32::seeded(0);
    let prompts = [
        "The transformer architecture ",
        "Attention lets every token ",
        "Computing softmax requires ",
        "The constant softmax replaces ",
        "A small lookup table stores ",
        "Long contexts make ",
    ];
    let mut t_arrive = 0.0f64;
    let mut schedule = Vec::new();
    for id in 0..n_requests as u64 {
        t_arrive += rng.exponential(20.0); // ~20 req/s offered load
        schedule.push((t_arrive, GenRequest {
            id,
            prompt: prompts[rng.below(prompts.len() as u64) as usize].into(),
            max_new_tokens: if id % 4 == 0 { max_new } else { max_new / 4 + 1 },
            // mixed sampling policies in one batch: the server keeps
            // each request's own temperature
            temperature: if id % 3 == 0 { 0.0 } else { 0.8 },
            stop: None,
            deadline_ms: None,
        }));
    }

    let t0 = std::time::Instant::now();
    let mut responses = Vec::new();
    let mut next = 0;
    // event loop: admit arrivals whose time has come, then advance the
    // scheduler (one slot-pool tick, or one full static batch)
    while responses.len() < n_requests {
        let now = t0.elapsed().as_secs_f64();
        while next < schedule.len() && schedule[next].0 <= now {
            server.submit(schedule[next].1.clone());
            next += 1;
        }
        let idle = server.pending() == 0
            && (!continuous || server.in_flight() == 0);
        if idle {
            std::thread::sleep(std::time::Duration::from_millis(1));
            continue;
        }
        let completed = if continuous { server.step()? } else { server.run_once()? };
        for r in completed {
            let accept = if r.spec_proposed > 0 {
                format!(
                    ", accept {:3.0}%",
                    100.0 * r.spec_accepted as f64 / r.spec_proposed as f64
                )
            } else {
                String::new()
            };
            println!(
                "[lat {:7.1} ms, ttft {:6.1} ms] req {:2} ({} co-resident, \
                 {} prompt toks, {} new{accept}): {:?}",
                r.latency_ms, r.ttft_ms, r.id, r.batch_size, r.prompt_tokens,
                r.new_tokens, r.text
            );
            responses.push(r);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== serving report ===");
    println!("requests:   {n_requests} in {wall:.2}s ({:.1} req/s)", n_requests as f64 / wall);
    println!("throughput: {:.1} tok/s", server.tokens_out as f64 / wall);
    println!(
        "completion: p50 {:.0} ms  p95 {:.0} ms  mean {:.0} ms (per request, from submit)",
        server.latencies.percentile(50.0).unwrap() / 1e3,
        server.latencies.percentile(95.0).unwrap() / 1e3,
        server.latencies.mean().unwrap() / 1e3
    );
    println!(
        "TTFT:       p50 {:.0} ms  p99 {:.0} ms   TPOT: p50 {:.2} ms/tok",
        server.ttft.percentile(50.0).unwrap() / 1e3,
        server.ttft.percentile(99.0).unwrap() / 1e3,
        server.tpot.percentile(50.0).unwrap_or(0.0) / 1e3
    );
    let batched = responses.iter().filter(|r| r.batch_size > 1).count();
    println!(
        "batching:   {batched}/{n_requests} responses shared the engine with a neighbor"
    );
    let st = server.stats();
    if st.kv_paged {
        println!(
            "paged KV:   {} blocks x {} tokens, {} free at drain, {} preemption(s)",
            st.kv_total_blocks, st.kv_block_tokens, st.kv_free_blocks, st.preemptions
        );
    }
    if server.prefill_chunk().is_some() || server.spec_config().is_some() {
        let acc = if st.spec_proposed > 0 {
            format!(
                "{:.1}%",
                100.0 * st.spec_accepted as f64 / st.spec_proposed as f64
            )
        } else {
            "n/a".to_string()
        };
        println!(
            "speculation: {} proposed, {} accepted (acceptance {acc}); \
             {} prefill-chunk feeds vs {} decode steps",
            st.spec_proposed, st.spec_accepted,
            st.prefill_chunk_steps, st.decode_steps
        );
    }
    Ok(())
}
