//! Fig 5 reproduction: simulate the attention accelerator under the
//! token-pipeline (Fig 2) and element-wise (Fig 4b) schedules, render the
//! module timelines, and sweep context length to show the widening gap.
//!
//! Run: `cargo run --example pipeline_sim`

use consmax::sim::pipeline::fig5_time_saving;
use consmax::sim::{simulate, NormKind, Schedule, SimResult, Workload};
use consmax::util::bench::print_table;

/// ASCII timeline: one row per module, '#' = busy.
fn render_timeline(r: &SimResult, width: usize) {
    let scale = r.total_cycles as f64 / width as f64;
    for (name, m) in [("QK  ", &r.qk), ("Norm", &r.norm_unit), ("PV  ", &r.pv)] {
        let mut line = vec![' '; width];
        for &(s, e) in &m.segments {
            let a = (s as f64 / scale) as usize;
            let b = ((e as f64 / scale) as usize).min(width - 1);
            for c in line.iter_mut().take(b + 1).skip(a) {
                *c = '#';
            }
        }
        println!("  {name} |{}|", line.iter().collect::<String>());
    }
}

fn main() {
    // ---------------- single-token generation (the Fig 5 case) ---------
    let seq = 256;
    let w = Workload::paper_generation(seq);
    println!("generation stage, context {seq}, head_dim {}\n", w.head_dim);

    let base = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
    println!(
        "Softmax / token pipeline — {} cycles, utilization {:.0}%",
        base.total_cycles,
        base.utilization() * 100.0
    );
    render_timeline(&base, 72);

    let soft = simulate(&w, NormKind::Softermax, Schedule::TokenPipeline);
    println!(
        "\nSoftermax / token pipeline — {} cycles, utilization {:.0}%",
        soft.total_cycles,
        soft.utilization() * 100.0
    );
    render_timeline(&soft, 72);

    let cons = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
    println!(
        "\nConSmax / element-wise pipeline — {} cycles, utilization {:.0}%",
        cons.total_cycles,
        cons.utilization() * 100.0
    );
    render_timeline(&cons, 72);

    println!(
        "\nConSmax time saving vs Softmax: {:.1}%  (speedup {:.2}x)",
        (1.0 - cons.total_cycles as f64 / base.total_cycles as f64) * 100.0,
        cons.speedup_over(&base)
    );

    // ---------------- context-length sweep -----------------------------
    let mut rows = Vec::new();
    for seq in [256usize, 512, 1024, 2048, 4096, 8192] {
        let (base, cons, saving) = fig5_time_saving(seq);
        let soft = simulate(
            &Workload::paper_generation(seq),
            NormKind::Softermax,
            Schedule::TokenPipeline,
        );
        let part = simulate(
            &Workload::paper_generation(seq),
            NormKind::PartialSoftmax { chunks: 8 },
            Schedule::TokenPipeline,
        );
        rows.push(vec![
            seq.to_string(),
            base.total_cycles.to_string(),
            soft.total_cycles.to_string(),
            part.total_cycles.to_string(),
            cons.total_cycles.to_string(),
            format!("{:.1}%", saving * 100.0),
            format!("{:.0}%", cons.utilization() * 100.0),
        ]);
    }
    print_table(
        "Fig 5 sweep: generation latency (cycles) by normalizer; \
         ConSmax element-wise keeps all modules busy at any context",
        &["seq", "Softmax", "Softermax", "Partial/8", "ConSmax", "saving", "util"],
        &rows,
    );

    // ---------------- summarization (multi-token) ----------------------
    let mut rows = Vec::new();
    for tokens in [1usize, 4, 16, 64] {
        let w = Workload::summarization(tokens, 256);
        let sm = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
        let cs = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
        rows.push(vec![
            tokens.to_string(),
            format!("{}", sm.total_cycles),
            format!("{}", cs.total_cycles),
            format!("{:.2}x", cs.speedup_over(&sm)),
        ]);
    }
    print_table(
        "Summarization: the token pipeline amortizes across tokens but never \
         catches the element-wise schedule",
        &["tokens", "Softmax cycles", "ConSmax cycles", "speedup"],
        &rows,
    );
}
