//! End-to-end driver (the DESIGN.md §validation run): train the paper's
//! GPT benchmark model (6L/6H/384, ~10.8M params) with ConSmax AND with
//! Softmax on identical data through the full three-layer stack — Pallas
//! kernels lowered into JAX HLO, executed by the Rust coordinator via
//! PJRT — and print the Fig 6-style loss/perplexity trajectory.
//!
//! Run: `cargo run --release --example train_gpt -- [steps] [config]`
//!   steps  — training steps per normalizer (default 120)
//!   config — tiny|paper (default paper)
//!
//! The full log lands in runs/<key>_train_gpt.jsonl; EXPERIMENTS.md §Fig6
//! records a 300-step run.

use anyhow::Result;
use consmax::coordinator::{ParamStore, TrainOptions, Trainer};
use consmax::data::{BatchSampler, ByteTokenizer, Corpus};
use consmax::metrics::perplexity;
use consmax::runtime::Engine;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let config = args.get(2).cloned().unwrap_or_else(|| "paper".into());

    let engine = Engine::new("artifacts")?;
    println!("platform: {}", engine.platform());

    let corpus = Corpus::synthetic(200_000, 0);
    let (train_text, val_text) = corpus.split();
    let tok = ByteTokenizer;
    println!(
        "corpus: {} ({} bytes, {} train / {} val)\n",
        corpus.name,
        corpus.len_bytes(),
        train_text.len(),
        val_text.len()
    );

    let mut summary = Vec::new();
    for norm in ["softmax", "consmax"] {
        let key = format!("{config}_{norm}");
        let cfg = engine.manifest.config(&key)?.clone();
        let store = ParamStore::init(&cfg, 0)?;
        println!(
            "=== {key}: {}L/{}H/{}d ctx {} — {} params ===",
            cfg.n_layer,
            cfg.n_head,
            cfg.n_embd,
            cfg.ctx,
            store.param_count()
        );
        let train = BatchSampler::new(
            tok.encode(train_text),
            cfg.train_batch,
            cfg.ctx,
            0,
        );
        let val =
            BatchSampler::new(tok.encode(val_text), cfg.train_batch, cfg.ctx, 0);
        let mut tr = Trainer::new(&engine, &key, store, train, Some(val))?;
        let report = tr.train(&TrainOptions {
            steps,
            log_every: (steps / 20).max(1),
            eval_every: (steps / 4).max(1),
            eval_batches: 4,
            trace_params: norm == "consmax",
            checkpoint: Some(format!("runs/{key}.ckpt").into()),
        })?;

        // print the trajectory
        let series = tr.metrics.get("train_loss").unwrap();
        println!("\n step    loss    ppl");
        for &(s, l) in &series.points {
            println!("{s:5}  {l:6.3}  {:7.1}", perplexity(l));
        }
        if norm == "consmax" {
            // Fig 7 flavour: where did beta/gamma end up?
            let b = tr.metrics.get("beta_l0h0").unwrap();
            let g = tr.metrics.get("gamma_l0h0").unwrap();
            println!(
                "\nbeta[l0h0]: {:.3} -> {:.3};  gamma[l0h0]: {:.2} -> {:.2}",
                b.points[0].1,
                b.points.last().unwrap().1,
                g.points[0].1,
                g.points.last().unwrap().1
            );
        }
        let val_loss = tr.evaluate(4)?;
        println!(
            "\n{norm}: final train loss {:.4}, val loss {:.4} (ppl {:.1}), \
             {:.2} steps/s\n",
            report.final_loss,
            val_loss,
            perplexity(val_loss),
            report.steps_per_s
        );
        tr.metrics
            .save(format!("runs/{key}_train_gpt.jsonl"))?;
        summary.push((norm, report.final_loss, val_loss));
    }

    println!("=== Fig 6 summary (identical data, seed, schedule) ===");
    for (norm, train, val) in &summary {
        println!(
            "{norm:10} train {train:.4}  val {val:.4} (ppl {:.1})",
            perplexity(*val)
        );
    }
    if summary.len() == 2 {
        let gap = (summary[1].2 - summary[0].2) / summary[0].2 * 100.0;
        println!(
            "\nConSmax val-loss gap vs Softmax: {gap:+.2}% \
             (paper: +2.3% early, <0.9% @10K iters, parity at convergence)"
        );
    }
    Ok(())
}
