//! Full hardware report: Table I side by side with the paper's published
//! numbers, the abstract's savings ratios, the Fig 9 area breakdown and
//! the Fig 10 optimum-energy points — all from the synthesis estimator
//! (DESIGN.md §2 documents the EDA-flow substitution).
//!
//! Run: `cargo run --example hw_report`

use consmax::hw::report::{area_vs_seq, paper_table1_reference, power_test_freq};
use consmax::hw::{fig10, fig9, savings, table1, EdaFlow, TechNode};
use consmax::util::bench::print_table;

fn main() {
    // ---------------- Table I ------------------------------------------
    for flow in [EdaFlow::Proprietary, EdaFlow::OpenSource] {
        let rows = table1(flow, 256);
        let refs = paper_table1_reference();
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                let node = if r.corner.starts_with("16nm") { "16nm" } else { "130nm" };
                let paper = refs
                    .iter()
                    .find(|(d, n, _)| *d == r.design && *n == node)
                    .map(|(_, _, v)| *v);
                let fmt_ref = |i: usize| {
                    paper
                        .map(|v| format!("{}", v[i]))
                        .unwrap_or_else(|| "-".into())
                };
                vec![
                    r.design.clone(),
                    r.corner.clone(),
                    format!("{:.0}", r.fmax_mhz),
                    fmt_ref(0),
                    format!("{:.5}", r.area_mm2),
                    fmt_ref(1),
                    format!("{:.2}", r.power_mw),
                    fmt_ref(2),
                    format!("{:.2}", r.opt_energy_pj),
                    fmt_ref(3),
                ]
            })
            .collect();
        print_table(
            &format!(
                "Table I ({flow:?} flow; power at {:.0}/{:.0} MHz; \
                 'paper' columns = proprietary-EDA reference)",
                power_test_freq(TechNode::Fin16),
                power_test_freq(TechNode::Sky130)
            ),
            &[
                "design", "corner", "Fmax", "paper", "area mm2", "paper",
                "power mW", "paper", "opt pJ", "paper",
            ],
            &table,
        );

        let s_rows: Vec<Vec<String>> = savings(&rows)
            .iter()
            .map(|s| {
                vec![
                    s.corner.clone(),
                    s.vs.clone(),
                    format!("{:.2}x", s.power_ratio),
                    format!("{:.2}x", s.area_ratio),
                ]
            })
            .collect();
        print_table(
            "ConSmax savings (paper 16nm: 3.35x power / 2.75x area vs Softermax; \
             7.5x / 13.75x vs Softmax)",
            &["corner", "vs", "power", "area"],
            &s_rows,
        );
    }

    // ---------------- Fig 9: area breakdown ----------------------------
    let entries = fig9(TechNode::Fin16, 256);
    let mut rows = Vec::new();
    for e in &entries {
        let total: f64 = e.breakdown_um2.iter().map(|(_, v)| v).sum();
        for (class, um2) in &e.breakdown_um2 {
            rows.push(vec![
                e.design.clone(),
                e.flow.clone(),
                class.to_string(),
                format!("{um2:.0}"),
                format!("{:.1}%", um2 / total * 100.0),
            ]);
        }
        rows.push(vec![
            e.design.clone(),
            e.flow.clone(),
            "TOTAL".into(),
            format!("{total:.0}"),
            format!("Fmax {:.0} MHz", e.fmax_mhz),
        ]);
    }
    print_table(
        "Fig 9: 16nm cell-area breakdown by component class + Fmax",
        &["design", "flow", "class", "area um2", "share"],
        &rows,
    );

    // ---------------- Fig 10: energy vs frequency ----------------------
    let series = fig10(TechNode::Fin16, EdaFlow::Proprietary, 256, 12);
    let mut rows = Vec::new();
    for (name, sweep, opt) in &series {
        for p in sweep {
            rows.push(vec![
                name.clone(),
                format!("{:.0}", p.freq_mhz),
                format!("{:.3}", p.voltage),
                format!("{:.3}", p.energy_pj_per_elem),
                format!("{:.3}", p.power_mw),
            ]);
        }
        rows.push(vec![
            name.clone(),
            format!("{:.0}", opt.freq_mhz),
            format!("{:.3}", opt.voltage),
            format!("{:.3}", opt.energy_pj_per_elem),
            "<- optimum".into(),
        ]);
    }
    print_table(
        "Fig 10: energy/op vs frequency, 16nm (paper optima: ConSmax/Softermax \
         at 666 MHz, Softmax at 714 MHz; ConSmax 0.2 pJ)",
        &["design", "MHz", "V", "pJ/elem", "power mW"],
        &rows,
    );

    // ---------------- long-context ablation ----------------------------
    let series = area_vs_seq(TechNode::Fin16, &[256, 512, 1024, 2048, 4096, 8192]);
    let mut rows = Vec::new();
    for (name, pts) in &series {
        for (seq, mm2) in pts {
            rows.push(vec![name.clone(), seq.to_string(), format!("{mm2:.5}")]);
        }
    }
    print_table(
        "Ablation: area vs context length (ConSmax is O(1); buffers grow in \
         the baselines — the paper's §III-A motivation quantified)",
        &["design", "seq", "area mm2"],
        &rows,
    );
}
