//! Network-serving demo: the hardened TCP/HTTP front end exercised by
//! real sockets — a well-behaved streaming client, a client that
//! vanishes mid-stream, a malformed request, a stats probe, and a
//! graceful drain — all in one process.
//!
//! The serve loop runs on the main thread (`serve_net::serve` owns the
//! engine); a driver thread plays the clients against the ephemeral
//! port and then requests the drain. Runs on the native KV-cached
//! decode engine from a bare checkout: no Python, no PJRT, no
//! artifacts, and no checkpoint needed (random weights still exercise
//! the full path).
//!
//! Run: `cargo run --release --example serve_net -- [requests] [max_new]`
//! (defaults 6 and 12). See `consmax serve-net --help` for the
//! production CLI over the same stack.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{Context, Result};
use consmax::config::ModelConfig;
use consmax::coordinator::{EngineAdapter, Generator, ParamStore, Server};
use consmax::runtime::serve_net::{self, FaultPlan, NetOptions};

/// One scripted client: POST /generate, stream the NDJSON response.
/// `hang_up_after` cuts the connection after that many token lines —
/// the mid-stream-disconnect client. Returns (status, tokens seen,
/// reached a terminal line).
fn client(
    addr: &str,
    prompt: &str,
    max_new: usize,
    hang_up_after: Option<usize>,
) -> Result<(u16, usize, bool)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let body = format!(
        "{{\"prompt\":\"{prompt}\",\"max_new\":{max_new}}}"
    );
    write!(
        stream,
        "POST /generate HTTP/1.1\r\nHost: demo\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .context("no status code")?;
    // skip headers
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 || h.trim().is_empty() {
            break;
        }
    }
    if status != 200 {
        return Ok((status, 0, false));
    }
    let mut tokens = 0usize;
    let mut terminal = false;
    loop {
        let mut l = String::new();
        if reader.read_line(&mut l)? == 0 {
            break;
        }
        if l.contains("\"token\"") {
            tokens += 1;
            if hang_up_after.is_some_and(|n| tokens >= n) {
                return Ok((status, tokens, false)); // vanish mid-stream
            }
        } else if l.contains("\"done\"")
            || l.contains("\"timeout\"")
            || l.contains("\"cancelled\"")
        {
            terminal = true;
            break;
        } // heartbeats ({"hb":1}) fall through
    }
    Ok((status, tokens, terminal))
}

/// A deliberately malformed request; returns the status line.
fn malformed_client(addr: &str) -> Result<u16> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    write!(stream, "NONSENSE /nowhere HTTP/1.1\r\n\r\n")?;
    stream.flush()?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line)?;
    Ok(line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0))
}

fn stats_client(addr: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    write!(stream, "GET /stats HTTP/1.1\r\n\r\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut body = String::new();
    loop {
        let mut l = String::new();
        if reader.read_line(&mut l)? == 0 {
            break;
        }
        if l.trim_start().starts_with('{') {
            body = l.trim().to_string();
        }
    }
    Ok(body)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize =
        args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);
    let max_new: usize =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(12);

    let cfg = ModelConfig::builtin("tiny", "consmax")?;
    let ckpt = std::path::Path::new("runs/tiny_consmax.ckpt");
    let store = if ckpt.exists() {
        println!("loading checkpoint {}", ckpt.display());
        ParamStore::load(ckpt, &cfg)?
    } else {
        println!("no checkpoint; serving random weights");
        ParamStore::init(&cfg, 0)?
    };
    let generator = Generator::native(&cfg, &store, 7)?;
    let server = Server::new(generator);
    // bounded admission: shed past 32 queued; no default deadline
    let mut engine = EngineAdapter::new(server, Some(32), None, None)?;

    serve_net::reset_drain();
    let listener = serve_net::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    println!("serving on http://{addr}\n");

    // the clients run against the socket while serve() blocks below
    let client_addr = addr.clone();
    let driver = std::thread::spawn(move || -> Vec<String> {
        let mut out = Vec::new();
        let mut handles = Vec::new();
        for i in 0..n_requests {
            let a = client_addr.clone();
            // client 1 hangs up mid-stream; the rest behave
            let hang = (i == 1).then_some(2);
            handles.push(std::thread::spawn(move || {
                let prompt = format!("The attention mechanism {i} ");
                (i, hang, client(&a, &prompt, max_new, hang))
            }));
        }
        match malformed_client(&client_addr) {
            Ok(code) => out.push(format!("malformed request -> {code}")),
            Err(e) => out.push(format!("malformed request failed: {e:#}")),
        }
        for h in handles {
            let (i, hang, res) = h.join().expect("client thread");
            match res {
                Ok((status, tokens, terminal)) => out.push(format!(
                    "client {i}: status {status}, {tokens} token(s), {}",
                    if terminal {
                        "terminal line seen"
                    } else if hang.is_some() {
                        "hung up mid-stream"
                    } else {
                        "no terminal line"
                    }
                )),
                Err(e) => out.push(format!("client {i} failed: {e:#}")),
            }
        }
        match stats_client(&client_addr) {
            Ok(body) => out.push(format!("stats: {body}")),
            Err(e) => out.push(format!("stats probe failed: {e:#}")),
        }
        serve_net::request_drain();
        out
    });

    let opts = NetOptions {
        queue_cap: 32,
        heartbeat_ms: 250,
        drain_timeout_ms: 5_000,
        ..NetOptions::default()
    };
    let report =
        serve_net::serve(&mut engine, listener, &opts, &FaultPlan::default())?;

    for line in driver.join().expect("driver thread") {
        println!("{line}");
    }
    let server = engine.into_server();
    println!(
        "\ndrained ({}): admitted {} completed {} shed {} rejected {} \
         disconnects {} slow-readers {} over {} ticks",
        if report.drained_clean { "clean" } else { "forced" },
        report.admitted,
        report.completed,
        report.shed,
        report.rejected,
        report.disconnects,
        report.slow_readers,
        report.ticks,
    );
    println!(
        "terminal accounting: {} submitted == {} completed + {} shed + {} \
         timed-out + {} cancelled",
        server.submitted,
        server.completed,
        server.shed,
        server.timed_out,
        server.cancelled,
    );
    assert_eq!(
        server.submitted,
        server.completed + server.shed + server.timed_out + server.cancelled,
        "terminal-state accounting must close"
    );
    Ok(())
}
