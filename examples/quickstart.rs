//! Quickstart: load the AOT ConSmax kernel, run it through PJRT from
//! Rust, and see the paper's two core properties with your own eyes:
//!
//! 1. ConSmax ≈ a score normalizer (orders preserved, small scores
//!    suppressed) *without* computing a max or a sum;
//! 2. every output element depends only on its own input — the
//!    synchronization-freeness that the hardware exploits.
//!
//! Run: `cargo run --example quickstart` (after `make artifacts`).

use anyhow::Result;
use consmax::quant::{merge_beta_gamma, BitSplitLut, Int8Quantizer};
use consmax::runtime::{Engine, HostTensor};

fn main() -> Result<()> {
    let engine = Engine::new("artifacts")?;
    println!("PJRT platform: {}\n", engine.platform());

    // --- 1. run the pallas ConSmax kernel via its AOT artifact ---------
    let (rows, cols) = (64, 256);
    let beta = 1.5f32;
    let gamma = 100.0f32;
    let c = (-beta).exp() / gamma;

    // a score row with one strong match (position 3) and noise elsewhere
    let mut scores = vec![0.0f32; rows * cols];
    for (i, s) in scores.iter_mut().enumerate() {
        *s = ((i % 7) as f32) * 0.3 - 1.0;
    }
    scores[3] = 4.0;

    let out = engine.execute(
        "op_consmax",
        &[
            HostTensor::from_f32(&scores, &[rows, cols]),
            HostTensor::from_f32(&vec![c; rows * cols], &[rows, cols]),
        ],
    )?;
    let probs = out[0].as_f32()?;
    println!("ConSmax(s)[0..8]  = {:?}", &probs[..8]);
    println!(
        "  strong match at [3] -> {:.4} (>> neighbours, no row sum needed)",
        probs[3]
    );

    // --- 2. element independence ----------------------------------------
    let mut scores2 = scores.clone();
    scores2[100] = 9.9; // poke an unrelated element
    let out2 = engine.execute(
        "op_consmax",
        &[
            HostTensor::from_f32(&scores2, &[rows, cols]),
            HostTensor::from_f32(&vec![c; rows * cols], &[rows, cols]),
        ],
    )?;
    let probs2 = out2[0].as_f32()?;
    assert_eq!(probs[3], probs2[3]);
    println!("\nperturbing s[100] leaves ConSmax(s)[3] bit-identical [ok]");

    // softmax, by contrast, couples the whole row:
    let sm = engine.execute(
        "op_softmax",
        &[HostTensor::from_f32(&scores, &[rows, cols])],
    )?[0]
        .as_f32()?;
    let sm2 = engine.execute(
        "op_softmax",
        &[HostTensor::from_f32(&scores2, &[rows, cols])],
    )?[0]
        .as_f32()?;
    assert_ne!(sm[3], sm2[3]);
    println!(
        "softmax(s)[3] changes ({:.5} -> {:.5}) - the barrier ConSmax removes",
        sm[3], sm2[3]
    );

    // --- 3. the hardware path: INT8 + bitwidth-split LUTs ---------------
    let quant = Int8Quantizer::paper();
    let lut = BitSplitLut::paper();
    let chw = merge_beta_gamma(beta, gamma);
    println!("\nINT8 hardware datapath (bit-exact model):");
    for &x in &[-2.0f32, 0.0, 2.0, 4.0] {
        let q = quant.quantize(x);
        let hw = lut.consmax(q, chw).to_f32();
        let sw = (x - beta).exp() / gamma;
        println!("  s={x:+.1}  q={q:+4}  hw={hw:.6}  float={sw:.6}");
    }
    println!(
        "\n(2 x 16-entry fp16 LUTs, {} bits total - not a 256-entry table)",
        BitSplitLut::CAPACITY_BITS
    );
    Ok(())
}
