//! Native training integration suite (DESIGN.md §Training seam):
//! `consmax train --backend native` semantics pinned end-to-end —
//! loss decreases on the in-tree corpus, the whole normalizer zoo
//! trains, Fig 7 β/γ traces are recorded, and checkpoints resume with
//! a continuous step count.

use consmax::config::ModelConfig;
use consmax::coordinator::{NativeTrainer, ParamStore, TrainOptions};
use consmax::data::{BatchSampler, ByteTokenizer, Corpus};

fn trainer(normalizer: &str, seed: u64) -> NativeTrainer {
    let cfg = ModelConfig::builtin("tiny", normalizer).unwrap();
    let corpus = Corpus::tiny();
    let (train_text, val_text) = corpus.split();
    let tok = ByteTokenizer;
    let train =
        BatchSampler::new(tok.encode(train_text), cfg.train_batch, cfg.ctx, seed);
    let val =
        BatchSampler::new(tok.encode(val_text), cfg.train_batch, cfg.ctx, seed);
    let store = ParamStore::init(&cfg, seed).unwrap();
    NativeTrainer::new(cfg, store, train, Some(val))
}

#[test]
fn consmax_loss_decreases_on_the_tiny_corpus() {
    let mut tr = trainer("consmax", 0);
    let opts = TrainOptions {
        steps: 25,
        log_every: 1,
        eval_every: 10,
        eval_batches: 2,
        trace_params: true,
        checkpoint: None,
    };
    let report = tr.train(&opts).unwrap();
    let series = tr.metrics.get("train_loss").unwrap();
    let initial = series.points.first().unwrap().1;
    let final_ = series.points.last().unwrap().1;
    // byte-LM from scratch starts near ln(256) ≈ 5.55 and AdamW moves it
    // fast; 25 steps reliably buys well over 0.1 nats
    assert!(
        final_ < initial - 0.1,
        "loss did not decrease: {initial:.4} -> {final_:.4}"
    );
    assert_eq!(report.final_loss, final_);
    assert!(report.steps_per_s > 0.0);
    // validation was scored mid-run
    assert!(tr.metrics.get("val_loss").is_some());
    assert!(report.best_val_loss.is_some());
}

#[test]
fn every_normalizer_trains_without_diverging() {
    for norm in ["consmax", "softmax", "softermax", "consmax-v2", "ssmax"] {
        let mut tr = trainer(norm, 1);
        let opts = TrainOptions {
            steps: 4,
            log_every: 1,
            eval_every: 0,
            eval_batches: 1,
            trace_params: false,
            checkpoint: None,
        };
        let report = tr.train(&opts).unwrap();
        assert!(report.final_loss.is_finite(), "{norm}");
        let series = tr.metrics.get("train_loss").unwrap();
        assert_eq!(series.points.len(), 4, "{norm}: log_every=1 over 4 steps");
    }
}

#[test]
fn fig7_learnable_traces_are_recorded() {
    let mut tr = trainer("consmax", 2);
    let opts = TrainOptions {
        steps: 3,
        log_every: 1,
        eval_every: 0,
        eval_batches: 1,
        trace_params: true,
        checkpoint: None,
    };
    tr.train(&opts).unwrap();
    // per-(layer, head) series, same naming as the PJRT trainer
    for l in 0..2 {
        for h in 0..2 {
            let beta = tr.metrics.get(&format!("beta_l{l}h{h}")).unwrap();
            let gamma = tr.metrics.get(&format!("gamma_l{l}h{h}")).unwrap();
            assert_eq!(beta.points.len(), 3);
            assert_eq!(gamma.points.len(), 3);
        }
    }
    // β must actually move under training (Fig 7's point); γ's step is
    // tiny at the 100.0 init but the series must exist either way
    let b00 = tr.metrics.get("beta_l0h0").unwrap();
    assert!(b00.points.first().unwrap().1 != b00.points.last().unwrap().1);

    // ssmax records its own learnable scale
    let mut tr = trainer("ssmax", 2);
    tr.train(&opts).unwrap();
    assert!(tr.metrics.get("ssmax_s_l0h0").is_some());
}

#[test]
fn checkpoint_resume_continues_the_step_count() {
    let dir = std::env::temp_dir().join("consmax_train_native_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("resume.ckpt");
    let _ = std::fs::remove_file(&ckpt);

    let mut tr = trainer("consmax", 3);
    let opts = TrainOptions {
        steps: 3,
        log_every: 1,
        eval_every: 0,
        eval_batches: 1,
        trace_params: false,
        checkpoint: Some(ckpt.clone()),
    };
    tr.train(&opts).unwrap();
    assert_eq!(tr.store.step, 3);

    let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
    let store = ParamStore::load(&ckpt, &cfg).unwrap();
    assert_eq!(store.step, 3);
    // moments were persisted (training really warmed them up)
    assert!(store.m.iter().any(|t| t.data.iter().any(|&b| b != 0)));

    let corpus = Corpus::tiny();
    let (train_text, _) = corpus.split();
    let sampler = BatchSampler::new(
        ByteTokenizer.encode(train_text),
        cfg.train_batch,
        cfg.ctx,
        3,
    );
    let mut resumed = NativeTrainer::new(cfg, store, sampler, None);
    let report = resumed
        .train(&TrainOptions { steps: 2, checkpoint: None, ..opts })
        .unwrap();
    assert_eq!(resumed.store.step, 5);
    assert!(report.final_loss.is_finite());
    // metric steps continue where the first run stopped
    let series = resumed.metrics.get("train_loss").unwrap();
    assert_eq!(series.points.first().unwrap().0, 3);
}
