//! Exhaustive bit-faithfulness of the ConSmax LUT serving path
//! (DESIGN.md §Quantization seam).
//!
//! The int8 serving tail computes `C·exp(s)` through the bit-split LUT,
//! and the claim is *bit*-equality, not tolerance: for **every**
//! representable bit-split input — all 256 int8 codes, not a spot-check
//! golden vector — the response table the model serves from, the
//! [`BitSplitLut`] reference, the 3-stage RTL pipeline model, and the
//! [`native::attend_consmax_lut`] kernel must all emit identical fp16
//! bit patterns. (The cross-*language* golden pins stay in
//! `quant_cross_validation.rs`; this suite is the cross-*layer* sweep.)

use consmax::hw::rtl::{ConsmaxUnitSim, SimInput};
use consmax::quant::{merge_beta_gamma, BitSplitLut, Int8Quantizer};
use consmax::runtime::backend::native;
use consmax::util::fp16::F16;

/// Power-of-two LUT scales worth sweeping: the paper's operating point
/// plus one finer and one coarser grid.
const SCALES: [f32; 3] = [1.0 / 16.0, 1.0 / 32.0, 1.0 / 8.0];

/// Merged C = exp(-β)/γ constants spanning the regimes the models hit:
/// the init point (β=2.5, γ=100), a trained-ish point, C == 1, a large
/// C, and a tiny C near fp16 subnormals.
fn c_values() -> Vec<F16> {
    vec![
        merge_beta_gamma(2.5, 100.0),
        merge_beta_gamma(1.5, 100.0),
        merge_beta_gamma(0.0, 1.0),
        merge_beta_gamma(-2.0, 0.25),
        merge_beta_gamma(8.0, 500.0),
    ]
}

/// Every i8 code, in two's-complement table order (index = q as u8).
fn all_codes() -> Vec<i8> {
    (0..=255u8).map(|b| b as i8).collect()
}

#[test]
fn response_table_matches_lut_for_every_code_and_c() {
    // the serving path reads `response_table(c)`; the reference is the
    // per-code LUT datapath exp(q)·C — all 256 entries, every C, every
    // scale must agree bit-for-bit
    for &scale in &SCALES {
        let lut = BitSplitLut::new(scale);
        for c in c_values() {
            let table = lut.response_table(c);
            for q in all_codes() {
                assert_eq!(
                    table[q as u8 as usize].to_bits(),
                    lut.consmax(q, c).to_bits(),
                    "scale {scale} c {} code {q}",
                    c.to_f32()
                );
                assert_eq!(
                    lut.consmax(q, c).to_bits(),
                    lut.exp(q).mul(c).to_bits(),
                    "scale {scale} c {} code {q}: consmax != exp*C",
                    c.to_f32()
                );
            }
        }
    }
}

#[test]
fn rtl_pipeline_matches_lut_for_every_code() {
    // the 3-stage hardware model must drain to exactly the LUT bits on
    // the full input space, at every scale and C
    for &scale in &SCALES {
        let lut = BitSplitLut::new(scale);
        for c in c_values() {
            let codes = all_codes();
            let mut sim = ConsmaxUnitSim::new(scale);
            let probs = sim.run_stream(&codes, c);
            assert_eq!(probs.len(), codes.len());
            for (&q, p) in codes.iter().zip(&probs) {
                assert_eq!(
                    p.to_bits(),
                    lut.consmax(q, c).to_bits(),
                    "scale {scale} c {} code {q}",
                    c.to_f32()
                );
            }
        }
    }
}

#[test]
fn rtl_pipeline_bubbles_do_not_corrupt_the_stream() {
    // interleave bubbles between every valid input: the valid outputs
    // must still be exactly the LUT bits, in order
    let scale = 1.0 / 16.0;
    let lut = BitSplitLut::new(scale);
    let c = merge_beta_gamma(1.5, 100.0);
    let mut sim = ConsmaxUnitSim::new(scale);
    let mut got = Vec::new();
    for q in all_codes() {
        let o1 = sim.clock(SimInput { valid: true, score: q, c_const: c });
        let o2 = sim.clock(SimInput::bubble());
        for o in [o1, o2] {
            if o.valid {
                got.push(o.prob);
            }
        }
    }
    // drain the pipeline
    for _ in 0..ConsmaxUnitSim::LATENCY {
        let o = sim.clock(SimInput::bubble());
        if o.valid {
            got.push(o.prob);
        }
    }
    let codes = all_codes();
    assert_eq!(got.len(), codes.len());
    for (&q, p) in codes.iter().zip(&got) {
        assert_eq!(p.to_bits(), lut.consmax(q, c).to_bits(), "code {q}");
    }
}

#[test]
fn attend_consmax_lut_kernel_emits_table_bits_for_every_code() {
    // the serving kernel end-to-end: head_dim 1, q = [1], unit scale and
    // a unit V row make y exactly the probability, so each of the 256
    // codes is recoverable bit-for-bit. Keys are exact dequantizations,
    // which round-trip to their own code (exact_codes_roundtrip).
    let lut = BitSplitLut::paper();
    let quant = Int8Quantizer::paper();
    let c = merge_beta_gamma(2.5, 100.0);
    let table = lut.response_table(c);
    for q in all_codes() {
        let key = [quant.dequantize(q)];
        let val = [1.0f32];
        let mut y = [0.0f32];
        native::attend_consmax_lut(
            &[1.0f32],
            &key,
            &val,
            1,
            1.0,
            &quant,
            &table,
            &mut y,
        );
        assert_eq!(
            y[0].to_bits(),
            table[q as u8 as usize].to_f32().to_bits(),
            "code {q}"
        );
    }
}

#[test]
fn saturation_routes_out_of_range_scores_to_the_rim_codes() {
    // scores beyond the int8 grid must land exactly on the ±rim table
    // entries — the serving path's clamp is part of the bit contract
    let lut = BitSplitLut::paper();
    let quant = Int8Quantizer::paper();
    let c = merge_beta_gamma(1.5, 100.0);
    let table = lut.response_table(c);
    for (score, code) in [(1e9f32, 127i8), (-1e9, -128), (8.0, 127), (-8.5, -128)]
    {
        let mut y = [0.0f32];
        native::attend_consmax_lut(
            &[1.0f32],
            &[score],
            &[1.0f32],
            1,
            1.0,
            &quant,
            &table,
            &mut y,
        );
        assert_eq!(
            y[0].to_bits(),
            table[code as u8 as usize].to_f32().to_bits(),
            "score {score}"
        );
        assert_eq!(
            table[code as u8 as usize].to_bits(),
            lut.consmax(code, c).to_bits()
        );
    }
}

#[test]
fn lut_rom_capacity_is_the_papers_512_bits() {
    // the whole serving tail fits the paper's two 16-entry fp16 ROMs
    assert_eq!(BitSplitLut::CAPACITY_BITS, 512);
    let (msb, lsb) = BitSplitLut::paper().table_bits();
    assert_eq!(msb.len() + lsb.len(), 32);
}
