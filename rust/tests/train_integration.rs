//! End-to-end coordinator integration: train the tiny model through the
//! AOT train-step from Rust, check learning happens, exercise eval /
//! checkpointing / sweep / generation against the real PJRT runtime.
//!
//! Tests skip (with a message) when artifacts are missing.

use consmax::coordinator::sweep::pin_beta_gamma;
use consmax::coordinator::{
    GenRequest, Generator, ParamStore, Server, TrainOptions, Trainer,
};
use consmax::data::{BatchSampler, ByteTokenizer, Corpus};
use consmax::runtime::Engine;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine() -> Option<Engine> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing, run `make artifacts`");
        return None;
    }
    Some(Engine::new(artifacts_dir()).expect("engine"))
}

fn samplers(
    cfg: &consmax::config::ModelConfig,
    seed: u64,
) -> (BatchSampler, BatchSampler) {
    let corpus = Corpus::tiny();
    let (train, val) = corpus.split();
    let tok = ByteTokenizer;
    (
        BatchSampler::new(tok.encode(train), cfg.train_batch, cfg.ctx, seed),
        BatchSampler::new(tok.encode(val), cfg.train_batch, cfg.ctx, seed),
    )
}

fn trainer<'e>(eng: &'e Engine, key: &str, seed: u64) -> Trainer<'e> {
    let cfg = eng.manifest.config(key).expect("config").clone();
    let store = ParamStore::init(&cfg, seed).expect("init");
    let (train, val) = samplers(&cfg, seed);
    Trainer::new(eng, key, store, train, Some(val)).expect("trainer")
}

#[test]
fn tiny_training_reduces_loss() {
    let Some(eng) = engine() else { return };
    let mut tr = trainer(&eng, "tiny_consmax", 0);
    let report = tr
        .train(&TrainOptions {
            steps: 40,
            log_every: 5,
            eval_every: 0,
            trace_params: true,
            ..Default::default()
        })
        .expect("train");
    // byte-level model starts at ~ln(256)=5.55; 40 steps on the tiny
    // corpus must make clear progress
    assert!(report.final_loss < 5.0, "loss {}", report.final_loss);
    assert!(report.final_loss.is_finite());
    let first = tr.metrics.get("train_loss").unwrap().points[0].1;
    assert!(first > report.final_loss, "{first} -> {}", report.final_loss);
}

#[test]
fn softmax_variant_also_trains() {
    let Some(eng) = engine() else { return };
    let mut tr = trainer(&eng, "tiny_softmax", 0);
    let report = tr
        .train(&TrainOptions {
            steps: 20,
            log_every: 10,
            trace_params: false,
            ..Default::default()
        })
        .expect("train");
    assert!(report.final_loss < 5.4, "loss {}", report.final_loss);
}

#[test]
fn beta_gamma_traces_recorded_and_move() {
    let Some(eng) = engine() else { return };
    let mut tr = trainer(&eng, "tiny_consmax", 1);
    tr.train(&TrainOptions {
        steps: 25,
        log_every: 5,
        trace_params: true,
        ..Default::default()
    })
    .expect("train");
    // Fig 7: per-head beta series exist and are not frozen
    let s = tr.metrics.get("beta_l0h0").expect("beta series");
    assert!(s.points.len() >= 4);
    let first = s.points[0].1;
    let last = s.points.last().unwrap().1;
    assert_ne!(first, last, "beta should move during training");
    // gamma series exist too (low % change per the paper)
    assert!(tr.metrics.get("gamma_l0h0").is_some());
}

#[test]
fn evaluation_returns_sane_loss() {
    let Some(eng) = engine() else { return };
    let mut tr = trainer(&eng, "tiny_consmax", 2);
    let loss = tr.evaluate(2).expect("eval");
    // untrained byte model: near ln(256) = 5.545
    assert!((4.5..6.5).contains(&loss), "{loss}");
}

#[test]
fn checkpoint_roundtrip_preserves_training_state() {
    let Some(eng) = engine() else { return };
    let dir = std::env::temp_dir().join("consmax_train_int");
    let ckpt = dir.join("t.ckpt");
    let mut tr = trainer(&eng, "tiny_consmax", 3);
    tr.train(&TrainOptions {
        steps: 10,
        log_every: 10,
        trace_params: false,
        checkpoint: Some(ckpt.clone()),
        ..Default::default()
    })
    .expect("train");
    let loss_before = tr.evaluate(2).expect("eval");

    // reload and confirm identical evaluation
    let cfg = eng.manifest.config("tiny_consmax").unwrap().clone();
    let store = ParamStore::load(&ckpt, &cfg).expect("load");
    assert_eq!(store.step, 10);
    let (train, val) = samplers(&cfg, 3);
    let mut tr2 =
        Trainer::new(&eng, "tiny_consmax", store, train, Some(val)).unwrap();
    let loss_after = tr2.evaluate(2).expect("eval");
    assert!(
        (loss_before - loss_after).abs() < 1e-5,
        "{loss_before} vs {loss_after}"
    );
}

#[test]
fn resumed_training_continues_improving() {
    let Some(eng) = engine() else { return };
    let mut tr = trainer(&eng, "tiny_consmax", 4);
    tr.train(&TrainOptions {
        steps: 15,
        log_every: 15,
        trace_params: false,
        ..Default::default()
    })
    .unwrap();
    let mid = tr.evaluate(2).unwrap();
    tr.train(&TrainOptions {
        steps: 30,
        log_every: 30,
        trace_params: false,
        ..Default::default()
    })
    .unwrap();
    assert_eq!(tr.store.step, 45);
    let end = tr.evaluate(2).unwrap();
    assert!(end < mid + 0.05, "resume regressed: {mid} -> {end}");
}

#[test]
fn pinned_beta_gamma_inits_apply() {
    let Some(eng) = engine() else { return };
    let cfg = eng.manifest.config("tiny_consmax").unwrap().clone();
    let mut store = ParamStore::init(&cfg, 0).unwrap();
    pin_beta_gamma(&mut store, 1.25, 64.0);
    let beta = store.get("beta").unwrap().as_f32().unwrap();
    assert!(beta.iter().all(|&b| b == 1.25));
    let gamma = store.get("gamma").unwrap().as_f32().unwrap();
    assert!(gamma.iter().all(|&g| g == 64.0));
}

#[test]
fn generation_is_deterministic_greedy() {
    let Some(eng) = engine() else { return };
    let cfg = eng.manifest.config("tiny_consmax").unwrap().clone();
    let store = ParamStore::init(&cfg, 5).unwrap();
    let mut g1 = Generator::new(&eng, &store, 0).unwrap();
    let mut g2 = Generator::new(&eng, &store, 99).unwrap(); // rng unused at T=0
    let a = g1.generate_batch(&["hello ".into()], 12, 0.0).unwrap();
    let b = g2.generate_batch(&["hello ".into()], 12, 0.0).unwrap();
    assert_eq!(a, b);
    assert_eq!(a[0].len(), 12);
}

#[test]
fn generation_respects_context_budget() {
    let Some(eng) = engine() else { return };
    let cfg = eng.manifest.config("tiny_consmax").unwrap().clone();
    let store = ParamStore::init(&cfg, 5).unwrap();
    let mut g = Generator::new(&eng, &store, 0).unwrap();
    // prompt longer than ctx: must clamp, not crash
    let long = "x".repeat(cfg.ctx * 2);
    let out = g.generate_batch(&[long], 8, 0.0).unwrap();
    assert_eq!(out[0].len(), 8);
}

#[test]
fn server_serves_all_requests() {
    let Some(eng) = engine() else { return };
    let cfg = eng.manifest.config("tiny_consmax").unwrap().clone();
    let store = ParamStore::init(&cfg, 6).unwrap();
    let gen = Generator::new(&eng, &store, 0).unwrap();
    let mut server = Server::new(gen);
    for id in 0..3 {
        server.submit(GenRequest {
            id,
            prompt: format!("prompt {id} "),
            max_new_tokens: 6,
            temperature: 0.0,
            stop: None,
            deadline_ms: None,
        });
    }
    let responses = server.run_to_completion().expect("serve");
    assert_eq!(responses.len(), 3);
    assert_eq!(server.pending(), 0);
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2]);
    for r in &responses {
        assert_eq!(r.new_tokens, 6);
        assert!(r.latency_ms > 0.0);
    }
    assert_eq!(server.latencies.len(), 3);
}

#[test]
fn divergence_is_reported_not_hidden() {
    let Some(eng) = engine() else { return };
    let cfg = eng.manifest.config("tiny_consmax").unwrap().clone();
    // poison the weights to force non-finite loss
    let mut store = ParamStore::init(&cfg, 0).unwrap();
    let i = store.index_of("wte").unwrap();
    let shape = store.params[i].shape.clone();
    let n: usize = shape.iter().product();
    store.params[i] =
        consmax::runtime::HostTensor::from_f32(&vec![f32::NAN; n], &shape);
    let (train, val) = samplers(&cfg, 0);
    let mut tr = Trainer::new(&eng, "tiny_consmax", store, train, Some(val)).unwrap();
    let err = tr
        .train(&TrainOptions {
            steps: 2,
            log_every: 1,
            trace_params: false,
            ..Default::default()
        })
        .unwrap_err();
    assert!(err.to_string().contains("diverged"), "{err}");
}
