//! The SIMD microkernel seam's cross-mode contract (DESIGN.md
//! §SIMD-kernel seam), pinned from outside the lane module:
//!
//! * `exp_approx` / `exp2_approx` vs libm over the LUT-representable
//!   input grid and a dense sweep of the finite range, plus the edge
//!   contract (±inf, NaN, subnormals, large-negative → exactly 0.0,
//!   never NaN);
//! * the dispatched reductions (`dot`, `dot_i8`) bit-identical between
//!   `--simd off` and `--simd auto` (bit-identity by construction);
//! * the fused attention tails and row normalizers within the
//!   documented exp tolerance between modes, at every thread count
//!   (property-based);
//! * model-level `next_logits` within tolerance between modes, and
//!   bitwise thread-count-invariant *within* each mode.
//!
//! Mode and thread flips are process-global, so every test that
//! touches them serializes through `MODE_LOCK` and restores the
//! defaults before releasing it. The in-module `simd.rs` unit tests
//! deliberately never flip modes — this binary owns that.

use std::sync::{Mutex, MutexGuard};

use consmax::config::ModelConfig;
use consmax::coordinator::ParamStore;
use consmax::prop_assert;
use consmax::runtime::backend::simd::{self, Mode};
use consmax::runtime::backend::{native, NativeModel};
use consmax::runtime::parallel;
use consmax::util::proptest::run_property;

/// Serializes every mode/thread flip in this binary (tests run
/// concurrently in one process). Poison-tolerant: a failing test must
/// not cascade into every later lock holder.
static MODE_LOCK: Mutex<()> = Mutex::new(());

fn locked() -> MutexGuard<'static, ()> {
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restore process defaults before the lock is released.
fn restore() {
    simd::set_mode(Mode::Auto);
    parallel::set_threads(0);
}

/// Relative error of the polynomial vs f64 libm, at a point.
fn rel_err(got: f32, want: f64) -> f64 {
    (got as f64 - want).abs() / want.abs().max(f64::MIN_POSITIVE)
}

// ---------------------------------------------------------------------------
// exp_approx accuracy + edges (pure functions; no lock needed)
// ---------------------------------------------------------------------------

#[test]
fn exp_approx_exhaustive_on_lut_grid() {
    // every int8 score code at the paper's 1/16 operating point —
    // the exact input set the quantized datapath can ever produce
    for code in -128i32..=127 {
        let x = code as f32 / 16.0;
        let err = rel_err(simd::exp_approx(x), (x as f64).exp());
        assert!(err <= 1e-6, "exp({x}): rel err {err:.3e}");
        let err2 = rel_err(simd::exp2_approx(x), (x as f64).exp2());
        assert!(err2 <= 1e-6, "exp2({x}): rel err {err2:.3e}");
    }
}

#[test]
fn exp_approx_dense_sweep_of_finite_range() {
    // ~35k points across the non-saturating input range
    let mut x = -87.0f32;
    while x <= 88.0 {
        let err = rel_err(simd::exp_approx(x), (x as f64).exp());
        assert!(err <= 3e-6, "exp({x}): rel err {err:.3e}");
        x += 0.005;
    }
    let mut x = -125.0f32;
    while x <= 126.0 {
        let err = rel_err(simd::exp2_approx(x), (x as f64).exp2());
        assert!(err <= 3e-6, "exp2({x}): rel err {err:.3e}");
        x += 0.007;
    }
}

#[test]
fn exp_approx_edge_contract() {
    // saturation / flush edges: large-negative must be exactly 0.0 —
    // never NaN — so masked -inf scores vanish like libm's exp
    for f in [simd::exp_approx as fn(f32) -> f32, simd::exp2_approx] {
        assert_eq!(f(f32::NEG_INFINITY).to_bits(), 0.0f32.to_bits());
        assert_eq!(f(-1e30), 0.0);
        assert_eq!(f(-200.0), 0.0);
        assert!(f(f32::INFINITY).is_infinite());
        assert!(f(1e30).is_infinite());
        assert!(f(f32::NAN).is_nan());
        // subnormal and ±0 inputs are exp(~0) = exactly 1
        assert_eq!(f(0.0), 1.0);
        assert_eq!(f(-0.0), 1.0);
        assert_eq!(f(1.0e-40), 1.0);
        assert_eq!(f(-1.0e-40), 1.0);
    }
    // documented saturation points (tighter than libm's overflow edge)
    assert!(simd::exp_approx(simd::EXP_HI).is_finite());
    assert!(simd::exp_approx(88.5).is_infinite());
    assert!(simd::exp2_approx(simd::EXP2_HI).is_finite());
    assert!(simd::exp2_approx(127.5).is_infinite());
    // exact powers of two come out exact in base 2
    assert_eq!(simd::exp2_approx(10.0), 1024.0);
    assert_eq!(simd::exp2_approx(-3.0), 0.125);
}

// ---------------------------------------------------------------------------
// cross-mode contracts (mode/thread flips; all under MODE_LOCK)
// ---------------------------------------------------------------------------

#[test]
fn dot_and_dot_i8_bits_equal_across_modes() {
    let _g = locked();
    for len in [0usize, 1, 7, 8, 9, 16, 31, 64, 100, 257] {
        let a: Vec<f32> = (0..len).map(|i| (i as f32) * 0.21 - 5.0).collect();
        let b: Vec<f32> = (0..len).map(|i| 2.5 - (i as f32) * 0.11).collect();
        let q: Vec<i8> = (0..len).map(|i| ((i * 37) % 255) as i8).collect();
        simd::set_mode(Mode::Off);
        let (d_off, qi_off) = (native::dot(&a, &b), native::dot_i8(&a, &q));
        simd::set_mode(Mode::Auto);
        let (d_on, qi_on) = (native::dot(&a, &b), native::dot_i8(&a, &q));
        assert_eq!(d_off.to_bits(), d_on.to_bits(), "dot len {len}");
        assert_eq!(qi_off.to_bits(), qi_on.to_bits(), "dot_i8 len {len}");
    }
    restore();
}

#[test]
fn attention_tails_match_scalar_within_tolerance_at_every_thread_count() {
    let _g = locked();
    run_property("simd tail vs scalar tail", 40, |g| {
        let hd = *g.choose(&[4usize, 8, 16, 32]);
        let n = g.usize(1, 65);
        let q: Vec<f32> = (0..hd).map(|_| g.normal_f32() * 0.5).collect();
        let k: Vec<f32> = (0..n * hd).map(|_| g.normal_f32() * 0.5).collect();
        let v: Vec<f32> = (0..n * hd).map(|_| g.normal_f32()).collect();
        let scale = 1.0 / (hd as f32).sqrt();
        let (beta, gamma) = (g.f32(0.0, 2.0), g.f32(1.0, 100.0));
        type Tail = fn(
            &[f32],
            &[f32],
            &[f32],
            usize,
            f32,
            f32,
            f32,
            &mut [f32],
        );
        for tail in [
            native::attend_consmax as Tail,
            native::attend_consmax2 as Tail,
        ] {
            let mut per_mode: Vec<Vec<f32>> = Vec::new();
            for mode in [Mode::Off, Mode::Auto] {
                simd::set_mode(mode);
                let mut per_threads: Vec<Vec<f32>> = Vec::new();
                for threads in [1usize, 4] {
                    parallel::set_threads(threads);
                    let mut y = vec![0.0f32; hd];
                    tail(&q, &k, &v, hd, scale, beta, gamma, &mut y);
                    per_threads.push(y);
                }
                // within one mode the tail is bitwise thread-invariant
                prop_assert!(
                    per_threads[0] == per_threads[1],
                    "tail not thread-invariant within a mode (n={n} hd={hd})"
                );
                per_mode.push(per_threads.pop().unwrap());
            }
            // across modes only the exp differs: documented tolerance
            for (i, (s, f)) in per_mode[0].iter().zip(&per_mode[1]).enumerate()
            {
                let tol = 1e-5 * s.abs().max(f.abs()).max(1.0);
                prop_assert!(
                    (s - f).abs() <= tol,
                    "tail[{i}]: scalar {s} vs simd {f} (n={n} hd={hd} \
                     beta={beta} gamma={gamma})"
                );
            }
        }
        Ok(())
    });
    restore();
}

#[test]
fn row_normalizers_match_scalar_within_tolerance() {
    let _g = locked();
    run_property("simd softmax vs scalar softmax", 40, |g| {
        let row = g.usize(1, 48);
        let rows = g.usize(1, 4);
        let mut s: Vec<f32> =
            (0..rows * row).map(|_| g.normal_f32() * 3.0).collect();
        // sprinkle -inf masking like the causal mask does
        if g.bool() && s.len() > 1 {
            let i = g.usize(0, s.len());
            s[i] = f32::NEG_INFINITY;
        }
        for variant in [
            native::softmax_rows as fn(&[f32], usize) -> Vec<f32>,
            native::softermax_rows,
        ] {
            simd::set_mode(Mode::Off);
            let p_off = variant(&s, row);
            simd::set_mode(Mode::Auto);
            let p_on = variant(&s, row);
            for (i, (a, b)) in p_off.iter().zip(&p_on).enumerate() {
                // probabilities are in [0, 1]: absolute tolerance
                prop_assert!(
                    (a - b).abs() <= 2e-6,
                    "p[{i}]: off {a} vs auto {b} (row={row})"
                );
            }
            // both modes still normalize each live row to 1
            for chunk in p_on.chunks_exact(row) {
                let total: f32 = chunk.iter().sum();
                prop_assert!(
                    total == 0.0 || (total - 1.0).abs() <= 1e-5,
                    "row sums to {total}"
                );
            }
        }
        Ok(())
    });
    restore();
}

#[test]
fn model_logits_agree_across_modes_and_stay_thread_invariant() {
    let _g = locked();
    let seqs: Vec<Vec<i32>> = vec![
        (0..12).map(|i| (i * 29 + 3) % 256).collect(),
        (0..7).map(|i| (i * 53 + 11) % 256).collect(),
    ];
    for norm in ["consmax", "consmax-v2", "softmax"] {
        let cfg = ModelConfig::builtin("tiny", norm).unwrap();
        let store = ParamStore::init(&cfg, 0).unwrap();
        let model =
            NativeModel::from_params(&cfg, &store.order, &store.params).unwrap();

        simd::set_mode(Mode::Off);
        parallel::set_threads(1);
        let off = model.next_logits(&seqs).unwrap();

        simd::set_mode(Mode::Auto);
        let auto_1t = model.next_logits(&seqs).unwrap();
        parallel::set_threads(4);
        let auto_4t = model.next_logits(&seqs).unwrap();

        // within the SIMD mode: bitwise thread invariance end to end
        assert_eq!(auto_1t, auto_4t, "{norm}: SIMD logits not thread-invariant");
        // across modes: the exp approximation's drift through a full
        // forward stays tiny relative to logit scale
        assert_eq!(off.len(), auto_1t.len());
        for (i, (a, b)) in off.iter().zip(&auto_1t).enumerate() {
            let tol = 1e-4 * a.abs().max(b.abs()).max(1.0);
            assert!(
                (a - b).abs() <= tol,
                "{norm} logit[{i}]: off {a} vs auto {b}"
            );
        }
    }
    restore();
}

#[test]
fn mode_selection_is_reported_and_flips_exp_dispatch() {
    let _g = locked();
    simd::set_mode(Mode::Off);
    assert_eq!(simd::level(), simd::Level::Off);
    // off mode dispatches to libm exactly
    for x in [-5.0f32, -0.3, 0.0, 0.7, 10.0] {
        assert_eq!(simd::exp(x).to_bits(), x.exp().to_bits());
        assert_eq!(simd::exp2(x).to_bits(), x.exp2().to_bits());
    }
    simd::set_mode(Mode::Auto);
    let l = simd::level();
    assert!(matches!(l, simd::Level::Portable | simd::Level::Avx2));
    // auto mode dispatches to the polynomial exactly
    for x in [-5.0f32, -0.3, 0.0, 0.7, 10.0] {
        assert_eq!(simd::exp(x).to_bits(), simd::exp_approx(x).to_bits());
        assert_eq!(simd::exp2(x).to_bits(), simd::exp2_approx(x).to_bits());
    }
    restore();
}
