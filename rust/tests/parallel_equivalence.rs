//! Threaded-vs-serial equivalence suite: the determinism contract of
//! the parallel compute layer (DESIGN.md §Parallel-compute seam).
//!
//! Partitioning work across the pool must only decide *who* computes an
//! element, never *how* — per-row reductions are fixed serial orders —
//! so forward, prefill and decode logits must be **bit-identical** for
//! every thread count, for all three normalizers, on ragged batches,
//! through the eviction path, and under partial active masks. A
//! CI matrix leg re-runs the whole test suite with `CONSMAX_THREADS=1`
//! to pin the single-thread baseline itself.
//!
//! Tests in this binary serialize their `set_threads` toggling through
//! one mutex (the knob is process-global); the assertions themselves
//! would hold even without it, since results are thread-count-invariant.

use std::sync::{Mutex, MutexGuard, OnceLock};

use consmax::config::ModelConfig;
use consmax::coordinator::ParamStore;
use consmax::prop_assert;
use consmax::runtime::backend::{DecodeSession, NativeModel};
use consmax::runtime::parallel;
use consmax::util::proptest::{run_property, Gen};

const NORMALIZERS: [&str; 3] = ["consmax", "softmax", "softermax"];

fn lock() -> MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn tiny_model(norm: &str, seed: u64) -> NativeModel {
    let cfg = ModelConfig::builtin("tiny", norm).unwrap();
    let store = ParamStore::init(&cfg, seed).unwrap();
    NativeModel::from_params(&cfg, &store.order, &store.params).unwrap()
}

/// Run `f` once at 1 thread and once at `n`, restoring the default.
fn at_threads<T>(n: usize, mut f: impl FnMut() -> T) -> (T, T) {
    parallel::set_threads(1);
    let serial = f();
    parallel::set_threads(n);
    let threaded = f();
    parallel::set_threads(0);
    (serial, threaded)
}

#[test]
fn forward_is_thread_invariant() {
    let _g = lock();
    for norm in NORMALIZERS {
        let m = tiny_model(norm, 3);
        let toks: Vec<i32> =
            (0..2 * 24).map(|i| ((i * 17 + 3) % 256) as i32).collect();
        let (serial, threaded) =
            at_threads(4, || m.forward(&toks, 2, 24).unwrap());
        assert_eq!(
            serial, threaded,
            "{norm}: forward logits diverged across thread counts"
        );
    }
}

#[test]
fn prefill_and_decode_are_thread_invariant() {
    let _g = lock();
    for norm in NORMALIZERS {
        let m = tiny_model(norm, 5);
        // ragged on purpose: mid-length, single-token, overlong (clamps
        // to ctx, so its first decode step exercises ring eviction), and
        // short
        let rows: Vec<Vec<i32>> = vec![
            (0..50).map(|i| ((i * 7 + 1) % 256) as i32).collect(),
            vec![42],
            (0..90).map(|i| ((i * 11 + 2) % 256) as i32).collect(),
            (0..17).map(|i| ((i * 3 + 9) % 256) as i32).collect(),
        ];
        let active_masks = [
            vec![true, true, true, true],
            vec![true, false, true, false],
            vec![false, true, false, true],
            vec![true, true, true, true],
        ];
        let run = || {
            let mut sess = DecodeSession::new(&m.cfg, rows.len());
            let mut all = m.prefill(&mut sess, &rows).unwrap();
            for (step, active) in active_masks.iter().enumerate() {
                let toks: Vec<i32> = (0..rows.len())
                    .map(|r| ((step * 13 + r * 31 + 7) % 256) as i32)
                    .collect();
                let logits =
                    m.decode_step_active(&mut sess, &toks, active).unwrap();
                all.extend_from_slice(&logits);
            }
            all
        };
        let (serial, threaded) = at_threads(4, run);
        assert_eq!(
            serial, threaded,
            "{norm}: prefill/decode logits diverged across thread counts"
        );
    }
}

#[test]
fn prop_ragged_batches_thread_invariant() {
    let _g = lock();
    run_property("ragged batches thread-invariant", 10, |g: &mut Gen| {
        let norm = *g.choose(&NORMALIZERS);
        let m = tiny_model(norm, g.u64(0, 1000));
        let b = g.usize(1, 5);
        let rows: Vec<Vec<i32>> = (0..b)
            .map(|_| {
                let len = g.usize(1, 80); // some rows overlong vs ctx 64
                (0..len).map(|_| g.usize(0, 256) as i32).collect()
            })
            .collect();
        let steps = g.usize(1, 4);
        let toks_per_step: Vec<Vec<i32>> = (0..steps)
            .map(|_| (0..b).map(|_| g.usize(0, 256) as i32).collect())
            .collect();
        let nthreads = g.usize(2, 7);

        let run = || {
            let mut sess = DecodeSession::new(&m.cfg, b);
            let mut all = m.prefill(&mut sess, &rows).unwrap();
            for toks in &toks_per_step {
                all.extend_from_slice(
                    &m.decode_step(&mut sess, toks).unwrap(),
                );
            }
            all
        };
        parallel::set_threads(1);
        let serial = run();
        parallel::set_threads(nthreads);
        let threaded = run();
        parallel::set_threads(0);
        prop_assert!(
            serial == threaded,
            "{norm}: b={b}, {nthreads} threads: logits diverged"
        );
        Ok(())
    });
}
