//! Property tests for the int8 quantization seam (DESIGN.md
//! §Quantization seam): per-channel weight quantization via
//! [`QuantizedMatrix`] and the per-vector KV storage transform
//! ([`kv_vec_scale`] / [`quantize_i8`] / [`dequantize_i8`]), driven
//! over random tensors with injected adversarial structure — all-zero
//! channels, single outliers, subnormals, near-max magnitudes, and
//! NaN/inf elements.
//!
//! The pinned contract:
//! * every fitted scale is a finite positive power of two — never
//!   NaN, inf, or zero — for **any** f32 input bits (the quantizer is
//!   symmetric, so the zero-point is identically 0 by construction);
//! * on finite activation-range inputs the roundtrip error stays
//!   within the documented `scale / 2` bound and the output is finite;
//! * quantize→dequantize is **idempotent in bits** on finite inputs:
//!   re-quantizing a dequantized tensor reproduces it exactly, because
//!   power-of-two scales make the rescale a pure exponent shift. This
//!   is the property that lets the paged decode staging path
//!   (`KvDtype::roundtrip_vec`) and the pool's `write_token`
//!   re-quantization agree bit for bit.

use consmax::config::KvDtype;
use consmax::prop_assert;
use consmax::quant::{
    dequantize_i8, kv_vec_scale, quantize_i8, Int8Quantizer,
    QuantizedMatrix,
};
use consmax::util::proptest::{run_property, Gen};

/// Finite adversarial magnitudes: signed zeros, f32 subnormals, an
/// activation-scale outlier, and near-max normals. `f32::MAX` itself is
/// excluded — its fitted code dequantizes to `64 * 2^122`, which
/// overflows f32 — and lives in [`WILD`], where only scale totality and
/// NaN-freedom are asserted.
const BOUNDED: [f32; 10] = [
    0.0,
    -0.0,
    1e-42,
    -1e-42,
    1e-44,
    f32::MIN_POSITIVE,
    1e6,
    -1e6,
    1e30,
    -1e30,
];

/// Everything, including the inputs a buggy fit would turn into a NaN,
/// inf, or zero scale.
const WILD: [f32; 8] = [
    0.0,
    f32::NAN,
    f32::INFINITY,
    f32::NEG_INFINITY,
    f32::MAX,
    -f32::MAX,
    1e-44,
    -1e9,
];

fn is_pow2(scale: f32) -> bool {
    scale.is_finite() && scale > 0.0 && scale.log2().fract() == 0.0
}

/// Random vector with a few adversarial elements spliced in.
fn gen_vec(g: &mut Gen, pool: &[f32]) -> Vec<f32> {
    let mut v = g.vec_f32(1, 48, -1e4, 1e4);
    for _ in 0..g.usize(0, 5) {
        let i = g.usize(0, v.len());
        v[i] = *g.choose(pool);
    }
    v
}

/// Random `[dout, din]` row-major matrix where each output channel may
/// get adversarial structure: all-zero, single outlier, all-subnormal,
/// or one element from `pool`.
fn gen_matrix(g: &mut Gen, pool: &[f32]) -> (Vec<f32>, usize, usize) {
    let dout = g.usize(1, 8);
    let din = g.usize(1, 16);
    let mut w = vec![0.0f32; dout * din];
    for x in w.iter_mut() {
        *x = g.f32(-50.0, 50.0);
    }
    for r in 0..dout {
        let row = &mut w[r * din..(r + 1) * din];
        match g.usize(0, 5) {
            0 => row.fill(0.0),
            1 => row[g.usize(0, din)] = 1e6,
            2 => {
                for x in row.iter_mut() {
                    *x = *g.choose(&[1e-42f32, -1e-42, 1e-44]);
                }
            }
            3 => row[g.usize(0, din)] = *g.choose(pool),
            _ => {}
        }
    }
    (w, dout, din)
}

#[test]
fn kv_scale_is_total_and_pow2() {
    run_property("kv scale total", 400, |g: &mut Gen| {
        let v = gen_vec(g, &WILD);
        let s = kv_vec_scale(&v);
        prop_assert!(is_pow2(s), "scale {s:e} for {v:?}");
        Ok(())
    });
}

#[test]
fn fit_safe_is_total_over_all_f32_bit_patterns() {
    run_property("fit_safe total", 2000, |g: &mut Gen| {
        let x = f32::from_bits(g.rng().next_u32());
        let q = Int8Quantizer::fit_safe(x);
        prop_assert!(is_pow2(q.scale), "x {x:e} -> scale {:e}", q.scale);
        Ok(())
    });
}

#[test]
fn kv_roundtrip_error_is_bounded_on_finite_vectors() {
    run_property("kv roundtrip bound", 400, |g: &mut Gen| {
        let v = gen_vec(g, &BOUNDED);
        let s = kv_vec_scale(&v);
        for &x in &v {
            let rt = dequantize_i8(quantize_i8(x, s), s);
            prop_assert!(rt.is_finite(), "x {x:e} -> {rt:e} (scale {s:e})");
            prop_assert!(
                (rt - x).abs() <= 0.5 * s,
                "x {x:e} -> {rt:e} err {:e} > scale/2 (scale {s:e})",
                (rt - x).abs()
            );
        }
        Ok(())
    });
}

#[test]
fn kv_roundtrip_is_idempotent_in_bits() {
    run_property("kv roundtrip idempotent", 300, |g: &mut Gen| {
        let v = gen_vec(g, &BOUNDED);
        let mut once = v.clone();
        KvDtype::Int8.roundtrip_vec(&mut once);
        let mut twice = once.clone();
        KvDtype::Int8.roundtrip_vec(&mut twice);
        for (i, (a, b)) in once.iter().zip(&twice).enumerate() {
            prop_assert!(
                a.to_bits() == b.to_bits(),
                "[{i}] {a:e} re-quantized to {b:e} (input {v:?})"
            );
        }
        Ok(())
    });
}

#[test]
fn roundtrip_vec_matches_the_pool_storage_transform() {
    // the paged decode staging path (KvDtype::roundtrip_vec) and the
    // per-vector transform KvPool applies at write_token must be the
    // same function, bit for bit — decode correctness rests on it
    run_property("staging == storage transform", 300, |g: &mut Gen| {
        let v = gen_vec(g, &BOUNDED);
        let mut staged = v.clone();
        KvDtype::Int8.roundtrip_vec(&mut staged);
        let s = kv_vec_scale(&v);
        for (i, (&x, &st)) in v.iter().zip(&staged).enumerate() {
            let stored = dequantize_i8(quantize_i8(x, s), s);
            prop_assert!(
                st.to_bits() == stored.to_bits(),
                "[{i}] staged {st:e} != stored {stored:e}"
            );
        }
        Ok(())
    });
}

#[test]
fn weight_channels_quantize_independently_within_bound() {
    run_property("weight channel bound", 200, |g: &mut Gen| {
        let (w, dout, din) = gen_matrix(g, &BOUNDED);
        let qm = QuantizedMatrix::from_rows(&w, dout, din);
        let dq = qm.dequantize();
        for r in 0..dout {
            let s = qm.scales[r];
            prop_assert!(is_pow2(s), "row {r} scale {s:e}");
            for c in 0..din {
                let (a, b) = (w[r * din + c], dq[r * din + c]);
                prop_assert!(b.is_finite(), "[{r},{c}] {a:e} -> {b:e}");
                prop_assert!(
                    (a - b).abs() <= 0.5 * s,
                    "[{r},{c}] {a:e} -> {b:e} (scale {s:e})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn weight_quantization_is_idempotent_in_bits() {
    run_property("weight quant idempotent", 150, |g: &mut Gen| {
        let (w, dout, din) = gen_matrix(g, &BOUNDED);
        let qm = QuantizedMatrix::from_rows(&w, dout, din);
        let dq = qm.dequantize();
        let qm2 = QuantizedMatrix::from_rows(&dq, dout, din);
        let dq2 = qm2.dequantize();
        for (i, (a, b)) in dq.iter().zip(&dq2).enumerate() {
            prop_assert!(
                a.to_bits() == b.to_bits(),
                "[{i}] {a:e} re-quantized to {b:e}"
            );
        }
        Ok(())
    });
}

#[test]
fn wild_inputs_never_corrupt_scales_or_produce_nan() {
    run_property("wild inputs total", 300, |g: &mut Gen| {
        let (w, dout, din) = gen_matrix(g, &WILD);
        let qm = QuantizedMatrix::from_rows(&w, dout, din);
        for (r, &s) in qm.scales.iter().enumerate() {
            prop_assert!(is_pow2(s), "row {r} scale {s:e}");
        }
        // dequantized values are code * pow2-scale products: possibly
        // saturated, never NaN
        for (i, x) in qm.dequantize().iter().enumerate() {
            prop_assert!(!x.is_nan(), "[{i}] NaN after weight roundtrip");
        }
        let v = gen_vec(g, &WILD);
        let s = kv_vec_scale(&v);
        for &x in &v {
            let rt = dequantize_i8(quantize_i8(x, s), s);
            prop_assert!(!rt.is_nan(), "x {x:e} -> NaN (scale {s:e})");
        }
        Ok(())
    });
}
