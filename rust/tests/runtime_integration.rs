//! Integration: load the AOT artifacts, execute them via PJRT, and pin
//! numerics against the golden vectors python emitted — proving the
//! three-layer contract (Pallas kernel → JAX HLO → Rust execute) holds
//! end to end.
//!
//! Requires `make artifacts` to have run; tests skip with a message when
//! artifacts are missing so `cargo test` stays usable pre-build.

use consmax::runtime::{DType, Engine, HostTensor};
use consmax::util::json::Json;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine() -> Option<Engine> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing, run `make artifacts`");
        return None;
    }
    Some(Engine::new(artifacts_dir()).expect("engine"))
}

fn golden() -> Json {
    let text = std::fs::read_to_string(artifacts_dir().join("golden.json"))
        .expect("golden.json");
    Json::parse(&text).expect("parse golden")
}

fn assert_close(got: &[f32], want: &[f64], rtol: f64, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let g = *g as f64;
        let denom = g.abs().max(w.abs()).max(1e-30);
        assert!(
            (g - w).abs() / denom <= rtol || (g - w).abs() < 1e-7,
            "{what}[{i}]: {g} vs {w}"
        );
    }
}

#[test]
fn consmax_op_matches_golden() {
    let Some(eng) = engine() else { return };
    let g = golden();
    let gc = g.get("consmax");
    let s: Vec<f32> = gc.get("s").to_f64_vec().unwrap().iter().map(|&v| v as f32).collect();
    let c = gc.get("c").as_f64().unwrap() as f32;
    let want = gc.get("out").to_f64_vec().unwrap();

    // op_consmax expects (64, 256) score + constant tensors; embed the 4x8
    // golden block in the top-left corner, zero elsewhere.
    let mut s_full = vec![0f32; 64 * 256];
    let mut c_full = vec![c; 64 * 256];
    for r in 0..4 {
        for col in 0..8 {
            s_full[r * 256 + col] = s[r * 8 + col];
        }
    }
    // keep padding scores at 0 -> outputs c*1, ignored
    let out = eng
        .execute(
            "op_consmax",
            &[
                HostTensor::from_f32(&s_full, &[64, 256]),
                HostTensor::from_f32(&c_full, &[64, 256]),
            ],
        )
        .expect("execute");
    let vals = out[0].as_f32().unwrap();
    let mut got = Vec::new();
    for r in 0..4 {
        for col in 0..8 {
            got.push(vals[r * 256 + col]);
        }
    }
    assert_close(&got, &want, 1e-5, "op_consmax");
    c_full.clear(); // silence unused-mut lint paranoia
}

#[test]
fn softmax_op_matches_golden() {
    let Some(eng) = engine() else { return };
    let g = golden();
    let gs = g.get("softmax");
    let s: Vec<f32> = gs.get("s").to_f64_vec().unwrap().iter().map(|&v| v as f32).collect();
    let want = gs.get("out").to_f64_vec().unwrap();

    // softmax reduces over the whole 256-wide row: pad with -inf so the
    // golden 8-wide rows keep their normalization.
    let mut s_full = vec![f32::NEG_INFINITY; 64 * 256];
    for r in 0..4 {
        for col in 0..8 {
            s_full[r * 256 + col] = s[r * 8 + col];
        }
    }
    // rows 4.. are all -inf which softmax turns into NaN; that's fine,
    // we only read rows 0..4.
    let out = eng
        .execute("op_softmax", &[HostTensor::from_f32(&s_full, &[64, 256])])
        .expect("execute");
    let vals = out[0].as_f32().unwrap();
    let mut got = Vec::new();
    for r in 0..4 {
        for col in 0..8 {
            got.push(vals[r * 256 + col]);
        }
    }
    assert_close(&got, &want, 1e-5, "op_softmax");
}

#[test]
fn lut_consmax_op_is_bit_exact_on_full_grid() {
    let Some(eng) = engine() else { return };
    let g = golden();
    let lut = g.get("lut_exp_s16");
    let q: Vec<i8> = lut
        .get("q")
        .to_f64_vec()
        .unwrap()
        .iter()
        .map(|&v| v as i8)
        .collect();
    let want_bits: Vec<u16> = lut
        .get("out_bits")
        .to_f64_vec()
        .unwrap()
        .iter()
        .map(|&v| v as u16)
        .collect();

    // op_lut_consmax expects (64, 256) int8 + f32 C; with C=1.0 the output
    // is the raw LUT exponential. Replicate the 256-code grid per row.
    let mut q_full = vec![0i8; 64 * 256];
    for r in 0..64 {
        q_full[r * 256..(r + 1) * 256].copy_from_slice(&q);
    }
    let c_full = vec![1.0f32; 64 * 256];
    let out = eng
        .execute(
            "op_lut_consmax",
            &[
                HostTensor::from_i8(&q_full, &[64, 256]),
                HostTensor::from_f32(&c_full, &[64, 256]),
            ],
        )
        .expect("execute");
    assert_eq!(out[0].dtype, DType::F16);
    let bits = out[0].as_f16_bits().unwrap();
    // every row must match the golden grid EXACTLY (bit-level losslessness
    // of the hardware path, validated through the whole AOT pipeline)
    for r in 0..64 {
        assert_eq!(&bits[r * 256..(r + 1) * 256], &want_bits[..], "row {r}");
    }
}

#[test]
fn forward_runs_and_is_finite() {
    let Some(eng) = engine() else { return };
    let key = "tiny_consmax";
    let cfg = eng.manifest.config(key).expect("config").clone();
    let entry = format!("{key}_forward");
    let spec = eng.manifest.entry(&entry).expect("entry").clone();

    // build inputs: params (seeded like python? no — any finite params do)
    let mut inputs = Vec::new();
    let mut rng = consmax::util::rng::Pcg32::seeded(7);
    for (i, ts) in spec.inputs.iter().enumerate() {
        let n: usize = ts.shape.iter().product();
        match ts.dtype.as_str() {
            "float32" => {
                let vals = rng.normal_vec_f32(n, 0.0, 0.02);
                inputs.push(HostTensor::from_f32(&vals, &ts.shape));
            }
            "int32" => {
                let vals: Vec<i32> =
                    (0..n).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
                inputs.push(HostTensor::from_i32(&vals, &ts.shape));
            }
            other => panic!("unexpected input {i} dtype {other}"),
        }
    }
    let out = eng.execute(&entry, &inputs).expect("forward");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![1, cfg.ctx, cfg.vocab]);
    let logits = out[0].as_f32().unwrap();
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(eng) = engine() else { return };
    let bad = HostTensor::from_f32(&[0.0; 4], &[2, 2]);
    let err = eng.execute("op_softmax", &[bad]).unwrap_err().to_string();
    assert!(err.contains("shape"), "{err}");
}

#[test]
fn dtype_mismatch_is_rejected() {
    let Some(eng) = engine() else { return };
    let bad = HostTensor::from_i32(&vec![0; 64 * 256], &[64, 256]);
    let err = eng.execute("op_softmax", &[bad]).unwrap_err().to_string();
    assert!(err.contains("dtype"), "{err}");
}

#[test]
fn wrong_arity_is_rejected() {
    let Some(eng) = engine() else { return };
    let t = HostTensor::from_f32(&vec![0.0; 64 * 256], &[64, 256]);
    let err = eng
        .execute("op_consmax", &[t])
        .unwrap_err()
        .to_string();
    assert!(err.contains("inputs"), "{err}");
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(eng) = engine() else { return };
    let t = HostTensor::from_f32(&vec![0.0; 64 * 256], &[64, 256]);
    eng.execute("op_softmax", std::slice::from_ref(&t)).unwrap();
    let n1 = eng.loaded_count();
    eng.execute("op_softmax", std::slice::from_ref(&t)).unwrap();
    assert_eq!(eng.loaded_count(), n1);
}

#[test]
fn literal_roundtrip_through_pjrt_types() {
    if engine().is_none() {
        return;
    }
    // HostTensor -> Literal -> HostTensor for every dtype we marshal
    let cases = vec![
        HostTensor::from_f32(&[1.5, -2.25, 0.0, 3.75, 5.5, -0.125], &[2, 3]),
        HostTensor::from_i32(&[-7, 0, 123456], &[3]),
        HostTensor::from_i8(&[-128, -1, 0, 127], &[4]),
        HostTensor::from_f16_bits(&[0x3C00, 0xC000, 0x7BFF, 0x0001], &[2, 2]),
    ];
    for t in cases {
        let lit = t.to_literal().expect("to_literal");
        let back = HostTensor::from_literal(&lit).expect("from_literal");
        assert_eq!(back, t);
    }
}

#[test]
fn repeated_execution_does_not_leak_memory() {
    // Regression for the xla-crate `execute()` input-buffer leak (the C
    // wrapper `release()`s every uploaded input buffer): 200 executions
    // with ~128 KiB of inputs each must not grow RSS by more than a few
    // MB. With the leak, growth would be ~25 MB+.
    fn rss_kb() -> u64 {
        let statm = std::fs::read_to_string("/proc/self/statm").unwrap();
        let pages: u64 = statm.split_whitespace().nth(1).unwrap().parse().unwrap();
        pages * 4 // 4 KiB pages
    }
    let Some(eng) = engine() else { return };
    let s = HostTensor::from_f32(&vec![0.5f32; 64 * 256], &[64, 256]);
    let c = HostTensor::from_f32(&vec![0.01f32; 64 * 256], &[64, 256]);
    // warm up: compile + allocator pools
    for _ in 0..20 {
        eng.execute("op_consmax", &[s.clone(), c.clone()]).unwrap();
    }
    let before = rss_kb();
    for _ in 0..200 {
        eng.execute("op_consmax", &[s.clone(), c.clone()]).unwrap();
    }
    let grown = rss_kb().saturating_sub(before);
    assert!(grown < 8 * 1024, "RSS grew {grown} KiB over 200 executions");
}

#[test]
fn corrupt_artifact_reports_parse_error() {
    // a manifest pointing at a garbage HLO file must fail with a
    // contextual error, not a crash
    if engine().is_none() {
        return;
    }
    let dir = std::env::temp_dir().join("consmax_corrupt_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::copy(
        artifacts_dir().join("manifest.json"),
        dir.join("manifest.json"),
    )
    .unwrap();
    // copy goldens (not needed) but write a corrupt op_softmax artifact
    std::fs::write(dir.join("op_softmax.hlo.txt"), "HloModule broken \x01\x02")
        .unwrap();
    let eng = Engine::new(&dir).unwrap();
    let t = HostTensor::from_f32(&vec![0.0; 64 * 256], &[64, 256]);
    let err = eng.execute("op_softmax", &[t]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("op_softmax") || msg.contains("parsing"),
        "unhelpful error: {msg}"
    );
}

#[test]
fn missing_artifact_file_reports_path() {
    if engine().is_none() {
        return;
    }
    let dir = std::env::temp_dir().join("consmax_missing_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::copy(
        artifacts_dir().join("manifest.json"),
        dir.join("manifest.json"),
    )
    .unwrap();
    let eng = Engine::new(&dir).unwrap();
    let t = HostTensor::from_f32(&vec![0.0; 64 * 256], &[64, 256]);
    let err = format!("{:#}", eng.execute("op_softmax", &[t]).unwrap_err());
    assert!(err.contains("op_softmax"), "{err}");
}
