//! Paged-KV equivalence + memory-behavior suite (DESIGN.md §KV-memory
//! seam):
//!
//! * a **paged f32** session is *bitwise identical* to the dense oracle
//!   — prefill, incremental decode, ring eviction + window re-encode —
//!   for the whole normalizer zoo (softmax, consmax, softermax,
//!   consmax-v2, ssmax) and for block sizes that do and don't divide
//!   the context;
//! * **fp16/bf16 KV** tracks the dense logits within the documented
//!   tolerances (EXPERIMENTS.md §KV memory scaling);
//! * **int8 KV** (one byte per element + a per-vector power-of-two
//!   scale) tracks dense within its own documented tolerance, and its
//!   prefix-sharing/CoW paths are *bitwise* against an int8 solo
//!   session with the identical pool config — sharing may never change
//!   which codes a row reads;
//! * **prefix sharing** really shares blocks (gauges move) and changes
//!   no bits: a row riding a shared prefix emits the exact dense
//!   logits, stays isolated after divergence (copy-on-write), and
//!   survives eviction re-encode;
//! * the pool **returns to empty** when rows reset, and a byte budget
//!   below one full row is rejected;
//! * the continuous scheduler over a small budget **preempts-and-
//!   requeues whole requests** without changing any request's output.

use consmax::config::{KvCacheConfig, KvDtype, ModelConfig};
use consmax::coordinator::{GenRequest, Generator, ParamStore, Server};
use consmax::runtime::backend::{DecodeSession, NativeModel};

const NORMALIZERS: [&str; 5] =
    ["consmax", "softmax", "softermax", "consmax-v2", "ssmax"];

/// Documented closeness bound for f16 KV storage vs the f32 oracle
/// (relative, with a 1.0 absolute floor in the denominator).
const F16_TOL: f32 = 2e-2;
/// Same bound for bf16 (7-bit mantissa: coarser).
const BF16_TOL: f32 = 1e-1;
/// Same bound for int8 KV: symmetric per-vector quantization at a
/// power-of-two scale carries ~1% relative error per stored element
/// (max `scale/2` with `scale <= 2 * max_abs / 127`), coarser than
/// bf16's 7-bit mantissa, so the logit bound is looser again.
const INT8_TOL: f32 = 4e-1;

fn tiny_model(norm: &str, seed: u64) -> NativeModel {
    let cfg = ModelConfig::builtin("tiny", norm).unwrap();
    let store = ParamStore::init(&cfg, seed).unwrap();
    NativeModel::from_params(&cfg, &store.order, &store.params).unwrap()
}

fn kv_cfg(dtype: KvDtype, block_tokens: usize) -> KvCacheConfig {
    KvCacheConfig { dtype, block_tokens, mem_bytes: None }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let denom = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() / denom <= tol,
            "{what}[{i}]: paged {x} vs dense {y} (tol {tol})"
        );
    }
}

/// Drive a dense and a paged session through the same greedy decode
/// (tokens picked from the dense logits, so the two stay aligned even
/// at reduced precision) and compare logits each step.
fn compare_greedy(
    norm: &str,
    dtype: KvDtype,
    block_tokens: usize,
    prompt_len: usize,
    steps: usize,
    tol: Option<f32>,
) {
    let m = tiny_model(norm, 11);
    let prompt: Vec<i32> =
        (0..prompt_len).map(|i| ((i * 37 + 5) % 256) as i32).collect();

    let mut dense = DecodeSession::new(&m.cfg, 1);
    let mut paged =
        DecodeSession::new_paged(&m.cfg, 1, &kv_cfg(dtype, block_tokens))
            .unwrap();
    let mut dl = m.prefill(&mut dense, &[prompt.clone()]).unwrap();
    let pl = m.prefill(&mut paged, &[prompt]).unwrap();
    let tag = format!("{norm}/{dtype:?}/bt{block_tokens}");
    match tol {
        None => assert_eq!(dl, pl, "{tag}: prefill not bitwise"),
        Some(t) => assert_close(&pl, &dl, t, &format!("{tag}: prefill")),
    }
    assert_eq!(paged.len_of(0), dense.len_of(0));

    for step in 0..steps {
        let next = argmax(&dl) as i32;
        dl = m.decode_step(&mut dense, &[next]).unwrap();
        let pl = m.decode_step(&mut paged, &[next]).unwrap();
        match tol {
            None => assert_eq!(dl, pl, "{tag}: step {step} not bitwise"),
            Some(t) => {
                assert_close(&pl, &dl, t, &format!("{tag}: step {step}"))
            }
        }
    }
}

#[test]
fn paged_f32_bitwise_matches_dense_within_ctx() {
    for norm in NORMALIZERS {
        // 16 prompt + 32 generated = 48 < ctx (64): incremental path,
        // one divisor block size and one that straddles block edges
        for bt in [16usize, 5] {
            compare_greedy(norm, KvDtype::F32, bt, 16, 32, None);
        }
    }
}

#[test]
fn paged_f32_bitwise_matches_dense_past_ctx() {
    for norm in NORMALIZERS {
        // 58 prompt + 14 generated crosses ring eviction + window
        // re-encode; block size 16 divides ctx, 7 does not
        for bt in [16usize, 7] {
            compare_greedy(norm, KvDtype::F32, bt, 58, 14, None);
        }
    }
}

#[test]
fn paged_f32_handles_overlong_prompt_and_tiny_blocks() {
    // prompt longer than ctx clamps to the trailing window, same as the
    // dense path; block size 1 is the worst-case table length
    compare_greedy("consmax", KvDtype::F32, 1, 100, 6, None);
    let m = tiny_model("consmax", 11);
    let long: Vec<i32> = (0..100).map(|i| ((i * 13 + 1) % 256) as i32).collect();
    let mut paged =
        DecodeSession::new_paged(&m.cfg, 1, &kv_cfg(KvDtype::F32, 16)).unwrap();
    let pl = m.prefill(&mut paged, &[long.clone()]).unwrap();
    let oracle = m.next_logits(&[long]).unwrap();
    assert_eq!(pl, oracle, "overlong paged prefill vs recompute oracle");
    assert_eq!(paged.len_of(0), m.cfg.ctx);
}

#[test]
fn reduced_precision_kv_stays_close_to_dense() {
    for norm in NORMALIZERS {
        compare_greedy(norm, KvDtype::F16, 16, 20, 12, Some(F16_TOL));
        compare_greedy(norm, KvDtype::Bf16, 16, 20, 12, Some(BF16_TOL));
        compare_greedy(norm, KvDtype::Int8, 16, 20, 12, Some(INT8_TOL));
    }
    // and across an eviction re-encode
    compare_greedy("consmax", KvDtype::F16, 16, 60, 8, Some(F16_TOL));
    compare_greedy("consmax", KvDtype::Int8, 16, 60, 8, Some(INT8_TOL));
    // block sizes that straddle block edges must quantize identically
    // (scales are per head_dim vector, not per block, so geometry is
    // irrelevant to the stored values)
    compare_greedy("consmax", KvDtype::Int8, 5, 20, 8, Some(INT8_TOL));
}

#[test]
fn prefix_sharing_shares_blocks_and_changes_no_bits() {
    let m = tiny_model("consmax", 5);
    // 40 tokens at block 8 = 5 full blocks; the sharer may take at most
    // 4 (one token must stay computable for logits)
    let prompt: Vec<i32> = (0..40).map(|i| ((i * 7 + 3) % 256) as i32).collect();
    let kv = kv_cfg(KvDtype::F32, 8);

    let mut dense = DecodeSession::new(&m.cfg, 2);
    let mut paged = DecodeSession::new_paged(&m.cfg, 2, &kv).unwrap();
    let dl = m
        .prefill(&mut dense, &[prompt.clone(), prompt.clone()])
        .unwrap();
    let pl = m
        .prefill(&mut paged, &[prompt.clone(), prompt.clone()])
        .unwrap();
    assert_eq!(dl, pl, "shared-prefix prefill not bitwise");

    let st = paged.kv_stats().unwrap();
    assert_eq!(st.shared_blocks, 4, "prefix blocks not shared: {st:?}");
    // row 0: 5 blocks; row 1: 4 shared + 1 fresh = 6 distinct in use
    assert_eq!(st.used_blocks, 6, "{st:?}");

    // rows diverge after the shared prefix; CoW keeps them isolated
    let v = m.cfg.vocab;
    let mut dl = dl;
    for step in 0..10 {
        let t0 = argmax(&dl[..v]) as i32;
        let t1 = (argmax(&dl[v..]) as i32 + 1 + step) % 256; // diverge
        dl = m.decode_step(&mut dense, &[t0, t1]).unwrap();
        let pl = m.decode_step(&mut paged, &[t0, t1]).unwrap();
        assert_eq!(dl, pl, "post-share step {step} not bitwise");
    }

    // drain: every reference returns, nothing stays shared
    paged.reset_row(0);
    paged.reset_row(1);
    let st = paged.kv_stats().unwrap();
    assert_eq!(st.free_blocks, st.total_blocks, "pool did not drain: {st:?}");
    assert_eq!(st.shared_blocks, 0);
}

#[test]
fn int8_prefix_sharing_is_bitwise_against_an_int8_solo_session() {
    // the dense-f32 oracle can't pin lossy int8 storage, so the oracle
    // here is a solo paged-int8 session with the identical pool config:
    // sharing and copy-on-write must not change which codes a row reads
    let m = tiny_model("consmax", 5);
    let prompt: Vec<i32> =
        (0..40).map(|i| ((i * 7 + 3) % 256) as i32).collect();
    let kv = kv_cfg(KvDtype::Int8, 8);

    let mut solo = DecodeSession::new_paged(&m.cfg, 1, &kv).unwrap();
    let mut shared = DecodeSession::new_paged(&m.cfg, 2, &kv).unwrap();
    let mut sl = m.prefill(&mut solo, &[prompt.clone()]).unwrap();
    let mut pl = m
        .prefill(&mut shared, &[prompt.clone(), prompt.clone()])
        .unwrap();
    let v = m.cfg.vocab;
    assert_eq!(sl[..], pl[..v], "row 0 prefill not bitwise vs solo");
    assert_eq!(sl[..], pl[v..], "row 1 prefill not bitwise vs solo");
    assert!(
        shared.kv_stats().unwrap().shared_blocks > 0,
        "prefix not shared"
    );

    // row 0 follows the solo greedy stream; row 1 diverges, exercising
    // copy-on-write (codes *and* scales) without touching row 0's bits
    for step in 0..10 {
        let t0 = argmax(&sl) as i32;
        let t1 = (t0 + 1 + step as i32) % 256;
        sl = m.decode_step(&mut solo, &[t0]).unwrap();
        pl = m.decode_step(&mut shared, &[t0, t1]).unwrap();
        assert_eq!(sl[..], pl[..v], "row 0 step {step} not bitwise vs solo");
    }
}

#[test]
fn int8_shared_rows_survive_eviction_reencode_bitwise_vs_solo() {
    // full-ctx shared prompt decoded past ctx with int8 blocks: the
    // eviction re-encode privatizes and re-quantizes every window, and
    // the shared row must keep emitting exactly the solo session's bits
    let m = tiny_model("consmax", 9);
    let prompt: Vec<i32> =
        (0..m.cfg.ctx).map(|i| ((i * 11 + 2) % 256) as i32).collect();
    let kv = kv_cfg(KvDtype::Int8, 8);

    let mut solo = DecodeSession::new_paged(&m.cfg, 1, &kv).unwrap();
    let mut shared = DecodeSession::new_paged(&m.cfg, 2, &kv).unwrap();
    let mut sl = m.prefill(&mut solo, &[prompt.clone()]).unwrap();
    let mut pl = m
        .prefill(&mut shared, &[prompt.clone(), prompt.clone()])
        .unwrap();
    let v = m.cfg.vocab;
    assert_eq!(sl[..], pl[..v]);
    assert!(shared.kv_stats().unwrap().shared_blocks > 0);

    for step in 0..5 {
        let t0 = argmax(&sl) as i32;
        let t1 = (t0 + 13) % 256;
        sl = m.decode_step(&mut solo, &[t0]).unwrap();
        pl = m.decode_step(&mut shared, &[t0, t1]).unwrap();
        assert_eq!(sl[..], pl[..v], "eviction step {step} not bitwise");
    }
}

#[test]
fn shared_rows_survive_eviction_reencode() {
    // two rows share a full-ctx prompt (7 of 8 blocks shared), then
    // decode past ctx: the re-encode privatizes the shared blocks and
    // both rows keep emitting the exact dense logits
    let m = tiny_model("softermax", 9);
    let prompt: Vec<i32> =
        (0..m.cfg.ctx).map(|i| ((i * 11 + 2) % 256) as i32).collect();
    let kv = kv_cfg(KvDtype::F32, 8);

    let mut dense = DecodeSession::new(&m.cfg, 2);
    let mut paged = DecodeSession::new_paged(&m.cfg, 2, &kv).unwrap();
    let mut dl = m
        .prefill(&mut dense, &[prompt.clone(), prompt.clone()])
        .unwrap();
    let pl = m
        .prefill(&mut paged, &[prompt.clone(), prompt.clone()])
        .unwrap();
    assert_eq!(dl, pl);
    assert!(paged.kv_stats().unwrap().shared_blocks > 0);

    let v = m.cfg.vocab;
    for step in 0..5 {
        let t0 = argmax(&dl[..v]) as i32;
        let t1 = (t0 + 13) % 256;
        dl = m.decode_step(&mut dense, &[t0, t1]).unwrap();
        let pl = m.decode_step(&mut paged, &[t0, t1]).unwrap();
        assert_eq!(dl, pl, "eviction step {step} not bitwise");
    }
    // divergent windows: nothing can stay shared after both re-encoded
    assert_eq!(paged.kv_stats().unwrap().shared_blocks, 0);
}

#[test]
fn budget_below_one_row_is_rejected() {
    let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
    let kv = KvCacheConfig {
        dtype: KvDtype::F32,
        block_tokens: 16,
        mem_bytes: Some(1024), // far below one 64-token row
    };
    assert!(DecodeSession::new_paged(&cfg, 1, &kv).is_err());
}

/// Greedy single-request reference: the static oracle at batch 1.
fn oracle_tokens(
    cfg: &ModelConfig,
    store: &ParamStore,
    prompt: &str,
    max_new: usize,
) -> Vec<i32> {
    let mut g = Generator::native(cfg, store, 0).unwrap();
    g.generate_batch_ext(&[prompt.to_string()], &[max_new], &[0.0])
        .unwrap()
        .tokens
        .remove(0)
}

#[test]
fn paged_server_preempts_under_pressure_without_changing_outputs() {
    let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
    let store = ParamStore::init(&cfg, 5).unwrap();
    // 6 f32 blocks of 16 tokens: room for one long row plus change.
    // Requests grow to ~50 cached tokens (4 blocks) each, so two
    // concurrent residents must collide and trigger preemption.
    let block_bytes =
        2 * cfg.n_layer * cfg.n_head * 16 * cfg.head_dim() * 4;
    let kv = KvCacheConfig {
        dtype: KvDtype::F32,
        block_tokens: 16,
        mem_bytes: Some(6 * block_bytes),
    };
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    server.set_kv_config(Some(kv)).unwrap();
    server.set_max_batch(4).unwrap();

    let prompt = "a twenty byte prompt"; // 20 tokens -> 2 blocks at join
    for id in 0..4u64 {
        server.submit(GenRequest {
            id,
            prompt: prompt.into(),
            max_new_tokens: 30,
            temperature: 0.0,
            stop: None,
            deadline_ms: None,
        });
    }
    let mut responses = server.run_continuous().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 4);
    let want = oracle_tokens(&cfg, &store, prompt, 30);
    for r in &responses {
        assert_eq!(
            r.tokens, want,
            "req {}: preemption changed the output",
            r.id
        );
    }
    let st = server.stats();
    assert!(
        st.preemptions > 0,
        "budget of 6 blocks never preempted: {st:?}"
    );
    assert_eq!(st.kv_free_blocks, st.kv_total_blocks, "pool did not drain");
}

#[test]
fn paged_server_without_budget_matches_oracle_on_a_mixed_queue() {
    // budgetless paged pool (sharing + paging, no pressure): every
    // request must match its solo static oracle bit for bit
    let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
    let store = ParamStore::init(&cfg, 5).unwrap();
    let reqs = [
        ("The constant softmax ", 9usize),
        ("The constant softmax ", 4), // shares the full prefix
        ("Attention ", 1),
        ("x", 6),
        ("A much longer prompt that spans a few more byte tokens ", 12),
    ];
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    server
        .set_kv_config(Some(kv_cfg(KvDtype::F32, 8)))
        .unwrap();
    server.set_max_batch(3).unwrap();
    for (id, (prompt, max_new)) in reqs.iter().enumerate() {
        server.submit(GenRequest {
            id: id as u64,
            prompt: (*prompt).into(),
            max_new_tokens: *max_new,
            temperature: 0.0,
            stop: None,
            deadline_ms: None,
        });
    }
    let mut responses = server.run_continuous().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), reqs.len());
    for (r, (prompt, max_new)) in responses.iter().zip(&reqs) {
        let want = oracle_tokens(&cfg, &store, prompt, *max_new);
        assert_eq!(r.tokens, want, "req {} diverged on the paged pool", r.id);
    }
}
