//! Central-finite-difference gradcheck for the native training stack
//! (DESIGN.md §Training seam): every parameter tensor of every
//! normalizer in the zoo, checked end-to-end through
//! `NativeModel::forward_train` + `backward`.
//!
//! Strategy: per tensor, one random ±1/√n direction `u`; the analytic
//! directional derivative `Σ g·u` must match the central difference
//! `(L(θ+hu) − L(θ−hu)) / 2h` within `1e-3 · max(1, |an|, |fd|)`.
//! Directional probes keep the whole check to two extra forwards per
//! tensor while still touching every element of every gradient (the
//! per-element rules are additionally pinned by the unit FD tests in
//! `native.rs` / `normalizer.rs`).
//!
//! γ is pinned to 2.0 for the check: at the paper's γ=100 init the
//! per-element dγ ≈ −dot/γ is ~1e-4 of the score gradient and f32
//! forward noise would swamp the finite difference, telling us nothing.

use consmax::config::ModelConfig;
use consmax::coordinator::ParamStore;
use consmax::runtime::backend::NativeModel;
use consmax::runtime::HostTensor;
use consmax::util::rng::Pcg32;

const NORMALIZERS: [&str; 5] =
    ["consmax", "softmax", "softermax", "consmax-v2", "ssmax"];
const B: usize = 2;
const T: usize = 8;
const H: f32 = 1e-2;

fn loss_with_perturbation(
    cfg: &ModelConfig,
    store: &ParamStore,
    idx: usize,
    dir: &[f32],
    h: f32,
    x: &[i32],
    y: &[i32],
) -> f64 {
    let mut params = store.params.clone();
    let shape = params[idx].shape.clone();
    let mut p = params[idx].as_f32().unwrap();
    for (pv, &u) in p.iter_mut().zip(dir) {
        *pv += h * u;
    }
    params[idx] = HostTensor::from_f32(&p, &shape);
    let m = NativeModel::from_params(cfg, &store.order, &params).unwrap();
    m.forward_train(x, y, B, T).unwrap().loss
}

#[test]
fn gradcheck_every_tensor_of_every_normalizer() {
    for norm in NORMALIZERS {
        let cfg = ModelConfig::builtin("tiny", norm).unwrap();
        let mut store = ParamStore::init(&cfg, 5).unwrap();
        store.pin_beta_gamma(0.8, 2.0);

        let mut rng = Pcg32::seeded(11);
        let x: Vec<i32> =
            (0..B * T).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
        let y: Vec<i32> =
            (0..B * T).map(|_| rng.below(cfg.vocab as u64) as i32).collect();

        let model =
            NativeModel::from_params(&cfg, &store.order, &store.params).unwrap();
        let tape = model.forward_train(&x, &y, B, T).unwrap();
        let grads = model.backward(&tape, &x, &y).unwrap();

        for (idx, name) in store.order.iter().enumerate() {
            let g = &grads[name];
            let n = g.len() as f32;
            let dir: Vec<f32> = (0..g.len())
                .map(|_| {
                    let sign = if rng.below(2) == 0 { 1.0f32 } else { -1.0 };
                    sign / n.sqrt()
                })
                .collect();
            let df_an: f64 = g
                .iter()
                .zip(&dir)
                .map(|(&gv, &u)| gv as f64 * u as f64)
                .sum();
            let lp = loss_with_perturbation(&cfg, &store, idx, &dir, H, &x, &y);
            let lm = loss_with_perturbation(&cfg, &store, idx, &dir, -H, &x, &y);
            let df_fd = (lp - lm) / (2.0 * H as f64);
            let tol = 1e-3 * df_an.abs().max(df_fd.abs()).max(1.0);
            assert!(
                (df_an - df_fd).abs() <= tol,
                "{norm}/{name}: analytic {df_an:.6e} vs finite-diff \
                 {df_fd:.6e} (|err| {:.2e} > tol {tol:.2e})",
                (df_an - df_fd).abs()
            );
        }
    }
}

#[test]
fn normalizer_learnables_receive_nonzero_gradients() {
    // the zoo's own parameters actually train: β/γ for the consmax
    // family, the ssmax scale — and stay exactly zero where the
    // normalizer doesn't own them (softmax/softermax carry β/γ tensors
    // for schema parity but must not move them)
    let mut rng = Pcg32::seeded(3);
    let x: Vec<i32> = (0..B * T).map(|_| rng.below(256) as i32).collect();
    let y: Vec<i32> = (0..B * T).map(|_| rng.below(256) as i32).collect();
    for norm in NORMALIZERS {
        let cfg = ModelConfig::builtin("tiny", norm).unwrap();
        let mut store = ParamStore::init(&cfg, 9).unwrap();
        store.pin_beta_gamma(0.8, 2.0);
        let model =
            NativeModel::from_params(&cfg, &store.order, &store.params).unwrap();
        let tape = model.forward_train(&x, &y, B, T).unwrap();
        let grads = model.backward(&tape, &x, &y).unwrap();
        let beta_gamma_flow = matches!(norm, "consmax" | "consmax-v2");
        assert_eq!(
            grads["beta"].iter().any(|&v| v != 0.0),
            beta_gamma_flow,
            "{norm}: beta grad"
        );
        assert_eq!(
            grads["gamma"].iter().any(|&v| v != 0.0),
            beta_gamma_flow,
            "{norm}: gamma grad"
        );
        if norm == "ssmax" {
            assert!(
                grads["ssmax_s"].iter().any(|&v| v != 0.0),
                "ssmax: scale grad"
            );
        }
    }
}
