//! Chaos suite for the hardened serving stack (ISSUE: robustness PR).
//!
//! The invariant under test, at every level: **every submitted request
//! reaches exactly one terminal state** — completed, shed, timed out,
//! or cancelled — with no leaked slots, no leaked paged-KV blocks, and
//! no stats drift (`completed + shed + timed_out + cancelled ==
//! submitted`), and survivors decode **bit-identically** to a no-fault
//! solo oracle (the repo's signature-oracle pattern).
//!
//! Three layers:
//!
//! 1. Engine level (`Server` directly): deadlines, mid-flight cancels,
//!    contained worker panics, KV-pressure spikes, degenerate budgets,
//!    plus a randomized-churn property over all of it.
//! 2. Wire level with a mock engine: slow-reader eviction and the
//!    [`FaultPlan`] injected mid-stream disconnect, where the real
//!    model would only add noise.
//! 3. Full TCP integration: real sockets against `serve_net::serve`
//!    over the `EngineAdapter` — streaming, malformed requests,
//!    client disconnects, load shedding with `Retry-After`, and
//!    graceful drain answering 503.
//!
//! The network tests share process-global drain state, so they
//! serialize on [`NET_LOCK`] and re-arm with `reset_drain`.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use anyhow::Result;
use consmax::config::{KvCacheConfig, ModelConfig};
use consmax::coordinator::{
    Admission, EngineAdapter, GenRequest, GenResponse, Generator,
    ParamStore, ServeEvent, Server,
};
use consmax::prop_assert;
use consmax::runtime::backend::KvGeometry;
use consmax::runtime::parallel;
use consmax::runtime::serve_net::{
    self, FaultPlan, NetAdmission, NetEvent, NetOptions, NetRequest,
    ServeEngine,
};
use consmax::util::proptest::run_property;

fn setup() -> (ModelConfig, ParamStore) {
    let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
    let store = ParamStore::init(&cfg, 5).unwrap();
    (cfg, store)
}

fn greedy(id: u64, prompt: &str, max_new: usize) -> GenRequest {
    GenRequest::greedy(id, prompt, max_new)
}

/// Greedy single-request reference: the static oracle at batch 1.
fn oracle_tokens(
    cfg: &ModelConfig,
    store: &ParamStore,
    prompt: &str,
    max_new: usize,
) -> Vec<i32> {
    let mut g = Generator::native(cfg, store, 0).unwrap();
    g.generate_batch_ext(&[prompt.to_string()], &[max_new], &[0.0])
        .unwrap()
        .tokens
        .remove(0)
}

/// Step until the server is empty (bounded: chaos must not livelock).
fn drain_server(server: &mut Server<'_>) -> Vec<GenResponse> {
    let mut out = Vec::new();
    for _ in 0..500 {
        if server.pending() + server.in_flight() == 0 {
            return out;
        }
        out.extend(server.step().unwrap());
    }
    panic!(
        "server failed to drain in 500 steps: {} pending, {} in flight",
        server.pending(),
        server.in_flight()
    );
}

/// Accounting closure + paged-pool leak check, asserted at drain.
fn assert_closed(server: &Server<'_>) {
    assert_eq!(
        server.submitted,
        server.completed + server.shed + server.timed_out + server.cancelled,
        "terminal-state accounting must close"
    );
    let st = server.stats();
    assert_eq!(server.pending(), 0);
    assert_eq!(server.in_flight(), 0);
    if st.kv_paged {
        assert_eq!(
            st.kv_free_blocks, st.kv_total_blocks,
            "paged KV blocks leaked past drain"
        );
    }
}

/// Fold captured events: (terminal events per id, token events per id).
fn fold_events(
    events: &[ServeEvent],
) -> (HashMap<u64, usize>, HashMap<u64, usize>) {
    let mut terminals: HashMap<u64, usize> = HashMap::new();
    let mut tokens: HashMap<u64, usize> = HashMap::new();
    for ev in events {
        match ev {
            ServeEvent::Token { id, .. } => *tokens.entry(*id).or_insert(0) += 1,
            _ => *terminals.entry(ev.id()).or_insert(0) += 1,
        }
    }
    (terminals, tokens)
}

// ---- engine-level chaos ---------------------------------------------------

#[test]
fn zero_deadline_times_out_before_taking_a_slot() {
    let (cfg, store) = setup();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    server.set_event_capture(true);
    for id in 0..3 {
        let mut req = greedy(id, "doomed ", 8);
        req.deadline_ms = Some(0);
        server.submit(req);
    }
    let responses = drain_server(&mut server);
    assert!(responses.is_empty());
    assert_eq!(server.timed_out, 3);
    assert_closed(&server);
    let (terminals, tokens) = fold_events(&server.drain_events());
    assert_eq!(terminals.len(), 3);
    assert!(terminals.values().all(|&n| n == 1));
    assert!(tokens.is_empty(), "timed-out requests must stream nothing");
}

#[test]
fn deadline_drops_a_resident_mid_flight_and_frees_its_kv() {
    let (cfg, store) = setup();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    let mut kv = KvCacheConfig::default();
    kv.block_tokens = 8;
    server.set_kv_config(Some(kv)).unwrap();
    server.set_event_capture(true);
    // the victim gets a deadline it will blow mid-decode; the survivor
    // must come out bit-identical to its solo oracle anyway
    let mut victim = greedy(0, "victim with a long budget ", 48);
    victim.deadline_ms = Some(1); // lapses after the first step's work
    server.submit(victim);
    server.submit(greedy(1, "survivor ", 6));
    server.step().unwrap(); // both join, victim's deadline starts burning
    std::thread::sleep(Duration::from_millis(2));
    let responses = drain_server(&mut server);
    assert_eq!(server.timed_out, 1, "victim should lapse mid-flight");
    assert_eq!(responses.len(), 1);
    assert_eq!(responses[0].id, 1);
    assert_eq!(
        responses[0].tokens,
        oracle_tokens(&cfg, &store, "survivor ", 6),
        "survivor diverged from the no-fault oracle"
    );
    assert_closed(&server);
}

#[test]
fn cancel_frees_queued_and_resident_requests() {
    let (cfg, store) = setup();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    let mut kv = KvCacheConfig::default();
    kv.block_tokens = 8;
    server.set_kv_config(Some(kv)).unwrap();
    server.set_event_capture(true);
    for id in 0..4 {
        server.submit(greedy(id, "cancel target ", 24));
    }
    server.step().unwrap();
    assert!(server.cancel(0), "resident cancel");
    assert!(server.cancel(3), "cancel works wherever the request lives");
    assert_eq!(server.cancelled, 2);
    assert!(!server.cancel(0), "double cancel must be a no-op");
    let responses = drain_server(&mut server);
    assert_eq!(
        responses.len() as u64 + server.cancelled,
        4,
        "every request is either served or cancelled"
    );
    for r in &responses {
        assert_eq!(
            r.tokens,
            oracle_tokens(&cfg, &store, "cancel target ", 24),
            "survivor {} diverged after neighbor cancellation",
            r.id
        );
    }
    assert_closed(&server);
}

#[test]
fn contained_worker_panic_replays_residents_bit_identically() {
    let (cfg, store) = setup();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    server.set_event_capture(true);
    let cases = [("panic survivor A ", 10usize), ("B ", 4), ("longer C ", 14)];
    for (id, (prompt, max_new)) in cases.iter().enumerate() {
        server.submit(greedy(id as u64, prompt, *max_new));
    }
    server.step().unwrap(); // all resident
    parallel::inject_worker_panic_once();
    server.step().unwrap(); // panic fires, is contained, residents requeue
    assert_eq!(server.panics_recovered, 1);
    let responses = drain_server(&mut server);
    assert_eq!(responses.len(), cases.len());
    let mut responses = responses;
    responses.sort_by_key(|r| r.id);
    for (r, (prompt, max_new)) in responses.iter().zip(&cases) {
        assert_eq!(
            r.tokens,
            oracle_tokens(&cfg, &store, prompt, *max_new),
            "request {} not replay-deterministic after panic recovery",
            r.id
        );
    }
    // exactly-once token streaming across the replay: the watermark
    // suppresses the re-emitted prefix
    let (terminals, tokens) = fold_events(&server.drain_events());
    for r in &responses {
        assert_eq!(terminals.get(&r.id), Some(&1));
        assert_eq!(
            tokens.get(&r.id).copied().unwrap_or(0),
            r.new_tokens,
            "request {} streamed a duplicated or missing token",
            r.id
        );
    }
    assert_closed(&server);
}

#[test]
fn kv_pressure_spike_preempts_but_every_request_completes() {
    let (cfg, store) = setup();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    let mut kv = KvCacheConfig::default();
    kv.block_tokens = 8;
    // room for ~2 worst-case rows: a 6-deep queue must squeeze through
    let geo = KvGeometry::of(&cfg, &kv);
    kv.mem_bytes = Some(2 * geo.blocks_per_row * geo.block_bytes);
    server.set_kv_config(Some(kv)).unwrap();
    for id in 0..6 {
        server.submit(greedy(id, "pressure ", 20));
    }
    let responses = drain_server(&mut server);
    assert_eq!(responses.len(), 6);
    let want = oracle_tokens(&cfg, &store, "pressure ", 20);
    for r in &responses {
        assert_eq!(r.tokens, want, "request {} diverged under pressure", r.id);
    }
    assert_closed(&server);
}

#[test]
fn chaos_storm_every_request_reaches_exactly_one_terminal_state() {
    // everything at once: tight paged budget (preemptions), a zero
    // deadline, a mid-flight cancel, degenerate requests, a contained
    // worker panic, and a late joiner — accounting must close, blocks
    // must return, survivors must match their solo oracles
    let (cfg, store) = setup();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    let mut kv = KvCacheConfig::default();
    kv.block_tokens = 8;
    let geo = KvGeometry::of(&cfg, &kv);
    kv.mem_bytes = Some(2 * geo.blocks_per_row * geo.block_bytes);
    server.set_kv_config(Some(kv)).unwrap();
    server.set_event_capture(true);
    server.set_admission_limits(Some(16), None);

    let survivors = [
        (0u64, "storm survivor zero ", 12usize),
        (1, "one ", 5),
        (2, "a rather longer storm prompt two ", 18),
        (4, "four ", 9),
    ];
    for (id, prompt, max_new) in &survivors {
        assert_eq!(
            server.try_submit(greedy(*id, prompt, *max_new)),
            Admission::Admitted
        );
    }
    server.submit(greedy(3, "cancel victim ", 24));
    let mut doomed = greedy(10, "deadline victim ", 24);
    doomed.deadline_ms = Some(0);
    server.submit(doomed);
    server.submit(greedy(11, "", 4)); // empty prompt: completes untouched
    server.submit(greedy(12, "zero budget ", 0)); // completes with 0 tokens

    let mut responses = Vec::new();
    responses.extend(server.step().unwrap());
    assert!(server.cancel(3), "victim must be cancellable wherever it is");
    parallel::inject_worker_panic_once();
    responses.extend(server.step().unwrap());
    assert_eq!(server.panics_recovered, 1);
    // late joiner lands after the recovery requeue
    server.submit(greedy(5, "late storm joiner ", 7));
    responses.extend(drain_server(&mut server));

    assert_eq!(server.timed_out, 1);
    assert_eq!(server.cancelled, 1);
    assert_closed(&server);

    let mut by_id: HashMap<u64, GenResponse> =
        responses.into_iter().map(|r| (r.id, r)).collect();
    for (id, prompt, max_new) in &survivors {
        let r = by_id.remove(id).expect("survivor response");
        assert_eq!(
            r.tokens,
            oracle_tokens(&cfg, &store, prompt, *max_new),
            "survivor {id} diverged from its no-fault oracle"
        );
    }
    let late = by_id.remove(&5).expect("late joiner response");
    assert_eq!(
        late.tokens,
        oracle_tokens(&cfg, &store, "late storm joiner ", 7)
    );
    assert!(by_id.remove(&11).is_some(), "degenerate empty prompt completes");
    assert!(by_id.remove(&12).is_some(), "degenerate zero budget completes");
    assert!(by_id.is_empty(), "unexpected extra responses: {by_id:?}");

    // exactly one terminal event per non-shed request, tokens
    // exactly-once per position despite the panic replay
    let (terminals, tokens) = fold_events(&server.drain_events());
    assert_eq!(
        terminals.len() as u64,
        server.completed + server.timed_out + server.cancelled
    );
    assert!(terminals.values().all(|&n| n == 1), "duplicate terminal event");
    for (id, prompt, max_new) in &survivors {
        let want = oracle_tokens(&cfg, &store, prompt, *max_new).len();
        assert_eq!(
            tokens.get(id).copied().unwrap_or(0),
            want,
            "survivor {id} token stream not exactly-once"
        );
    }
}

// ---- satellite: degenerate paged budgets ----------------------------------

#[test]
fn kv_budget_below_one_row_is_rejected_at_config_time() {
    let (cfg, store) = setup();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    let mut kv = KvCacheConfig::default();
    kv.mem_bytes = Some(1024); // less than a single block
    let err = server.set_kv_config(Some(kv)).unwrap_err().to_string();
    assert!(
        err.contains("kv budget too small"),
        "want a clear config-time rejection, got: {err}"
    );
    // the server remains usable on the dense layout after the rejection
    server.submit(greedy(0, "still alive ", 4));
    let responses = drain_server(&mut server);
    assert_eq!(responses.len(), 1);
}

#[test]
fn one_row_kv_budget_serves_a_worst_case_request_without_livelock() {
    // the zero-progress edge: the pool holds exactly one worst-case
    // row, so requests must run strictly one at a time — and finish
    let (cfg, store) = setup();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    let mut kv = KvCacheConfig::default();
    kv.block_tokens = 8;
    let geo = KvGeometry::of(&cfg, &kv);
    kv.mem_bytes = Some(geo.blocks_per_row * geo.block_bytes);
    server.set_kv_config(Some(kv)).unwrap();
    // worst case: prompt + budget saturate the context window
    let long_prompt = "x".repeat(cfg.ctx - 8);
    server.submit(greedy(0, &long_prompt, 8));
    server.submit(greedy(1, "queued behind the giant ", 6));
    let mut responses = drain_server(&mut server);
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 2);
    assert!(responses[0].new_tokens > 0, "giant request made no progress");
    assert_eq!(
        responses[1].tokens,
        oracle_tokens(&cfg, &store, "queued behind the giant ", 6)
    );
    assert_closed(&server);
}

// ---- satellite: accounting property under randomized churn ----------------

#[test]
fn accounting_closes_under_randomized_churn() {
    let (cfg, store) = setup();
    run_property("serve_terminal_accounting", 6, |g| {
        let mut server =
            Server::new(Generator::native(&cfg, &store, 3).unwrap());
        server.set_event_capture(true);
        server.set_admission_limits(Some(g.usize(1, 5)), None);
        if g.bool() {
            let mut kv = KvCacheConfig::default();
            kv.block_tokens = 8;
            let geo = KvGeometry::of(&cfg, &kv);
            kv.mem_bytes = Some(
                g.usize(1, 4) * geo.blocks_per_row * geo.block_bytes,
            );
            server.set_kv_config(Some(kv)).map_err(|e| e.to_string())?;
        }
        let n = g.usize(3, 12) as u64;
        for id in 0..n {
            let mut req = greedy(
                id,
                ["a ", "bb ", "longer prompt ", ""][g.usize(0, 4)],
                g.usize(0, 12),
            );
            req.deadline_ms = match g.usize(0, 3) {
                0 => Some(0),     // dies in the sweep
                1 => Some(60_000), // never lapses in-test
                _ => None,
            };
            let _ = server.try_submit(req);
            // interleave: occasional step, occasional cancel of a
            // random earlier id (may already be terminal: no-op)
            if g.bool() {
                server.step().map_err(|e| e.to_string())?;
            }
            if g.bool() {
                server.cancel(g.u64(0, n.max(2)));
            }
        }
        for _ in 0..500 {
            if server.pending() + server.in_flight() == 0 {
                break;
            }
            server.step().map_err(|e| e.to_string())?;
        }
        prop_assert!(
            server.pending() + server.in_flight() == 0,
            "failed to drain"
        );
        prop_assert!(
            server.submitted
                == server.completed
                    + server.shed
                    + server.timed_out
                    + server.cancelled,
            "accounting drift: submitted {} completed {} shed {} \
             timed_out {} cancelled {}",
            server.submitted,
            server.completed,
            server.shed,
            server.timed_out,
            server.cancelled
        );
        let st = server.stats();
        if st.kv_paged {
            prop_assert!(
                st.kv_free_blocks == st.kv_total_blocks,
                "leaked {} paged blocks",
                st.kv_total_blocks - st.kv_free_blocks
            );
        }
        let (terminals, _tokens) = fold_events(&server.drain_events());
        prop_assert!(
            terminals.values().all(|&c| c == 1),
            "duplicate terminal events"
        );
        prop_assert!(
            terminals.len() as u64
                == server.completed + server.timed_out + server.cancelled,
            "terminal events {} != terminal counters {}",
            terminals.len(),
            server.completed + server.timed_out + server.cancelled
        );
        Ok(())
    });
}

// ---- wire-level faults over a mock engine ---------------------------------

/// Scripted engine: each admitted request streams `per_tick` tokens per
/// tick until `total` are out, then completes. Lets the wire tests pin
/// slow-reader eviction and injected disconnects without model noise.
struct MockEngine {
    per_tick: usize,
    total: usize,
    live: Vec<(u64, usize)>, // (id, remaining)
    pub admitted: u64,
    pub cancelled: u64,
    pub completed: u64,
}

impl MockEngine {
    fn new(per_tick: usize, total: usize) -> MockEngine {
        MockEngine {
            per_tick,
            total,
            live: Vec::new(),
            admitted: 0,
            cancelled: 0,
            completed: 0,
        }
    }
}

impl ServeEngine for MockEngine {
    fn try_admit(&mut self, req: NetRequest) -> NetAdmission {
        self.admitted += 1;
        self.live.push((req.id, self.total));
        NetAdmission::Admitted
    }

    fn cancel(&mut self, id: u64) -> bool {
        if let Some(pos) = self.live.iter().position(|&(i, _)| i == id) {
            self.live.remove(pos);
            self.cancelled += 1;
            true
        } else {
            false
        }
    }

    fn tick(&mut self) -> Result<Vec<NetEvent>> {
        let mut events = Vec::new();
        let mut finished = Vec::new();
        for (id, remaining) in self.live.iter_mut() {
            let n = self.per_tick.min(*remaining);
            for _ in 0..n {
                events.push(NetEvent::Token { id: *id, token: 7 });
            }
            *remaining -= n;
            if *remaining == 0 {
                finished.push(*id);
            }
        }
        for id in finished {
            self.live.retain(|&(i, _)| i != id);
            self.completed += 1;
            events.push(NetEvent::Completed {
                id,
                text: String::from("mock"),
                tokens: self.total,
                latency_ms: 0.0,
            });
        }
        Ok(events)
    }

    fn has_work(&self) -> bool {
        !self.live.is_empty()
    }

    fn live_ids(&self) -> Vec<u64> {
        self.live.iter().map(|&(id, _)| id).collect()
    }

    fn stats_json(&self) -> String {
        format!(
            "{{\"admitted\":{},\"completed\":{},\"cancelled\":{}}}",
            self.admitted, self.completed, self.cancelled
        )
    }
}

/// The network tests mutate process-global drain state: serialize them.
fn net_lock() -> std::sync::MutexGuard<'static, ()> {
    static NET_LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let guard = NET_LOCK
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner());
    serve_net::reset_drain();
    guard
}

/// Minimal streaming client. Returns (status, raw header block, token
/// lines seen, saw a terminal line). `hang_up_after` drops the
/// connection after that many token lines.
fn http_generate(
    addr: &str,
    prompt: &str,
    max_new: usize,
    hang_up_after: Option<usize>,
) -> (u16, String, usize, bool) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(20)))
        .unwrap();
    let body = format!("{{\"prompt\":\"{prompt}\",\"max_new\":{max_new}}}");
    write!(
        stream,
        "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("send request");
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let mut headers = String::new();
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h).unwrap_or(0) == 0 || h.trim().is_empty() {
            break;
        }
        headers.push_str(&h);
    }
    let (mut tokens, mut terminal) = (0usize, false);
    if status == 200 {
        loop {
            let mut l = String::new();
            match reader.read_line(&mut l) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            if l.contains("\"token\"") {
                tokens += 1;
                if hang_up_after.is_some_and(|n| tokens >= n) {
                    return (status, headers, tokens, false);
                }
            } else if l.contains("\"done\"")
                || l.contains("\"timeout\"")
                || l.contains("\"cancelled\"")
            {
                terminal = true;
                break;
            }
        }
    }
    (status, headers, tokens, terminal)
}

#[test]
fn wire_slow_reader_is_evicted_not_buffered_unboundedly() {
    let _guard = net_lock();
    // firehose engine: one request streams far more bytes than any
    // socket buffer holds; the never-reading client must be evicted by
    // outbox overflow, not queued without bound
    let mut engine = MockEngine::new(8192, 4_000_000);
    let listener = serve_net::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(&addr).expect("connect");
        let body = "{\"prompt\":\"firehose\",\"max_new\":1}";
        write!(
            stream,
            "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .expect("send");
        stream.flush().unwrap();
        // never read: hold the socket open until the server gives up
        std::thread::sleep(Duration::from_secs(20));
    });
    let opts = NetOptions {
        outbox_cap: 2,
        max_requests: Some(1),
        drain_timeout_ms: 10_000,
        ..NetOptions::default()
    };
    let report = serve_net::serve(
        &mut engine,
        listener,
        &opts,
        &FaultPlan::default(),
    )
    .unwrap();
    assert_eq!(report.admitted, 1);
    assert_eq!(report.slow_readers, 1, "slow reader must be evicted");
    assert_eq!(engine.cancelled, 1, "eviction must cancel the request");
    assert!(!engine.has_work(), "no live request may remain");
    drop(client); // detached; exits on its own
}

#[test]
fn wire_fault_plan_disconnects_mid_stream_deterministically() {
    let _guard = net_lock();
    let mut engine = MockEngine::new(1, 50);
    let listener = serve_net::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let client = std::thread::spawn(move || {
        http_generate(&addr, "doomed stream", 50, None)
    });
    let opts = NetOptions {
        max_requests: Some(1),
        ..NetOptions::default()
    };
    let faults = FaultPlan {
        close_after_tokens: vec![(1, 3)], // first request, 3 tokens in
        ..FaultPlan::default()
    };
    let report =
        serve_net::serve(&mut engine, listener, &opts, &faults).unwrap();
    let (status, _headers, tokens, terminal) = client.join().unwrap();
    assert_eq!(status, 200);
    assert!(
        tokens <= 3,
        "connection should close right after the injected point"
    );
    assert!(!terminal, "no terminal line after an injected disconnect");
    assert_eq!(report.disconnects, 1);
    assert_eq!(engine.cancelled, 1);
}

// ---- full TCP integration over the real engine ----------------------------

fn real_adapter(
    cfg: &ModelConfig,
    store: &ParamStore,
    queue_cap: usize,
) -> EngineAdapter<'static> {
    let server = Server::new(Generator::native(cfg, store, 7).unwrap());
    EngineAdapter::new(server, Some(queue_cap), None, None).unwrap()
}

#[test]
fn tcp_streams_to_completion_and_drains_clean() {
    let _guard = net_lock();
    let (cfg, store) = setup();
    let mut engine = real_adapter(&cfg, &store, 32);
    let listener = serve_net::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = NetOptions {
        max_requests: Some(2),
        ..NetOptions::default()
    };
    let serve = std::thread::spawn(move || {
        let report = serve_net::serve(
            &mut engine,
            listener,
            &opts,
            &FaultPlan::default(),
        )
        .unwrap();
        (report, engine.into_server())
    });
    let (s1, _h1, t1, done1) = http_generate(&addr, "The attention ", 8, None);
    let (s2, _h2, t2, done2) = http_generate(&addr, "net two ", 5, None);
    let (report, server) = serve.join().unwrap();
    assert_eq!((s1, s2), (200, 200));
    assert!(done1 && done2, "both streams must end with a terminal line");
    assert_eq!(t1, 8, "expected 8 streamed tokens");
    assert_eq!(t2, 5);
    assert_eq!(report.admitted, 2);
    assert_eq!(report.completed, 2);
    assert!(report.drained_clean);
    assert_closed(&server);
    // streamed tokens match the solo oracle lengths — and the server's
    // own response content matched the oracle already at engine level
    assert_eq!(server.completed, 2);
}

#[test]
fn tcp_malformed_is_400_and_vanished_client_is_cancelled() {
    let _guard = net_lock();
    let (cfg, store) = setup();
    let mut engine = real_adapter(&cfg, &store, 32);
    let listener = serve_net::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = NetOptions {
        max_requests: Some(1),
        drain_timeout_ms: 10_000,
        ..NetOptions::default()
    };
    let serve = std::thread::spawn(move || {
        let report = serve_net::serve(
            &mut engine,
            listener,
            &opts,
            &FaultPlan::default(),
        )
        .unwrap();
        (report, engine.into_server())
    });
    // malformed request: answered 400 directly, never reaches the engine
    {
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        write!(stream, "BOGUS /nowhere HTTP/1.1\r\n\r\n").unwrap();
        let mut line = String::new();
        BufReader::new(stream).read_line(&mut line).unwrap();
        assert!(line.contains("400"), "want 400, got {line:?}");
    }
    // streaming client that vanishes two tokens in
    let (status, _headers, tokens, terminal) =
        http_generate(&addr, "vanishing client ", 30, Some(2));
    assert_eq!(status, 200);
    assert_eq!(tokens, 2);
    assert!(!terminal);
    let (report, server) = serve.join().unwrap();
    assert_eq!(report.rejected, 1, "malformed request must be counted");
    assert_eq!(report.admitted, 1);
    assert_eq!(report.disconnects, 1, "EOF must cancel the request");
    assert_eq!(server.cancelled, 1);
    assert_closed(&server);
}

#[test]
fn tcp_overload_sheds_with_retry_after_instead_of_queueing() {
    let _guard = net_lock();
    let (cfg, store) = setup();
    // queue_cap 0: the engine sheds every request — the pure shed path
    let mut engine = real_adapter(&cfg, &store, 0);
    let listener = serve_net::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = NetOptions {
        max_requests: Some(1),
        ..NetOptions::default()
    };
    let serve = std::thread::spawn(move || {
        let report = serve_net::serve(
            &mut engine,
            listener,
            &opts,
            &FaultPlan::default(),
        )
        .unwrap();
        (report, engine.into_server())
    });
    let (status, headers, _tokens, _terminal) =
        http_generate(&addr, "shed me ", 4, None);
    let (report, server) = serve.join().unwrap();
    assert_eq!(status, 429);
    assert!(
        headers.to_ascii_lowercase().contains("retry-after:"),
        "429 must carry Retry-After, got headers: {headers}"
    );
    assert_eq!(report.shed, 1);
    assert_eq!(report.admitted, 0);
    assert_eq!(server.shed, 1);
    assert_closed(&server);
}

#[test]
fn tcp_drain_refuses_new_work_with_503_and_finishes_residents() {
    let _guard = net_lock();
    let (cfg, store) = setup();
    let mut engine = real_adapter(&cfg, &store, 32);
    let listener = serve_net::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = NetOptions {
        drain_timeout_ms: 20_000,
        ..NetOptions::default() // no max_requests: drains on request
    };
    let serve = std::thread::spawn(move || {
        let report = serve_net::serve(
            &mut engine,
            listener,
            &opts,
            &FaultPlan::default(),
        )
        .unwrap();
        (report, engine.into_server())
    });
    // resident A: signal once its stream is live, then read to the end
    let (tx, rx) = std::sync::mpsc::channel();
    let addr_a = addr.clone();
    let resident = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(&addr_a).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(20)))
            .unwrap();
        let body = "{\"prompt\":\"resident under drain \",\"max_new\":40}";
        write!(
            stream,
            "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let (mut tokens, mut terminal, mut signalled) = (0usize, false, false);
        loop {
            let mut l = String::new();
            match reader.read_line(&mut l) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            if l.contains("\"token\"") {
                tokens += 1;
                if !signalled {
                    signalled = true;
                    tx.send(()).unwrap(); // stream is live: drain now
                }
            } else if l.contains("\"done\"") {
                terminal = true;
                break;
            }
        }
        (tokens, terminal)
    });
    rx.recv_timeout(Duration::from_secs(20))
        .expect("resident never started streaming");
    serve_net::request_drain();
    // give the serve loop a beat to flip the draining flag, then any
    // new request must bounce with 503
    std::thread::sleep(Duration::from_millis(100));
    let (status, _h, _t, _d) = http_generate(&addr, "too late ", 4, None);
    assert_eq!(status, 503, "new work during drain must be refused");
    let (tokens, terminal) = resident.join().unwrap();
    assert!(terminal, "the resident must finish during a clean drain");
    assert_eq!(tokens, 40);
    let (report, server) = serve.join().unwrap();
    assert!(report.drained_clean, "drain should not need force-cancel");
    assert_eq!(report.completed, 1);
    assert!(report.refused_draining >= 1);
    assert_closed(&server);
}
