//! Equivalence suite for the two latency features on the continuous
//! scheduler (DESIGN.md §Speculation-and-chunking seam):
//!
//! * **Chunked prefill** (`--prefill-chunk N`) splits prompt ingestion
//!   into fixed-size cache-extension chunks interleaved with resident
//!   decode steps. `NativeModel::extend_rows` performs the same float
//!   ops in the same order as monolithic prefill, so logits, KV state
//!   and therefore every emitted token must be **bitwise identical** to
//!   the monolithic path — at any chunk size, on the dense and paged
//!   (f32) pools, under every normalizer, quantized or not.
//! * **Self-speculative decoding** (`--spec draft-k=K`) drafts K greedy
//!   tokens with a small model and verifies all of them with one
//!   batched target step. Greedy acceptance emits only tokens that are
//!   argmaxes of *target* logits, so outputs never depend on the draft:
//!   a perfect self-draft accepts everything, a mismatched draft only
//!   costs speed — never changes a token.
//!
//! Paged pools here pin the f32 KV dtype: lossy dtypes (f16/bf16/int8)
//! quantize at chunk boundaries, so chunked-vs-monolithic bitwise
//! equality is an f32 property (same caveat as warm prefix-shared
//! prefill).

use consmax::config::{KvCacheConfig, KvDtype, ModelConfig, QuantMode};
use consmax::coordinator::{
    DecodeMode, GenRequest, GenResponse, Generator, ParamStore, ServeEvent,
    Server, SpecConfig,
};
use consmax::prop_assert;
use consmax::runtime::backend::{
    DecodeSession, ExtendLogits, ExtendReq, NativeModel, Normalizer,
};
use consmax::util::proptest::{run_property, Gen};

fn setup() -> (ModelConfig, ParamStore) {
    setup_norm("consmax")
}

fn setup_norm(norm: &str) -> (ModelConfig, ParamStore) {
    let cfg = ModelConfig::builtin("tiny", norm).unwrap();
    let store = ParamStore::init(&cfg, 5).unwrap();
    (cfg, store)
}

fn greedy_req(id: u64, prompt: &str, max_new: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: prompt.into(),
        max_new_tokens: max_new,
        temperature: 0.0,
        stop: None,
        deadline_ms: None,
    }
}

fn by_id(mut responses: Vec<GenResponse>) -> Vec<GenResponse> {
    responses.sort_by_key(|r| r.id);
    responses
}

/// Build a continuous server with the full feature matrix: quantization,
/// KV pool, chunked prefill, and speculation (draft weights given as a
/// separate store so tests can pair a target with a mismatched draft).
fn build_server<'a>(
    cfg: &'a ModelConfig,
    store: &'a ParamStore,
    quant: QuantMode,
    kv: Option<KvCacheConfig>,
    chunk: Option<usize>,
    spec: Option<(usize, &ParamStore)>,
) -> Server<'a> {
    let gen =
        Generator::native_quant(cfg, store, 0, DecodeMode::Kv, quant).unwrap();
    let mut server = Server::new(gen);
    if let Some(kv) = kv {
        server.set_kv_config(Some(kv)).unwrap();
    }
    server.set_prefill_chunk(chunk).unwrap();
    if let Some((k, dstore)) = spec {
        let draft = NativeModel::from_params_quant(
            cfg,
            &dstore.order,
            &dstore.params,
            QuantMode::Off,
        )
        .unwrap();
        server
            .set_spec(Some((SpecConfig { draft_k: k }, draft)))
            .unwrap();
    }
    server
}

fn serve(
    cfg: &ModelConfig,
    store: &ParamStore,
    quant: QuantMode,
    kv: Option<KvCacheConfig>,
    chunk: Option<usize>,
    spec: Option<(usize, &ParamStore)>,
    reqs: &[GenRequest],
) -> Vec<GenResponse> {
    let mut server = build_server(cfg, store, quant, kv, chunk, spec);
    for r in reqs {
        server.submit(r.clone());
    }
    by_id(server.run_continuous().unwrap())
}

fn mixed_reqs() -> Vec<GenRequest> {
    vec![
        greedy_req(0, "The constant softmax ", 9),
        greedy_req(1, "Attention ", 1),
        greedy_req(2, "x", 6),
        greedy_req(3, "", 4), // empty: completes with no tokens, no slot
        greedy_req(4, "A much longer prompt that spans a few more byte tokens ", 12),
        greedy_req(5, "tail ", 3),
    ]
}

fn assert_same_tokens(got: &[GenResponse], want: &[GenResponse], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: request count diverged");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.id, w.id);
        assert_eq!(
            g.tokens, w.tokens,
            "{what}: req {} diverged: {:?} vs {:?}",
            g.id, g.tokens, w.tokens
        );
    }
}

// ---------------------------------------------------------------------------
// chunked prefill
// ---------------------------------------------------------------------------

#[test]
fn chunked_prefill_matches_monolithic_every_chunk_size() {
    // chunk sizes below, straddling, and beyond every prompt length —
    // including 1 (pure token-at-a-time ingestion) and >= ctx (degrades
    // to the monolithic path exactly)
    let (cfg, store) = setup();
    let reqs = mixed_reqs();
    let mono = serve(&cfg, &store, QuantMode::Off, None, None, None, &reqs);
    for chunk in [1usize, 3, 7, 64] {
        let chunked =
            serve(&cfg, &store, QuantMode::Off, None, Some(chunk), None, &reqs);
        assert_same_tokens(&chunked, &mono, &format!("dense chunk={chunk}"));
    }
}

#[test]
fn chunked_prefill_matches_monolithic_on_paged_f32() {
    let (cfg, store) = setup();
    let reqs = mixed_reqs();
    let pools = [
        KvCacheConfig { dtype: KvDtype::F32, block_tokens: 8, mem_bytes: None },
        KvCacheConfig {
            dtype: KvDtype::F32,
            block_tokens: 16,
            // 9 blocks: tight enough to exercise preemption mid-chunking
            mem_bytes: Some(
                9 * 2 * cfg.n_layer * cfg.n_head * 16 * cfg.head_dim() * 4,
            ),
        },
    ];
    for kv in pools {
        let mono =
            serve(&cfg, &store, QuantMode::Off, Some(kv), None, None, &reqs);
        for chunk in [1usize, 3] {
            let chunked = serve(
                &cfg, &store, QuantMode::Off, Some(kv), Some(chunk), None, &reqs,
            );
            assert_same_tokens(
                &chunked,
                &mono,
                &format!("paged({:?} blocks) chunk={chunk}", kv.mem_bytes),
            );
        }
    }
}

#[test]
fn chunked_prefill_matches_monolithic_every_normalizer() {
    for norm in Normalizer::NAMES {
        let (cfg, store) = setup_norm(norm);
        let reqs =
            vec![greedy_req(0, "normalizer zoo ", 5), greedy_req(1, "x", 3)];
        let mono = serve(&cfg, &store, QuantMode::Off, None, None, None, &reqs);
        let chunked =
            serve(&cfg, &store, QuantMode::Off, None, Some(3), None, &reqs);
        assert_same_tokens(&chunked, &mono, &format!("normalizer {norm}"));
    }
}

#[test]
fn chunked_prefill_matches_monolithic_int8_weights() {
    // int8 *weight* quantization is position-independent (the same
    // quantized matrices serve every forward), so chunking stays bitwise
    let (cfg, store) = setup();
    let reqs = mixed_reqs();
    let mono = serve(&cfg, &store, QuantMode::Int8, None, None, None, &reqs);
    for chunk in [1usize, 3] {
        let chunked =
            serve(&cfg, &store, QuantMode::Int8, None, Some(chunk), None, &reqs);
        assert_same_tokens(&chunked, &mono, &format!("int8 chunk={chunk}"));
    }
}

#[test]
fn chunked_prefill_logits_and_decode_path_bitwise_at_model_level() {
    // below the scheduler: prefill(w) + extend_rows(rest) must leave the
    // session with bit-identical next-token logits AND a KV state that
    // decodes bit-identically to monolithic prefill
    let (cfg, store) = setup();
    let model =
        NativeModel::from_params(&cfg, &store.order, &store.params).unwrap();
    let prompt: Vec<i32> = "chunk boundary test".bytes().map(i32::from).collect();
    for w in [1usize, 4, prompt.len() - 1] {
        let mut mono = DecodeSession::new(&cfg, 2);
        let l_mono = model.prefill_rows(&mut mono, &[(0, &prompt[..])]).unwrap();

        let mut chunked = DecodeSession::new(&cfg, 2);
        model.prefill_rows(&mut chunked, &[(0, &prompt[..w])]).unwrap();
        let l_chunk = model
            .extend_rows(
                &mut chunked,
                &[ExtendReq {
                    slot: 0,
                    tokens: &prompt[w..],
                    logits: ExtendLogits::Last,
                }],
            )
            .unwrap()
            .remove(0);
        assert_eq!(l_mono, l_chunk, "w={w}: final-chunk logits diverged");

        // a few greedy decode steps certify the cached KV is the same
        let mut tok = argmax(&l_mono) as i32;
        for step in 0..4 {
            let a = model
                .decode_step_active(&mut mono, &[tok, 0], &[true, false])
                .unwrap();
            let b = model
                .decode_step_active(&mut chunked, &[tok, 0], &[true, false])
                .unwrap();
            assert_eq!(a, b, "w={w}: decode step {step} diverged");
            tok = argmax(&a[..cfg.vocab]) as i32;
        }
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

#[test]
fn chunked_ttft_counts_to_first_emitted_token() {
    // a 5-token prompt at chunk=1 dwells 4 ticks in Prefill and emits
    // its first token on the 5th — TTFT is submit -> first *emitted*
    // token, and the event stream must show exactly that shape
    let (cfg, store) = setup();
    let mut server =
        build_server(&cfg, &store, QuantMode::Off, None, Some(1), None);
    server.set_event_capture(true);
    server.submit(greedy_req(0, "abcde", 3));
    for tick in 1..=4 {
        let done = server.step().unwrap();
        assert!(done.is_empty(), "tick {tick}: completed too early");
        let evs = server.drain_events();
        assert!(
            !evs.iter().any(|e| matches!(e, ServeEvent::Token { .. })),
            "tick {tick}: token emitted while the prompt was still feeding"
        );
    }
    server.step().unwrap(); // 5th tick: final chunk lands + first token
    let evs = server.drain_events();
    assert!(
        evs.iter().any(|e| matches!(e, ServeEvent::Token { .. })),
        "5th tick: the completing chunk must emit the first token"
    );
    let r = by_id(server.run_continuous().unwrap()).remove(0);
    assert!(r.ttft_ms > 0.0 && r.ttft_ms <= r.latency_ms);
    let st = server.stats();
    assert_eq!(st.prefill_chunk_steps, 5, "one feed per tick at chunk=1");
    assert!(st.decode_steps > 0);
}

// ---------------------------------------------------------------------------
// self-speculative decoding
// ---------------------------------------------------------------------------

#[test]
fn self_draft_accepts_everything_and_stays_bitwise() {
    // the draft IS the target: every proposal is the target's own argmax,
    // so acceptance is 100% and outputs are trivially bit-identical
    let (cfg, store) = setup();
    let reqs = mixed_reqs();
    let plain = serve(&cfg, &store, QuantMode::Off, None, None, None, &reqs);
    for k in [1usize, 2, 3] {
        let mut server = build_server(
            &cfg, &store, QuantMode::Off, None, None, Some((k, &store)),
        );
        for r in &reqs {
            server.submit(r.clone());
        }
        let spec = by_id(server.run_continuous().unwrap());
        assert_same_tokens(&spec, &plain, &format!("self-draft k={k}"));
        let st = server.stats();
        assert!(st.spec_proposed > 0, "k={k}: speculation never ran");
        assert_eq!(
            st.spec_accepted, st.spec_proposed,
            "k={k}: a self-draft must accept every proposal"
        );
        // per-response counters sum to the server totals
        let (p, a) = spec.iter().fold((0u64, 0u64), |(p, a), r| {
            (p + r.spec_proposed, a + r.spec_accepted)
        });
        assert_eq!((p, a), (st.spec_proposed, st.spec_accepted));
    }
}

#[test]
fn mismatched_draft_changes_speed_never_tokens() {
    // a draft trained on different weights proposes garbage; greedy
    // verification rejects what the target would not have emitted, so
    // outputs are still bitwise — only the acceptance rate drops
    let (cfg, store) = setup();
    let wrong = ParamStore::init(&cfg, 99).unwrap();
    let reqs = mixed_reqs();
    let plain = serve(&cfg, &store, QuantMode::Off, None, None, None, &reqs);
    let mut server = build_server(
        &cfg, &store, QuantMode::Off, None, None, Some((2, &wrong)),
    );
    for r in &reqs {
        server.submit(r.clone());
    }
    let spec = by_id(server.run_continuous().unwrap());
    assert_same_tokens(&spec, &plain, "mismatched draft");
    let st = server.stats();
    assert!(st.spec_proposed > 0);
    assert!(st.spec_accepted <= st.spec_proposed);
}

#[test]
fn spec_decode_matches_plain_every_normalizer() {
    for norm in Normalizer::NAMES {
        let (cfg, store) = setup_norm(norm);
        let reqs =
            vec![greedy_req(0, "normalizer zoo ", 6), greedy_req(1, "x", 3)];
        let plain = serve(&cfg, &store, QuantMode::Off, None, None, None, &reqs);
        let spec = serve(
            &cfg, &store, QuantMode::Off, None, None, Some((2, &store)), &reqs,
        );
        assert_same_tokens(&spec, &plain, &format!("normalizer {norm}"));
    }
}

#[test]
fn spec_decode_int8_target_with_f32_draft_stays_bitwise() {
    // quantized target + unquantized draft: proposals diverge wherever
    // int8 rounding flips an argmax, but verification is the int8
    // target's own logits, so the emitted stream is the int8 stream
    let (cfg, store) = setup();
    let reqs = mixed_reqs();
    let plain = serve(&cfg, &store, QuantMode::Int8, None, None, None, &reqs);
    let spec = serve(
        &cfg, &store, QuantMode::Int8, None, None, Some((2, &store)), &reqs,
    );
    assert_same_tokens(&spec, &plain, "int8 target, f32 self-draft");
}

#[test]
fn spec_and_chunking_compose() {
    let (cfg, store) = setup();
    let reqs = mixed_reqs();
    let plain = serve(&cfg, &store, QuantMode::Off, None, None, None, &reqs);
    for kv in [
        None,
        Some(KvCacheConfig {
            dtype: KvDtype::F32,
            block_tokens: 8,
            mem_bytes: None,
        }),
    ] {
        let both = serve(
            &cfg, &store, QuantMode::Off, kv, Some(3), Some((2, &store)), &reqs,
        );
        assert_same_tokens(&both, &plain, &format!("spec+chunk kv={kv:?}"));
    }
}

#[test]
fn spec_churn_proptest_mixed_temperatures_and_pools() {
    // randomized join/leave churn with sampled rows co-resident: greedy
    // rows speculate, sampled rows never do, and per-slot RNG streams
    // (seeded by request id) make even the sampled rows bitwise
    // reproducible against a spec-off run of the same pool
    let (cfg, store) = setup();
    let pools: [Option<KvCacheConfig>; 2] = [
        None,
        Some(KvCacheConfig {
            dtype: KvDtype::F32,
            block_tokens: 16,
            // 9 blocks: preemption fires while draft state is resident
            mem_bytes: Some(
                9 * 2 * cfg.n_layer * cfg.n_head * 16 * cfg.head_dim() * 4,
            ),
        }),
    ];
    for (pi, kv) in pools.iter().enumerate() {
        run_property("spec on == spec off under churn", 5, |g: &mut Gen| {
            let n = g.usize(3, 8);
            let mut reqs = Vec::new();
            for id in 0..n as u64 {
                let plen = g.usize(0, 90); // ctx is 64: some prompts clamp
                let prompt: String = (0..plen)
                    .map(|_| (b'a' + (g.usize(0, 26) as u8)) as char)
                    .collect();
                let mut r = greedy_req(id, &prompt, g.usize(0, 8));
                if g.usize(0, 3) == 0 {
                    r.temperature = 0.8;
                }
                reqs.push(r);
            }
            let run = |spec: Option<(usize, &ParamStore)>,
                       split: usize,
                       ticks: usize|
             -> Vec<GenResponse> {
                let mut server =
                    build_server(&cfg, &store, QuantMode::Off, *kv, None, spec);
                for r in reqs.iter().take(split) {
                    server.submit(r.clone());
                }
                let mut out = Vec::new();
                for _ in 0..ticks {
                    out.extend(server.step().unwrap());
                }
                for r in reqs.iter().skip(split) {
                    server.submit(r.clone());
                }
                out.extend(server.run_continuous().unwrap());
                by_id(out)
            };
            let split = g.usize(0, n + 1);
            let ticks = g.usize(0, 5);
            let plain = run(None, split, ticks);
            let spec = run(Some((2, &store)), split, ticks);
            prop_assert!(
                spec.len() == reqs.len(),
                "pool {pi}: served {} of {}",
                spec.len(),
                reqs.len()
            );
            for (s, p) in spec.iter().zip(&plain) {
                prop_assert!(
                    s.tokens == p.tokens,
                    "pool {pi}: req {} diverged under speculation: {:?} vs {:?}",
                    s.id,
                    s.tokens,
                    p.tokens
                );
            }
            Ok(())
        });
    }
}

#[test]
fn cancel_deadline_preempt_free_draft_state() {
    // terminal states while speculation is live: a cancelled resident, a
    // lapsed deadline, and budget-pressure preemption all release the
    // draft row with the slot; the accounting invariant holds and the
    // pool serves later requests bit-identically
    let (cfg, store) = setup();
    let kv = KvCacheConfig {
        dtype: KvDtype::F32,
        block_tokens: 16,
        mem_bytes: Some(9 * 2 * cfg.n_layer * cfg.n_head * 16 * cfg.head_dim() * 4),
    };
    let mut server = build_server(
        &cfg, &store, QuantMode::Off, Some(kv), Some(3), Some((2, &store)),
    );
    server.submit(greedy_req(0, "long running resident ", 24));
    server.submit(greedy_req(1, "will be cancelled ", 24));
    let mut doomed = greedy_req(2, "will time out ", 24);
    doomed.deadline_ms = Some(1); // lapses on the next sweep
    server.submit(doomed);
    server.submit(greedy_req(3, "queued behind the doomed ", 4));
    for _ in 0..3 {
        server.step().unwrap();
    }
    assert!(server.cancel(1), "resident cancel must land");
    std::thread::sleep(std::time::Duration::from_millis(3));
    let mut done = server.run_continuous().unwrap();
    // the freed slots keep serving: a fresh request still matches the
    // plain-decode reference
    server.submit(greedy_req(4, "after the churn ", 5));
    done.extend(server.run_continuous().unwrap());
    let done = by_id(done);
    let st = server.stats();
    assert_eq!(
        st.completed + st.timed_out + st.cancelled + st.shed,
        st.submitted,
        "terminal accounting must balance with spec+chunking live"
    );
    assert_eq!(server.in_flight(), 0);
    let reqs = [greedy_req(0, "after the churn ", 5)];
    let want = serve(&cfg, &store, QuantMode::Off, None, None, None, &reqs);
    let after = done.iter().find(|r| r.id == 4).expect("req 4 completed");
    assert_eq!(after.tokens, want[0].tokens, "post-churn request diverged");
    assert!(st.spec_accepted <= st.spec_proposed);
}

#[test]
fn feature_knobs_validate_and_gate_on_idle() {
    let (cfg, store) = setup();
    let mut server = build_server(&cfg, &store, QuantMode::Off, None, None, None);
    assert!(server.set_prefill_chunk(Some(0)).is_err(), "chunk 0 rejected");
    let draft = NativeModel::from_params_quant(
        &cfg,
        &store.order,
        &store.params,
        QuantMode::Off,
    )
    .unwrap();
    assert!(
        server.set_spec(Some((SpecConfig { draft_k: 0 }, draft))).is_err(),
        "draft-k 0 rejected"
    );
    // both setters are rejected while requests are resident
    server.submit(greedy_req(0, "resident ", 8));
    server.step().unwrap();
    assert!(server.set_prefill_chunk(Some(2)).is_err());
    let draft = NativeModel::from_params_quant(
        &cfg,
        &store.order,
        &store.params,
        QuantMode::Off,
    )
    .unwrap();
    assert!(server.set_spec(Some((SpecConfig { draft_k: 2 }, draft))).is_err());
    server.run_continuous().unwrap();
    // and accepted again once the pool drains
    server.set_prefill_chunk(Some(2)).unwrap();
    assert_eq!(server.prefill_chunk(), Some(2));
    let draft = NativeModel::from_params_quant(
        &cfg,
        &store.order,
        &store.params,
        QuantMode::Off,
    )
    .unwrap();
    server.set_spec(Some((SpecConfig { draft_k: 2 }, draft))).unwrap();
    assert_eq!(server.spec_config(), Some(SpecConfig { draft_k: 2 }));
}

#[test]
fn legacy_path_reports_zero_feature_counters() {
    // both features off: the scheduler must not tick the new counters
    // (prefill_chunk_steps stays 0; decode_steps is the only addition)
    let (cfg, store) = setup();
    let mut server = build_server(&cfg, &store, QuantMode::Off, None, None, None);
    server.submit(greedy_req(0, "legacy ", 4));
    server.run_continuous().unwrap();
    let st = server.stats();
    assert_eq!(st.prefill_chunk_steps, 0);
    assert_eq!(st.spec_proposed, 0);
    assert_eq!(st.spec_accepted, 0);
    // token 1 comes from the prefill sample; 2..4 from decode ticks
    assert!(st.decode_steps >= 3);
}
