//! KV-vs-recompute equivalence suite for the native decode engine, plus
//! regression tests for the batched-serving bugs this PR fixed:
//!
//! * greedy KV decode is **token-identical** to the recompute oracle
//!   (`NativeModel::next_logits`) and logit-identical within 1e-5, for
//!   the whole normalizer zoo (softmax, consmax, softermax, consmax-v2,
//!   ssmax), including sequences past `ctx` (ring eviction + window
//!   re-encode);
//! * a prompt in a ragged batch decodes exactly as it would alone
//!   (the left-pad pollution fix);
//! * each request is sampled at its own temperature (not `batch[0]`'s);
//! * accounting is in token space (`prompt_tokens` = post-clamp encoded
//!   length, `new_tokens` = generated token count, not chars/bytes);
//! * the int8 serving path (`--quant int8`: per-channel int8
//!   projections + LUT ConSmax tail) passes the same oracle suite —
//!   quantization error is identical on both sides, so the f32
//!   tolerances carry over unchanged.

use consmax::config::{KvCacheConfig, KvDtype, ModelConfig, QuantMode};
use consmax::coordinator::{
    DecodeMode, GenRequest, Generator, ParamStore, Server,
};
use consmax::runtime::backend::{DecodeSession, NativeModel};

const NORMALIZERS: [&str; 5] =
    ["consmax", "softmax", "softermax", "consmax-v2", "ssmax"];

fn tiny_model(norm: &str, seed: u64) -> NativeModel {
    tiny_model_quant(norm, seed, QuantMode::Off)
}

fn tiny_model_quant(norm: &str, seed: u64, quant: QuantMode) -> NativeModel {
    let cfg = ModelConfig::builtin("tiny", norm).unwrap();
    let store = ParamStore::init(&cfg, seed).unwrap();
    NativeModel::from_params_quant(&cfg, &store.order, &store.params, quant)
        .unwrap()
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

fn assert_close(kv: &[f32], oracle: &[f32], what: &str) {
    assert_eq!(kv.len(), oracle.len(), "{what}: length");
    for (i, (a, b)) in kv.iter().zip(oracle).enumerate() {
        let denom = a.abs().max(b.abs()).max(1.0);
        assert!(
            (a - b).abs() / denom <= 1e-5,
            "{what}[{i}]: kv {a} vs oracle {b}"
        );
    }
}

/// Greedy-decode `steps` tokens with the KV engine while checking every
/// step against the recompute oracle on the full growing sequence.
/// `paged` swaps the dense per-row cache for the paged block pool —
/// same public API, same oracle, so the whole equivalence suite runs on
/// both memory models.
fn check_greedy_equivalence_on(
    norm: &str,
    prompt_len: usize,
    steps: usize,
    paged: bool,
) {
    check_greedy_equivalence_quant(norm, prompt_len, steps, paged, QuantMode::Off);
}

/// Same oracle loop, but the model (both the KV engine under test and
/// the recompute oracle) may run the int8 serving path: the weight
/// quantization error is identical on both sides, so the same 1e-5
/// logit tolerance as f32 applies. Lossy int8 *KV storage* is pinned
/// separately in `kvcache_paged.rs` under its documented `INT8_TOL`.
fn check_greedy_equivalence_quant(
    norm: &str,
    prompt_len: usize,
    steps: usize,
    paged: bool,
    quant: QuantMode,
) {
    let m = tiny_model_quant(norm, 11, quant);
    let prompt: Vec<i32> =
        (0..prompt_len).map(|i| ((i * 37 + 5) % 256) as i32).collect();

    let mut sess = if paged {
        let kv = KvCacheConfig {
            dtype: KvDtype::F32,
            block_tokens: 16,
            mem_bytes: None,
        };
        DecodeSession::new_paged(&m.cfg, 1, &kv).unwrap()
    } else {
        DecodeSession::new(&m.cfg, 1)
    };
    let mut kv_logits = m.prefill(&mut sess, &[prompt.clone()]).unwrap();
    let mut seq = prompt;
    let oracle = m.next_logits(std::slice::from_ref(&seq)).unwrap();
    assert_close(&kv_logits, &oracle, &format!("{norm}: prefill"));

    for step in 0..steps {
        let next = argmax(&kv_logits) as i32;
        // the oracle extends the full sequence and recomputes its
        // ctx-bounded trailing window
        seq.push(next);
        let oracle = m.next_logits(std::slice::from_ref(&seq)).unwrap();
        let oracle_next = argmax(&oracle) as i32;
        // the KV engine takes one incremental (or eviction) step
        kv_logits = m.decode_step(&mut sess, &[next]).unwrap();
        assert_close(
            &kv_logits,
            &oracle,
            &format!("{norm}: step {step} (seq len {})", seq.len()),
        );
        assert_eq!(
            argmax(&kv_logits) as i32,
            oracle_next,
            "{norm}: greedy token diverged at step {step}"
        );
    }
}

fn check_greedy_equivalence(norm: &str, prompt_len: usize, steps: usize) {
    check_greedy_equivalence_on(norm, prompt_len, steps, false);
}

#[test]
fn kv_matches_recompute_within_ctx() {
    for norm in NORMALIZERS {
        // 16 prompt + 32 generated = 48 < ctx (64): pure incremental path
        check_greedy_equivalence(norm, 16, 32);
    }
}

#[test]
fn kv_matches_recompute_past_ctx() {
    for norm in NORMALIZERS {
        // 58 prompt + 14 generated = 72 > ctx (64): crosses into ring
        // eviction + window re-encode territory
        check_greedy_equivalence(norm, 58, 14);
    }
}

#[test]
fn paged_f32_kv_matches_recompute_within_and_past_ctx() {
    // the paged block pool behind the same DecodeSession API must pass
    // the same oracle equivalence, incl. eviction (the bitwise
    // paged-vs-dense suite lives in rust/tests/kvcache_paged.rs)
    for norm in NORMALIZERS {
        check_greedy_equivalence_on(norm, 16, 8, true);
        check_greedy_equivalence_on(norm, 58, 10, true);
    }
}

#[test]
fn int8_kv_matches_recompute_within_and_past_ctx() {
    // the int8 serving path (per-channel int8 projections + LM head,
    // LUT ConSmax tail) through the same dense-KV-vs-recompute oracle,
    // including ring eviction + window re-encode past ctx
    for norm in NORMALIZERS {
        check_greedy_equivalence_quant(norm, 16, 8, false, QuantMode::Int8);
        check_greedy_equivalence_quant(norm, 58, 10, false, QuantMode::Int8);
    }
}

#[test]
fn int8_paged_kv_matches_recompute() {
    // int8 weights over the paged pool with f32 block storage: paging
    // must stay transparent to the quantized compute path too
    for norm in NORMALIZERS {
        check_greedy_equivalence_quant(norm, 16, 8, true, QuantMode::Int8);
    }
}

#[test]
fn kv_matches_recompute_for_overlong_prompt() {
    // prompt already longer than ctx: prefill must clamp to the
    // trailing window exactly like the oracle
    let m = tiny_model("consmax", 11);
    let prompt: Vec<i32> = (0..100).map(|i| ((i * 13 + 1) % 256) as i32).collect();
    let mut sess = DecodeSession::new(&m.cfg, 1);
    let kv = m.prefill(&mut sess, &[prompt.clone()]).unwrap();
    let oracle = m.next_logits(&[prompt]).unwrap();
    assert_close(&kv, &oracle, "overlong prefill");
    assert_eq!(sess.len_of(0), m.cfg.ctx);
}

#[test]
fn batched_ragged_rows_match_solo_rows() {
    // the left-pad pollution regression: short prompts in a mixed batch
    // must produce byte-identical greedy continuations to running them
    // alone (pre-fix, padding was attended to and corrupted the logits)
    let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
    let store = ParamStore::init(&cfg, 5).unwrap();
    let prompts = [
        "The transformer architecture ".to_string(),
        "hi".to_string(),
        "a much longer prompt about streaming attention normalizers "
            .to_string(),
    ];

    let mut batched = Generator::native(&cfg, &store, 0).unwrap();
    let batch_out = batched.generate_batch(&prompts, 12, 0.0).unwrap();

    for (i, p) in prompts.iter().enumerate() {
        let mut solo = Generator::native(&cfg, &store, 0).unwrap();
        let solo_out = solo
            .generate_batch(std::slice::from_ref(p), 12, 0.0)
            .unwrap();
        assert_eq!(
            batch_out[i], solo_out[0],
            "row {i} ({p:?}) diverged between batched and solo decode"
        );
    }
}

#[test]
fn kv_and_recompute_generators_agree_on_batches() {
    for norm in NORMALIZERS {
        let cfg = ModelConfig::builtin("tiny", norm).unwrap();
        let store = ParamStore::init(&cfg, 9).unwrap();
        let prompts =
            ["alpha ".to_string(), "the quick brown fox".to_string()];
        let mut kv = Generator::native(&cfg, &store, 0).unwrap();
        let mut rc =
            Generator::native_with(&cfg, &store, 0, DecodeMode::Recompute)
                .unwrap();
        let a = kv.generate_batch(&prompts, 10, 0.0).unwrap();
        let b = rc.generate_batch(&prompts, 10, 0.0).unwrap();
        assert_eq!(a, b, "{norm}: kv vs recompute batch divergence");
    }
}

#[test]
fn int8_kv_and_recompute_generators_agree_on_batches() {
    // both generators run the same int8 model, so greedy continuations
    // must match exactly — the quantization error cancels across the
    // oracle pair
    for norm in NORMALIZERS {
        let cfg = ModelConfig::builtin("tiny", norm).unwrap();
        let store = ParamStore::init(&cfg, 9).unwrap();
        let prompts =
            ["alpha ".to_string(), "the quick brown fox".to_string()];
        let mut kv = Generator::native_quant(
            &cfg,
            &store,
            0,
            DecodeMode::Kv,
            QuantMode::Int8,
        )
        .unwrap();
        let mut rc = Generator::native_quant(
            &cfg,
            &store,
            0,
            DecodeMode::Recompute,
            QuantMode::Int8,
        )
        .unwrap();
        let a = kv.generate_batch(&prompts, 10, 0.0).unwrap();
        let b = rc.generate_batch(&prompts, 10, 0.0).unwrap();
        assert_eq!(a, b, "{norm}: int8 kv vs recompute divergence");
    }
}

#[test]
fn per_request_temperature_is_respected() {
    // pre-fix, Server::run_once applied batch[0].temperature to every
    // row; a greedy request riding behind a hot one must stay greedy
    let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
    let store = ParamStore::init(&cfg, 5).unwrap();

    let mut solo = Generator::native(&cfg, &store, 0).unwrap();
    let greedy_ref =
        solo.generate_batch(&["steady prompt ".into()], 10, 0.0).unwrap();

    let mut server = Server::new(Generator::native(&cfg, &store, 123).unwrap());
    server.submit(GenRequest {
        id: 0,
        prompt: "hot prompt ".into(),
        max_new_tokens: 10,
        temperature: 5.0, // near-uniform sampling
        stop: None,
        deadline_ms: None,
    });
    server.submit(GenRequest {
        id: 1,
        prompt: "steady prompt ".into(),
        max_new_tokens: 10,
        temperature: 0.0, // greedy
        stop: None,
        deadline_ms: None,
    });
    let mut responses = server.run_to_completion().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 2);
    assert_eq!(responses[0].batch_size, 2, "requests must share one batch");
    assert_eq!(
        responses[1].text, greedy_ref[0],
        "greedy request was not decoded greedily"
    );
}

#[test]
fn token_space_accounting() {
    let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
    let store = ParamStore::init(&cfg, 5).unwrap();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());

    // multi-byte prompt: 21 chars but 25 UTF-8 bytes => 25 byte-tokens
    let prompt = "héllo wörld — ConSmax".to_string();
    assert_eq!(prompt.chars().count(), 21);
    let prompt_bytes = prompt.len();
    assert!(prompt_bytes > prompt.chars().count());
    server.submit(GenRequest {
        id: 0,
        prompt,
        max_new_tokens: 5,
        temperature: 0.0,
        stop: None,
        deadline_ms: None,
    });
    let r = &server.run_to_completion().unwrap()[0];
    assert_eq!(
        r.prompt_tokens, prompt_bytes,
        "prompt_tokens must count tokens (encoded bytes), not chars"
    );
    assert_eq!(r.new_tokens, 5, "new_tokens must count tokens");
    assert_eq!(server.tokens_out, 5);

    // over-long prompt reports the post-clamp length, not the byte count
    let long = "z".repeat(cfg.ctx * 4);
    server.submit(GenRequest {
        id: 1,
        prompt: long,
        max_new_tokens: 8,
        temperature: 0.0,
        stop: None,
        deadline_ms: None,
    });
    let r = &server.run_to_completion().unwrap()[0];
    assert_eq!(r.prompt_tokens, cfg.ctx - 8);
    assert_eq!(r.new_tokens, 8);
}

#[test]
fn batched_decode_matches_per_row_sessions() {
    // a 3-row DecodeSession must behave as three independent 1-row
    // sessions (per-row lengths, no cross-row pollution), logits included
    let m = tiny_model("softermax", 4);
    let rows = [
        vec![10, 20, 30, 40, 50],
        vec![7],
        vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11],
    ];

    let mut batch_sess = DecodeSession::new(&m.cfg, 3);
    let mut batch_logits =
        m.prefill(&mut batch_sess, &rows).unwrap();
    let v = m.cfg.vocab;

    let mut solo_sessions: Vec<DecodeSession> =
        (0..3).map(|_| DecodeSession::new(&m.cfg, 1)).collect();
    for (r, row) in rows.iter().enumerate() {
        let solo = m
            .prefill(&mut solo_sessions[r], std::slice::from_ref(row))
            .unwrap();
        assert_eq!(
            batch_logits[r * v..(r + 1) * v],
            solo[..],
            "row {r} prefill"
        );
    }

    for step in 0..6 {
        let toks: Vec<i32> = (0..3)
            .map(|r| argmax(&batch_logits[r * v..(r + 1) * v]) as i32)
            .collect();
        batch_logits = m.decode_step(&mut batch_sess, &toks).unwrap();
        for r in 0..3 {
            let solo = m
                .decode_step(&mut solo_sessions[r], &toks[r..r + 1])
                .unwrap();
            assert_eq!(
                batch_logits[r * v..(r + 1) * v],
                solo[..],
                "row {r} step {step}"
            );
        }
    }
}
