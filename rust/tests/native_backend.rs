//! Native-backend cross-validation against the checked-in golden vectors
//! (`rust/tests/golden/golden.json`, generated from the python oracle
//! `python/compile/kernels/ref.py`) and against the bit-exact LUT model
//! in `quant/lut.rs`. This is the triangle the tentpole requires:
//!
//!   python oracle == checked-in goldens        (by construction)
//!   NativeBackend == goldens                   (float ops, rtol)
//!   NativeBackend == quant::BitSplitLut        (hardware path, bit-exact)
//!
//! plus end-to-end smoke over the native model: evaluation loss and
//! deterministic generation with zero artifacts on disk.

use consmax::config::ModelConfig;
use consmax::coordinator::{Generator, ParamStore};
use consmax::quant::{merge_beta_gamma, BitSplitLut, Int8Quantizer};
use consmax::runtime::backend::{Backend, NativeBackend};
use consmax::runtime::{DType, HostTensor};
use consmax::util::json::Json;

fn golden() -> Json {
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/golden.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    Json::parse(&text).expect("parse golden.json")
}

fn f32_vec(v: &Json) -> Vec<f32> {
    v.to_f64_vec().unwrap().iter().map(|&x| x as f32).collect()
}

fn assert_close(got: &[f32], want: &[f64], rtol: f64, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let g = *g as f64;
        let denom = g.abs().max(w.abs()).max(1e-30);
        assert!(
            (g - w).abs() / denom <= rtol || (g - w).abs() < 1e-7,
            "{what}[{i}]: {g} vs {w}"
        );
    }
}

#[test]
fn native_consmax_matches_python_golden() {
    let g = golden();
    let gc = g.get("consmax");
    let s = f32_vec(gc.get("s"));
    let c = gc.get("c").as_f64().unwrap() as f32;
    let want = gc.get("out").to_f64_vec().unwrap();

    let be = NativeBackend::new();
    let out = be
        .execute(
            "op_consmax",
            &[
                HostTensor::from_f32(&s, &[4, 8]),
                HostTensor::from_f32(&vec![c; s.len()], &[4, 8]),
            ],
        )
        .expect("op_consmax");
    assert_close(&out[0].as_f32().unwrap(), &want, 1e-5, "op_consmax");
}

#[test]
fn native_softmax_matches_python_golden() {
    let g = golden();
    let gs = g.get("softmax");
    let s = f32_vec(gs.get("s"));
    let want = gs.get("out").to_f64_vec().unwrap();
    let be = NativeBackend::new();
    let out = be
        .execute("op_softmax", &[HostTensor::from_f32(&s, &[4, 8])])
        .expect("op_softmax");
    assert_close(&out[0].as_f32().unwrap(), &want, 1e-5, "op_softmax");
}

#[test]
fn native_softermax_matches_python_golden() {
    let g = golden();
    let gs = g.get("softermax");
    let s = f32_vec(gs.get("s"));
    let want = gs.get("out").to_f64_vec().unwrap();
    let be = NativeBackend::new();
    let out = be
        .execute("op_softermax", &[HostTensor::from_f32(&s, &[4, 8])])
        .expect("op_softermax");
    assert_close(&out[0].as_f32().unwrap(), &want, 1e-5, "op_softermax");
}

#[test]
fn native_lut_op_bit_exact_on_full_grid() {
    // all 256 INT8 codes with C=1.0: the op output must equal the python
    // golden bits AND the quant::BitSplitLut model bits exactly
    let g = golden();
    let lut_g = g.get("lut_exp_s16");
    let q: Vec<i8> = lut_g
        .get("q")
        .to_f64_vec()
        .unwrap()
        .iter()
        .map(|&v| v as i8)
        .collect();
    let want_bits: Vec<u16> = lut_g
        .get("out_bits")
        .to_f64_vec()
        .unwrap()
        .iter()
        .map(|&v| v as u16)
        .collect();

    let be = NativeBackend::new();
    let q_t = HostTensor::from_i8(&q, &[256]);
    let c_t = HostTensor::from_f32(&vec![1.0f32; 256], &[256]);
    let out = be.execute("op_lut_consmax", &[q_t, c_t]).expect("lut op");
    assert_eq!(out[0].dtype, DType::F16);
    let bits = out[0].as_f16_bits().unwrap();
    assert_eq!(bits, want_bits, "backend vs python golden");

    let model = BitSplitLut::paper();
    for (code, b) in q.iter().zip(&bits) {
        assert_eq!(
            *b,
            model
                .consmax(*code, consmax::util::fp16::F16::from_f32(1.0))
                .to_bits(),
            "code {code}"
        );
    }
}

#[test]
fn native_consmax_vs_quantized_hw_path_within_lut_error() {
    // acceptance criterion: NativeBackend ConSmax must match the
    // quant/lut.rs bit-exact model on the golden vectors to within LUT
    // quantization error (score quantization + fp16 rounding).
    let g = golden();
    let gc = g.get("consmax");
    let s = f32_vec(gc.get("s"));
    let beta = gc.get("beta").as_f64().unwrap() as f32;
    let gamma = gc.get("gamma").as_f64().unwrap() as f32;

    let be = NativeBackend::new();
    let c = merge_beta_gamma(beta, gamma);
    let float_out = be
        .execute(
            "op_consmax",
            &[
                HostTensor::from_f32(&s, &[4, 8]),
                HostTensor::from_f32(&vec![c.to_f32(); s.len()], &[4, 8]),
            ],
        )
        .unwrap()[0]
        .as_f32()
        .unwrap();

    let quant = Int8Quantizer::paper();
    let lut = BitSplitLut::paper();
    for (x, w) in s.iter().zip(&float_out) {
        let hw = lut.consmax(quant.quantize(*x), c).to_f32() as f64;
        let w = *w as f64;
        // error budget: score quantization (±scale/2 in the exponent) +
        // fp16 rounding of the tiny products (~2%)
        let tol = w * ((quant.scale as f64 / 2.0).exp() - 1.0) + w * 0.02 + 1e-6;
        assert!((hw - w).abs() <= tol, "x={x}: hw {hw} vs native {w} (tol {tol})");
    }
}

#[test]
fn backend_trait_is_object_safe_and_uniform() {
    let be: Box<dyn Backend> = Box::new(NativeBackend::new());
    assert_eq!(be.name(), "native");
    assert!(be.supports("op_consmax"));
    assert!(!be.supports("tiny_consmax_train_step"));
    let s = HostTensor::from_f32(&[0.0, 1.0], &[1, 2]);
    let c = HostTensor::from_f32(&[0.5, 0.5], &[1, 2]);
    let out = be.execute("op_consmax", &[s, c]).unwrap();
    let vals = out[0].as_f32().unwrap();
    assert!((vals[0] - 0.5).abs() < 1e-6);
    assert!((vals[1] - 0.5 * std::f32::consts::E).abs() < 1e-5);
}

// ---------------------------------------------------------------------------
// end-to-end native model paths (zero artifacts on disk)
// ---------------------------------------------------------------------------

#[test]
fn native_eval_loss_is_near_uniform_for_random_weights() {
    use consmax::data::{BatchSampler, ByteTokenizer, Corpus};
    use consmax::runtime::backend::NativeModel;

    let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
    let store = ParamStore::init(&cfg, 2).unwrap();
    let model = NativeModel::from_params(&cfg, &store.order, &store.params).unwrap();
    let corpus = Corpus::tiny();
    let (_, val_text) = corpus.split();
    let tok = ByteTokenizer;
    let sampler = BatchSampler::new(tok.encode(val_text), cfg.train_batch, cfg.ctx, 0);
    let batches = sampler.eval_batches(2);
    assert!(!batches.is_empty());
    let mut total = 0.0;
    for (x, y) in &batches {
        total += model.loss(x, y, cfg.train_batch, cfg.ctx).unwrap();
    }
    let loss = total / batches.len() as f64;
    // untrained byte model: near ln(256) = 5.545
    assert!((4.5..6.5).contains(&loss), "{loss}");
}

#[test]
fn native_generation_deterministic_and_checkpoint_stable() {
    let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
    let store = ParamStore::init(&cfg, 5).unwrap();

    let mut g1 = Generator::native(&cfg, &store, 0).unwrap();
    let mut g2 = Generator::native(&cfg, &store, 99).unwrap(); // rng unused at T=0
    let a = g1.generate_batch(&["hello ".into()], 12, 0.0).unwrap();
    let b = g2.generate_batch(&["hello ".into()], 12, 0.0).unwrap();
    assert_eq!(a, b);
    assert_eq!(a[0].len(), 12);

    // checkpoint round-trip produces the same continuation
    let dir = std::env::temp_dir().join("consmax_native_backend_test");
    let ckpt = dir.join("native.ckpt");
    store.save(&ckpt).unwrap();
    let reloaded = ParamStore::load(&ckpt, &cfg).unwrap();
    let mut g3 = Generator::native(&cfg, &reloaded, 0).unwrap();
    let c = g3.generate_batch(&["hello ".into()], 12, 0.0).unwrap();
    assert_eq!(a, c);
}

#[test]
fn softmax_and_softermax_variants_generate_natively() {
    for norm in ["softmax", "softermax"] {
        let cfg = ModelConfig::builtin("tiny", norm).unwrap();
        let store = ParamStore::init(&cfg, 3).unwrap();
        let mut g = Generator::native(&cfg, &store, 0).unwrap();
        let out = g.generate_batch(&["abc ".into()], 6, 0.0).unwrap();
        assert_eq!(out[0].len(), 6, "{norm}");
    }
}
