//! Cross-language pinning: the Rust bit-exact LUT model must produce the
//! SAME BITS as the python oracle (`kernels/ref.py`) recorded in
//! `artifacts/golden.json`. This closes the triangle:
//!
//!   python oracle == pallas kernel (pytest)
//!   pallas kernel == AOT artifact through PJRT (runtime_integration)
//!   python oracle == rust quant model (THIS FILE)
//!
//! so all four implementations of the paper's hardware datapath agree to
//! the bit.

use consmax::quant::{merge_beta_gamma, BitSplitLut, Int8Quantizer};
use consmax::util::fp16::F16;
use consmax::util::json::Json;

fn golden() -> Option<Json> {
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/golden.json");
    let Ok(text) = std::fs::read_to_string(path) else {
        eprintln!("SKIP: golden.json missing, run `make artifacts`");
        return None;
    };
    Some(Json::parse(&text).expect("parse golden"))
}

#[test]
fn lut_tables_match_python_bits() {
    let Some(g) = golden() else { return };
    let t = g.get("lut_tables_s16");
    let want_msb: Vec<u16> = t
        .get("msb_bits")
        .to_f64_vec()
        .unwrap()
        .iter()
        .map(|&v| v as u16)
        .collect();
    let want_lsb: Vec<u16> = t
        .get("lsb_bits")
        .to_f64_vec()
        .unwrap()
        .iter()
        .map(|&v| v as u16)
        .collect();
    let (msb, lsb) = BitSplitLut::paper().table_bits();
    assert_eq!(msb.to_vec(), want_msb, "MSB ROM image differs from python");
    assert_eq!(lsb.to_vec(), want_lsb, "LSB ROM image differs from python");
}

#[test]
fn lut_exp_matches_python_bits_full_grid_scale16() {
    let Some(g) = golden() else { return };
    check_grid(&g, "lut_exp_s16", 1.0 / 16.0);
}

#[test]
fn lut_exp_matches_python_bits_full_grid_scale32() {
    let Some(g) = golden() else { return };
    check_grid(&g, "lut_exp_s32", 1.0 / 32.0);
}

fn check_grid(g: &Json, key: &str, scale: f32) {
    let e = g.get(key);
    assert_eq!(e.get("scale").as_f64().unwrap() as f32, scale);
    let qs: Vec<i8> = e
        .get("q")
        .to_f64_vec()
        .unwrap()
        .iter()
        .map(|&v| v as i8)
        .collect();
    let want: Vec<u16> = e
        .get("out_bits")
        .to_f64_vec()
        .unwrap()
        .iter()
        .map(|&v| v as u16)
        .collect();
    let lut = BitSplitLut::new(scale);
    for (q, w) in qs.iter().zip(&want) {
        let got = lut.exp(*q).to_bits();
        assert_eq!(
            got, *w,
            "q={q} scale={scale}: rust {got:#06x} vs python {:#06x}",
            w
        );
    }
}

#[test]
fn consmax_golden_reproduced_via_quantized_path() {
    // quantize the float golden scores, run the full hw path, compare to
    // the float consmax within the quantization error bound
    let Some(g) = golden() else { return };
    let gc = g.get("consmax");
    let s: Vec<f32> = gc
        .get("s")
        .to_f64_vec()
        .unwrap()
        .iter()
        .map(|&v| v as f32)
        .collect();
    let beta = gc.get("beta").as_f64().unwrap() as f32;
    let gamma = gc.get("gamma").as_f64().unwrap() as f32;
    let want = gc.get("out").to_f64_vec().unwrap();

    let quant = Int8Quantizer::paper();
    let lut = BitSplitLut::paper();
    let c = merge_beta_gamma(beta, gamma);
    for (x, w) in s.iter().zip(&want) {
        let q = quant.quantize(*x);
        let hw = lut.consmax(q, c).to_f32() as f64;
        // error budget: score quantization (±scale/2 in the exponent) +
        // fp16 of output (c ~ 2e-3 so results ~1e-3, near fp16 subnormal
        // boundary — allow 2%+quantization)
        let tol = w * ((quant.scale as f64 / 2.0).exp() - 1.0) + w * 0.02 + 1e-6;
        assert!(
            (hw - w).abs() <= tol,
            "x={x}: hw {hw} vs float {w} (tol {tol})"
        );
    }
}

#[test]
fn merged_constant_matches_python() {
    let Some(g) = golden() else { return };
    let gc = g.get("consmax");
    let beta = gc.get("beta").as_f64().unwrap() as f32;
    let gamma = gc.get("gamma").as_f64().unwrap() as f32;
    let c_py = gc.get("c").as_f64().unwrap() as f32;
    let c_rs = merge_beta_gamma(beta, gamma);
    assert_eq!(c_rs.to_bits(), F16::from_f32(c_py).to_bits());
}
