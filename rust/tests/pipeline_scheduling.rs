//! Normalizer *scheduling* semantics of `sim/pipeline.rs`, pinned as
//! tests (satellite of the backend PR): the module doc claims ConSmax
//! emits with **zero barrier cycles** — each score normalized a fixed
//! latency after it arrives — while Softmax pays a second full pass over
//! the buffered vector (exp+sum) before emission can even start, and
//! Softermax folds the sum pass into arrival but still pays the
//! per-token barrier. These tests assert those schedules structurally
//! (busy-cycle accounting + segment timing), not just end-to-end totals.

use consmax::sim::{simulate, NormKind, Schedule, Workload};

const SEQ: usize = 256;

fn gen() -> Workload {
    Workload::paper_generation(SEQ)
}

/// Norm-unit busy cycles per design, single token:
/// ConSmax touches each element once (streaming), Softermax twice
/// (arrival + emit), Softmax three times (arrival + exp/sum pass + emit).
#[test]
fn norm_unit_pass_count_by_design() {
    let w = gen();
    let cs = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
    let so = simulate(&w, NormKind::Softermax, Schedule::TokenPipeline);
    let sm = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
    assert_eq!(cs.norm_unit.busy_cycles, SEQ as u64, "consmax: one touch/elem");
    assert_eq!(so.norm_unit.busy_cycles, 2 * SEQ as u64, "softermax: two passes");
    assert_eq!(sm.norm_unit.busy_cycles, 3 * SEQ as u64, "softmax: three passes");
}

/// Zero-barrier claim, stated on the PV side: under ConSmax the PV module
/// starts consuming as soon as the FIRST normalized element emerges
/// (QK latency + 1 norm cycle + pipeline fill), not after the token.
#[test]
fn consmax_pv_starts_after_pipeline_fill_only() {
    let w = gen();
    let r = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
    let first_pv_start = r.pv.segments.first().expect("pv ran").0;
    let expected = w.qk_cycles_per_elem() + 1 + w.norm_latency;
    assert_eq!(
        first_pv_start, expected,
        "PV must start right after the first element clears the normalizer"
    );
}

/// Softmax's second-pass latency: emission (and therefore PV) cannot
/// begin until the whole score vector has arrived AND been re-read for
/// the exp/sum pass — at least 2·seq cycles of barrier before the divide
/// pass even starts, so PV starts no earlier than 3·seq.
#[test]
fn softmax_pv_waits_for_second_pass() {
    let w = gen();
    let r = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
    let first_pv_start = r.pv.segments.first().expect("pv ran").0;
    assert!(
        first_pv_start >= 3 * SEQ as u64,
        "softmax PV started at {first_pv_start}, before arrival+sum+emit \
         ({} expected minimum)",
        3 * SEQ
    );
}

/// The barrier gap itself: time between the last QK arrival and the
/// first norm emission. ConSmax: O(1) (its pipeline latency). Softmax:
/// O(seq) (the buffered exp/sum pass).
#[test]
fn barrier_gap_is_constant_for_consmax_linear_for_softmax() {
    for seq in [128usize, 512, 2048] {
        let w = Workload::paper_generation(seq);
        let last_arrival = seq as u64 * w.qk_cycles_per_elem();

        let cs = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
        let cs_first_pv = cs.pv.segments.first().unwrap().0;
        // gap measured from the FIRST arrival for the streaming design:
        // emission begins while QK is still producing
        assert!(
            cs_first_pv < last_arrival,
            "seq {seq}: consmax PV should overlap QK ({cs_first_pv} vs \
             {last_arrival})"
        );

        let sm = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
        let sm_first_pv = sm.pv.segments.first().unwrap().0;
        let gap = sm_first_pv.saturating_sub(last_arrival);
        assert!(
            gap >= 2 * seq as u64,
            "seq {seq}: softmax barrier gap {gap} should be >= 2*seq"
        );
    }
}

/// Work conservation under the barrier: the barrier changes *when* PV
/// runs, never *how much* — identical busy cycles across designs.
#[test]
fn barrier_shifts_but_conserves_pv_work() {
    let w = gen();
    let cs = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
    let sm = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
    assert_eq!(cs.pv.busy_cycles, sm.pv.busy_cycles);
    assert_eq!(cs.qk.busy_cycles, sm.qk.busy_cycles);
    // ...which is exactly why eliminating the barrier shows up 1:1 in
    // total latency:
    assert!(cs.total_cycles + 2 * SEQ as u64 <= sm.total_cycles);
}

/// Multi-token runs: the softmax norm unit serializes three passes per
/// token through one unit, so its busy share approaches 100% while QK
/// idles; the ConSmax norm unit stays a constant one-touch-per-element.
#[test]
fn multi_token_norm_occupancy() {
    let tokens = 8usize;
    let w = Workload::summarization(tokens, SEQ);
    let sm = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
    let cs = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
    assert_eq!(sm.norm_unit.busy_cycles, (3 * tokens * SEQ) as u64);
    assert_eq!(cs.norm_unit.busy_cycles, (tokens * SEQ) as u64);
    // softmax norm unit is the bottleneck resource in steady state
    let sm_share = sm.norm_unit.busy_cycles as f64 / sm.total_cycles as f64;
    assert!(sm_share > 0.85, "softmax norm share {sm_share}");
}
