//! Property-based tests over the substrates' invariants, driven by the
//! in-repo mini-proptest (`util::proptest`). These are the "invariant"
//! layer of the test pyramid: each property runs dozens of randomized
//! cases and shrinks failures to a smaller witness.

use consmax::quant::{BitSplitLut, Int8Quantizer, ReductionUnit};
use consmax::sim::{simulate, NormKind, Schedule, Workload};
use consmax::util::fp16::F16;
use consmax::util::json::Json;
use consmax::util::proptest::{run_property, Gen};
use consmax::{prop_assert, prop_assert_close};

// ---------------------------------------------------------------------------
// fp16 softfloat
// ---------------------------------------------------------------------------

#[test]
fn prop_f16_roundtrip_through_f32_is_identity() {
    run_property("f16 roundtrip", 300, |g: &mut Gen| {
        let bits = g.u64(0, 0x10000) as u16;
        let h = F16::from_bits(bits);
        if h.is_nan() {
            return Ok(());
        }
        let rt = F16::from_f32(h.to_f32());
        prop_assert!(rt.to_bits() == bits, "bits {bits:#06x} -> {:#06x}", rt.to_bits());
        Ok(())
    });
}

#[test]
fn prop_f16_conversion_is_monotone() {
    run_property("f16 monotone", 300, |g: &mut Gen| {
        let a = g.f32(-60000.0, 60000.0);
        let b = g.f32(-60000.0, 60000.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let fl = F16::from_f32(lo).to_f32();
        let fh = F16::from_f32(hi).to_f32();
        prop_assert!(fl <= fh, "{lo} -> {fl}, {hi} -> {fh}");
        Ok(())
    });
}

#[test]
fn prop_f16_mul_commutes() {
    run_property("f16 mul commutes", 300, |g: &mut Gen| {
        let a = F16::from_f32(g.f32(-100.0, 100.0));
        let b = F16::from_f32(g.f32(-100.0, 100.0));
        prop_assert!(a.mul(b).to_bits() == b.mul(a).to_bits());
        Ok(())
    });
}

#[test]
fn prop_f16_mul_one_is_identity() {
    run_property("f16 mul identity", 200, |g: &mut Gen| {
        let a = F16::from_f32(g.f32(-1000.0, 1000.0));
        prop_assert!(a.mul(F16::ONE).to_bits() == a.to_bits());
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// quantizer + LUT datapath
// ---------------------------------------------------------------------------

#[test]
fn prop_quantizer_error_bounded_in_range() {
    run_property("quantizer error bound", 300, |g: &mut Gen| {
        let scale = *g.choose(&[1.0f32 / 8.0, 1.0 / 16.0, 1.0 / 32.0]);
        let q = Int8Quantizer::new(scale);
        let lim = 127.0 * scale;
        let x = g.f32(-lim, lim);
        let err = (q.dequantize(q.quantize(x)) - x).abs();
        prop_assert!(err <= scale / 2.0 + 1e-6, "x={x} err={err}");
        Ok(())
    });
}

#[test]
fn prop_lut_split_identity() {
    // Eq. 4: q == 16*m + l for the signed nibble split, any q
    run_property("lut split identity", 256, |g: &mut Gen| {
        let q = g.i64(-128, 128) as i8;
        let (mi, li) = BitSplitLut::split(q);
        prop_assert!(16 * (mi as i32 - 8) + li as i32 == q as i32);
        Ok(())
    });
}

#[test]
fn prop_lut_exp_close_to_true_exp() {
    run_property("lut exp accuracy", 300, |g: &mut Gen| {
        let scale = *g.choose(&[1.0f32 / 16.0, 1.0 / 32.0]);
        let lut = BitSplitLut::new(scale);
        let q = g.i64(-128, 128) as i8;
        let got = lut.exp(q).to_f32() as f64;
        let want = (q as f64 * scale as f64).exp();
        prop_assert_close!(got, want, 2e-3);
        Ok(())
    });
}

#[test]
fn prop_consmax_scales_linearly_in_c() {
    // ConSmax(q, 2c) ≈ 2 * ConSmax(q, c): the unit is linear in the
    // merged constant (up to fp16 rounding)
    run_property("consmax linear in C", 200, |g: &mut Gen| {
        let lut = BitSplitLut::paper();
        let q = g.i64(-64, 64) as i8; // keep products well inside fp16
        let c = g.f32(1e-3, 0.1);
        let a = lut.consmax(q, F16::from_f32(c)).to_f32() as f64;
        let b = lut.consmax(q, F16::from_f32(2.0 * c)).to_f32() as f64;
        prop_assert_close!(2.0 * a, b, 5e-3);
        Ok(())
    });
}

#[test]
fn prop_reduction_unit_consistent_with_8bit_unit() {
    // an INT16 code that is a pure high-byte multiple must match the
    // 8-bit unit at 256x the scale
    run_property("reduction vs 8-bit", 200, |g: &mut Gen| {
        let scale = 1.0f32 / 256.0;
        let ru = ReductionUnit::new(scale);
        let hi = g.i64(-8, 8) as i16; // small so fp16 stays finite
        let q16 = hi * 256;
        let got = ru.exp16(q16).to_f32() as f64;
        let want = (q16 as f64 * scale as f64).exp();
        prop_assert_close!(got, want, 2e-3);
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// pipeline simulator conservation laws
// ---------------------------------------------------------------------------

fn random_workload(g: &mut Gen) -> Workload {
    Workload {
        tokens: g.usize(1, 6),
        seq: *g.choose(&[32usize, 64, 128, 256]),
        head_dim: *g.choose(&[16usize, 64]),
        qk_lanes: *g.choose(&[16usize, 64]),
        pv_lanes: *g.choose(&[16usize, 64]),
        norm_latency: g.u64(1, 8),
    }
}

#[test]
fn prop_sim_work_conservation() {
    // QK and PV busy cycles depend only on the workload, never on the
    // normalizer or schedule
    run_property("sim work conservation", 120, |g: &mut Gen| {
        let w = random_workload(g);
        let expect_qk = (w.tokens * w.seq) as u64 * w.qk_cycles_per_elem();
        let expect_pv = (w.tokens * w.seq) as u64 * w.pv_cycles_per_elem();
        for norm in [NormKind::Softmax, NormKind::Softermax, NormKind::ConSmax] {
            let r = simulate(&w, norm, Schedule::TokenPipeline);
            prop_assert!(r.qk.busy_cycles == expect_qk, "{norm:?} qk");
            prop_assert!(r.pv.busy_cycles == expect_pv, "{norm:?} pv");
        }
        let r = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
        prop_assert!(r.qk.busy_cycles == expect_qk);
        prop_assert!(r.pv.busy_cycles == expect_pv);
        Ok(())
    });
}

#[test]
fn prop_sim_elementwise_never_slower() {
    run_property("elementwise <= token pipeline", 120, |g: &mut Gen| {
        let w = random_workload(g);
        let ew = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
        let tp = simulate(&w, NormKind::ConSmax, Schedule::TokenPipeline);
        prop_assert!(
            ew.total_cycles <= tp.total_cycles,
            "ew {} > tp {}",
            ew.total_cycles,
            tp.total_cycles
        );
        Ok(())
    });
}

#[test]
fn prop_sim_consmax_dominates_baselines() {
    run_property("consmax fastest", 120, |g: &mut Gen| {
        let w = random_workload(g);
        let cs = simulate(&w, NormKind::ConSmax, Schedule::ElementWise).total_cycles;
        for norm in [
            NormKind::Softmax,
            NormKind::Softermax,
            NormKind::PartialSoftmax { chunks: 4 },
        ] {
            let other = simulate(&w, norm, Schedule::TokenPipeline).total_cycles;
            prop_assert!(cs <= other, "{norm:?}: {cs} > {other}");
        }
        Ok(())
    });
}

#[test]
fn prop_sim_busy_segments_within_makespan() {
    run_property("segments within makespan", 120, |g: &mut Gen| {
        let w = random_workload(g);
        for (norm, sched) in [
            (NormKind::Softmax, Schedule::TokenPipeline),
            (NormKind::ConSmax, Schedule::ElementWise),
        ] {
            let r = simulate(&w, norm, sched);
            for m in [&r.qk, &r.norm_unit, &r.pv] {
                for &(s, e) in &m.segments {
                    prop_assert!(s <= e && e <= r.total_cycles);
                }
                let sum: u64 = m.segments.iter().map(|(a, b)| b - a).sum();
                prop_assert!(sum == m.busy_cycles);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sim_total_monotone_in_tokens() {
    run_property("more tokens, more cycles", 80, |g: &mut Gen| {
        let mut w = random_workload(g);
        w.tokens = g.usize(1, 4);
        let a = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline).total_cycles;
        let mut w2 = w;
        w2.tokens = w.tokens + 1;
        let b = simulate(&w2, NormKind::Softmax, Schedule::TokenPipeline).total_cycles;
        prop_assert!(b > a);
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// JSON round-trip
// ---------------------------------------------------------------------------

fn random_json(g: &mut Gen, depth: usize) -> Json {
    if depth == 0 {
        return match g.usize(0, 4) {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64(-1e6, 1e6) * 100.0).round() / 100.0),
            _ => Json::Str(
                String::from_utf8(
                    g.vec_u8(0, 12).iter().map(|b| b % 94 + 32).collect(),
                )
                .unwrap(),
            ),
        };
    }
    match g.usize(0, 6) {
        0 => Json::Arr((0..g.usize(0, 4)).map(|_| random_json(g, depth - 1)).collect()),
        1 => Json::from_pairs(
            (0..g.usize(0, 4))
                .map(|i| (format!("k{i}"), random_json(g, depth - 1))),
        ),
        _ => random_json(g, 0),
    }
}

#[test]
fn prop_json_roundtrip() {
    run_property("json roundtrip", 300, |g: &mut Gen| {
        let v = random_json(g, 3);
        let text = v.to_string();
        let back = Json::parse(&text)
            .map_err(|e| format!("reparse failed on {text:?}: {e}"))?;
        prop_assert!(back == v, "{text}");
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// hw estimator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_hw_area_monotone_in_seq() {
    use consmax::hw::{softermax_unit, softmax_unit, EdaFlow, Synthesizer, TechNode, TechProfile};
    run_property("hw area monotone in seq", 60, |g: &mut Gen| {
        let s = Synthesizer::new(TechProfile::new(TechNode::Fin16, EdaFlow::Proprietary));
        let a = g.usize(32, 2048);
        let b = a + g.usize(1, 2048);
        prop_assert!(
            s.synthesize(&softermax_unit(a)).area_mm2
                <= s.synthesize(&softermax_unit(b)).area_mm2
        );
        prop_assert!(
            s.synthesize(&softmax_unit(a)).area_mm2
                <= s.synthesize(&softmax_unit(b)).area_mm2
        );
        Ok(())
    });
}

#[test]
fn prop_hw_energy_curve_has_interior_minimum() {
    use consmax::hw::{consmax_unit, EdaFlow, Precision, Synthesizer, TechNode, TechProfile};
    run_property("hw U-curve", 20, |g: &mut Gen| {
        let flow = if g.bool() { EdaFlow::Proprietary } else { EdaFlow::OpenSource };
        let s = Synthesizer::new(TechProfile::new(TechNode::Fin16, flow));
        let rep = s.synthesize(&consmax_unit(Precision::Int8));
        let sweep = s.energy_sweep(&rep, 60);
        let min = sweep
            .iter()
            .map(|p| p.energy_pj_per_elem)
            .fold(f64::INFINITY, f64::min);
        prop_assert!(min < sweep[0].energy_pj_per_elem);
        prop_assert!(min < sweep.last().unwrap().energy_pj_per_elem);
        Ok(())
    });
}
