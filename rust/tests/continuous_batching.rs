//! Scheduler-equivalence suite: the continuous-batching slot pool must
//! emit **bit-identical greedy tokens per request** to the static
//! reference batcher, no matter which neighbors share its decode steps
//! or when it joined the pool (DESIGN.md §Serving seam).
//!
//! Why this holds: per-row KV blocks are disjoint and every row attends
//! only to its own cached positions, so a row's logits are a function
//! of its own tokens alone — prefill-into-a-live-session
//! (`NativeModel::prefill_rows`) and `decode_step_active` over an
//! arbitrary active mask perform the same float ops in the same order
//! as a solo run. The suite also pins the *accounting* fix: under the
//! continuous scheduler, `latency_ms` is per-row completion time (a
//! 2-token request co-resident with a 48-token one reports a smaller
//! latency), never the batch's wall time.

use consmax::config::{KvCacheConfig, KvDtype, ModelConfig, QuantMode};
use consmax::coordinator::{
    DecodeMode, GenRequest, GenResponse, Generator, ParamStore, Server,
};
use consmax::prop_assert;
use consmax::util::proptest::{run_property, Gen};

fn setup() -> (ModelConfig, ParamStore) {
    let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
    let store = ParamStore::init(&cfg, 5).unwrap();
    (cfg, store)
}

/// Greedy single-request reference: the static oracle at batch 1.
fn oracle_tokens(
    cfg: &ModelConfig,
    store: &ParamStore,
    prompt: &str,
    max_new: usize,
) -> Vec<i32> {
    let mut g = Generator::native(cfg, store, 0).unwrap();
    g.generate_batch_ext(&[prompt.to_string()], &[max_new], &[0.0])
        .unwrap()
        .tokens
        .remove(0)
}

fn greedy_req(id: u64, prompt: &str, max_new: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: prompt.into(),
        max_new_tokens: max_new,
        temperature: 0.0,
        stop: None,
        deadline_ms: None,
    }
}

fn by_id(mut responses: Vec<GenResponse>) -> Vec<GenResponse> {
    responses.sort_by_key(|r| r.id);
    responses
}

#[test]
fn continuous_matches_static_oracle_per_request() {
    // mixed prompts and budgets co-resident in one pool: every request
    // decodes exactly as it would alone
    let (cfg, store) = setup();
    let reqs = [
        ("The constant softmax ", 9usize),
        ("Attention ", 1),
        ("x", 6),
        ("", 4), // clamps to empty: completes with no tokens, no slot
        ("A much longer prompt that spans a few more byte tokens ", 12),
        ("tail ", 3),
    ];
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    for (id, (prompt, max_new)) in reqs.iter().enumerate() {
        server.submit(greedy_req(id as u64, prompt, *max_new));
    }
    let responses = by_id(server.run_continuous().unwrap());
    assert_eq!(responses.len(), reqs.len());
    for (r, (prompt, max_new)) in responses.iter().zip(&reqs) {
        let want = if prompt.is_empty() {
            Vec::new()
        } else {
            oracle_tokens(&cfg, &store, prompt, *max_new)
        };
        assert_eq!(
            r.tokens, want,
            "req {} diverged from the solo static oracle",
            r.id
        );
        assert_eq!(r.new_tokens, want.len());
    }
}

#[test]
fn mid_flight_joins_do_not_disturb_residents() {
    // join while neighbors are mid-decode, leave before they finish:
    // ragged prompts, mixed budgets, staggered submission
    let (cfg, store) = setup();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    server.submit(greedy_req(0, "long resident request ", 16));
    server.submit(greedy_req(1, "short ", 2));
    // a few ticks: req 1 completes and frees its slot mid-flight
    let mut responses = Vec::new();
    for _ in 0..4 {
        responses.extend(server.step().unwrap());
    }
    // late joiners take the freed slot while req 0 is still decoding
    server.submit(greedy_req(2, "late joiner A ", 5));
    server.submit(greedy_req(3, "late joiner B", 8));
    responses.extend(server.run_continuous().unwrap());

    let responses = by_id(responses);
    assert_eq!(responses.len(), 4);
    let cases = [
        ("long resident request ", 16usize),
        ("short ", 2),
        ("late joiner A ", 5),
        ("late joiner B", 8),
    ];
    for (r, (prompt, max_new)) in responses.iter().zip(&cases) {
        let want = oracle_tokens(&cfg, &store, prompt, *max_new);
        assert_eq!(r.tokens, want, "req {} diverged", r.id);
    }
}

#[test]
fn join_leave_proptest_ragged_prompts_mixed_budgets() {
    // randomized join/leave churn: random prompts (incl. over-ctx ones
    // that clamp and empty ones that complete-and-skip), random budgets
    // (incl. zero), random step interleave — every request must match
    // its solo oracle bit-for-bit. Exercised on the dense slot pool,
    // the budgetless paged pool (prefix sharing live), and a
    // tight-budget paged pool (preempt-and-requeue live): the memory
    // model must never leak into outputs.
    let (cfg, store) = setup();
    let pools: [Option<KvCacheConfig>; 3] = [
        None,
        Some(KvCacheConfig {
            dtype: KvDtype::F32,
            block_tokens: 8,
            mem_bytes: None,
        }),
        Some(KvCacheConfig {
            dtype: KvDtype::F32,
            block_tokens: 16,
            // 9 blocks: pressure with a few co-resident rows
            mem_bytes: Some(
                9 * 2 * cfg.n_layer * cfg.n_head * 16 * cfg.head_dim() * 4,
            ),
        }),
    ];
    for (pi, kv) in pools.iter().enumerate() {
        run_property("continuous == static oracle under churn", 6, |g: &mut Gen| {
            let n = g.usize(3, 9);
            let mut reqs: Vec<(String, usize)> = Vec::new();
            for _ in 0..n {
                let plen = g.usize(0, 90); // ctx is 64: some prompts clamp
                let prompt: String = (0..plen)
                    .map(|_| (b'a' + (g.usize(0, 26) as u8)) as char)
                    .collect();
                let max_new = g.usize(0, 8);
                reqs.push((prompt, max_new));
            }
            let mut server =
                Server::new(Generator::native(&cfg, &store, 0).unwrap());
            if let Some(kv) = kv {
                server.set_kv_config(Some(*kv)).unwrap();
            }
            let split = g.usize(0, n + 1);
            for (id, (prompt, max_new)) in reqs.iter().take(split).enumerate() {
                server.submit(greedy_req(id as u64, prompt, *max_new));
            }
            let mut responses = Vec::new();
            for _ in 0..g.usize(0, 5) {
                responses.extend(server.step().unwrap());
            }
            for (id, (prompt, max_new)) in
                reqs.iter().enumerate().skip(split)
            {
                server.submit(greedy_req(id as u64, prompt, *max_new));
            }
            responses.extend(server.run_continuous().unwrap());
            prop_assert!(
                responses.len() == reqs.len(),
                "pool {pi}: served {} of {} requests",
                responses.len(),
                reqs.len()
            );
            let responses = {
                let mut r = responses;
                r.sort_by_key(|x| x.id);
                r
            };
            for (r, (prompt, max_new)) in responses.iter().zip(&reqs) {
                let want = if prompt.is_empty() {
                    Vec::new()
                } else {
                    oracle_tokens(&cfg, &store, prompt, *max_new)
                };
                prop_assert!(
                    r.tokens == want,
                    "pool {pi}: req {} (prompt {:?}, max_new {}) diverged: \
                     {:?} vs {:?}",
                    r.id,
                    prompt,
                    max_new,
                    r.tokens,
                    want
                );
            }
            Ok(())
        });
    }
}

/// Solo oracle for the fully quantized serving stack: int8 weights +
/// LUT tail *and* int8 KV blocks need an oracle with the identical
/// KV/quant config, because int8 KV storage is lossy — the dense-f32
/// oracle pins a different function.
fn int8_solo_tokens(
    cfg: &ModelConfig,
    store: &ParamStore,
    kv: &KvCacheConfig,
    prompt: &str,
    max_new: usize,
) -> Vec<i32> {
    let gen =
        Generator::native_quant(cfg, store, 0, DecodeMode::Kv, QuantMode::Int8)
            .unwrap();
    let mut server = Server::new(gen);
    server.set_kv_config(Some(*kv)).unwrap();
    server.set_max_batch(1).unwrap();
    server.submit(greedy_req(0, prompt, max_new));
    by_id(server.run_continuous().unwrap()).remove(0).tokens
}

#[test]
fn int8_join_leave_proptest_matches_int8_solo_oracle() {
    // the same churn property on the fully quantized stack
    // (`--quant int8 --kv-dtype int8`): budgetless (prefix sharing
    // live) and tight-budget (preempt-and-requeue live) int8 pools.
    // Preemption re-encode re-quantizes the same activations, and pow2
    // scales make that idempotent, so outputs must still be bitwise
    // solo — scheduling may never leak into a quantized request either.
    let (cfg, store) = setup();
    let stride16 = cfg.n_layer * cfg.n_head * 16 * cfg.head_dim();
    let int8_block_bytes = 2 * stride16 + 2 * (stride16 / cfg.head_dim()) * 4;
    let pools: [KvCacheConfig; 2] = [
        KvCacheConfig {
            dtype: KvDtype::Int8,
            block_tokens: 8,
            mem_bytes: None,
        },
        KvCacheConfig {
            dtype: KvDtype::Int8,
            block_tokens: 16,
            // 9 blocks: pressure with a few co-resident rows
            mem_bytes: Some(9 * int8_block_bytes),
        },
    ];
    for (pi, kv) in pools.iter().enumerate() {
        run_property("int8 continuous == int8 solo under churn", 4, |g: &mut Gen| {
            let n = g.usize(3, 8);
            let mut reqs: Vec<(String, usize)> = Vec::new();
            for _ in 0..n {
                let plen = g.usize(0, 90); // ctx is 64: some prompts clamp
                let prompt: String = (0..plen)
                    .map(|_| (b'a' + (g.usize(0, 26) as u8)) as char)
                    .collect();
                let max_new = g.usize(0, 8);
                reqs.push((prompt, max_new));
            }
            let gen = Generator::native_quant(
                &cfg,
                &store,
                0,
                DecodeMode::Kv,
                QuantMode::Int8,
            )
            .unwrap();
            let mut server = Server::new(gen);
            server.set_kv_config(Some(*kv)).unwrap();
            let split = g.usize(0, n + 1);
            for (id, (prompt, max_new)) in reqs.iter().take(split).enumerate() {
                server.submit(greedy_req(id as u64, prompt, *max_new));
            }
            let mut responses = Vec::new();
            for _ in 0..g.usize(0, 5) {
                responses.extend(server.step().unwrap());
            }
            for (id, (prompt, max_new)) in
                reqs.iter().enumerate().skip(split)
            {
                server.submit(greedy_req(id as u64, prompt, *max_new));
            }
            responses.extend(server.run_continuous().unwrap());
            prop_assert!(
                responses.len() == reqs.len(),
                "int8 pool {pi}: served {} of {} requests",
                responses.len(),
                reqs.len()
            );
            let responses = by_id(responses);
            for (r, (prompt, max_new)) in responses.iter().zip(&reqs) {
                let want = if prompt.is_empty() {
                    Vec::new()
                } else {
                    int8_solo_tokens(&cfg, &store, kv, prompt, *max_new)
                };
                prop_assert!(
                    r.tokens == want,
                    "int8 pool {pi}: req {} (prompt {:?}, max_new {}) \
                     diverged: {:?} vs {:?}",
                    r.id,
                    prompt,
                    max_new,
                    r.tokens,
                    want
                );
            }
            Ok(())
        });
    }
}

#[test]
fn slots_are_reused_past_the_pool_size() {
    // more requests than slots: finished rows free their slot the step
    // they complete, and the queue streams through the pool
    let (cfg, store) = setup();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    server.set_max_batch(3).unwrap();
    let n = 11u64;
    for id in 0..n {
        server.submit(greedy_req(id, "recycled slot ", 2 + (id % 3) as usize));
    }
    let responses = by_id(server.run_continuous().unwrap());
    assert_eq!(responses.len(), n as usize);
    assert_eq!(server.in_flight(), 0);
    assert!(responses.iter().all(|r| r.batch_size <= 3));
    for r in &responses {
        let want =
            oracle_tokens(&cfg, &store, "recycled slot ", 2 + (r.id % 3) as usize);
        assert_eq!(r.tokens, want, "req {} diverged", r.id);
    }
}

#[test]
fn stop_token_ends_generation_early_on_both_schedulers() {
    let (cfg, store) = setup();
    let full = oracle_tokens(&cfg, &store, "stop after three ", 16);
    let stop = full[3];
    // the stop token must not appear earlier (pick the first occurrence)
    let cut = full.iter().position(|&t| t == stop).unwrap();
    let want = &full[..cut];

    let mut req = greedy_req(0, "stop after three ", 16);
    req.stop = Some(stop);

    let mut cont = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    cont.submit(req.clone());
    let r = by_id(cont.run_continuous().unwrap()).remove(0);
    assert_eq!(r.tokens, want, "continuous: stop token not honored");
    assert_eq!(r.new_tokens, cut);
    assert_eq!(cont.tokens_out, cut as u64);

    let mut stat = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    stat.submit(req);
    let r = stat.run_to_completion().unwrap().remove(0);
    assert_eq!(r.tokens, want, "static: stop token not honored");
    assert_eq!(r.new_tokens, cut);
}

#[test]
fn zero_budget_requests_complete_immediately() {
    let (cfg, store) = setup();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    server.submit(greedy_req(0, "no tokens please", 0));
    server.submit(greedy_req(1, "some tokens ", 3));
    let responses = by_id(server.run_continuous().unwrap());
    assert_eq!(responses.len(), 2);
    assert_eq!(responses[0].new_tokens, 0);
    assert_eq!(responses[0].text, "");
    assert!(responses[0].prompt_tokens > 0);
    assert_eq!(responses[1].new_tokens, 3);
    assert_eq!(server.tokens_out, 3);
}

#[test]
fn latency_is_per_row_completion_not_batch_wall() {
    // a 2-token request co-resident with a 48-token one must report a
    // (much) smaller completion latency — pre-fix, every row of a batch
    // reported the same batch wall time
    let (cfg, store) = setup();
    let mut server = Server::new(Generator::native(&cfg, &store, 0).unwrap());
    server.submit(greedy_req(0, "short one ", 2));
    server.submit(greedy_req(1, "long one ", 48));
    let responses = by_id(server.run_continuous().unwrap());
    let (short, long) = (&responses[0], &responses[1]);
    assert_eq!(short.new_tokens, 2);
    assert_eq!(long.new_tokens, 48);
    assert!(
        short.latency_ms < long.latency_ms,
        "per-request latency lost: short {} ms vs long {} ms",
        short.latency_ms,
        long.latency_ms
    );
    for r in [short, long] {
        assert!(r.ttft_ms > 0.0);
        assert!(r.ttft_ms <= r.latency_ms);
    }
    // TTFT recorder saw both requests; TPOT only the token-emitting ones
    assert_eq!(server.ttft.len(), 2);
    assert_eq!(server.tpot.len(), 2);
}

#[test]
fn recompute_oracle_cannot_run_continuous() {
    let (cfg, store) = setup();
    let gen =
        Generator::native_with(&cfg, &store, 0, DecodeMode::Recompute).unwrap();
    let mut server = Server::new(gen);
    server.submit(greedy_req(0, "p ", 2));
    assert!(server.step().is_err());
    assert_eq!(server.run_to_completion().unwrap().len(), 1);
}
