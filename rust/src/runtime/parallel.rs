//! Std-only data-parallel substrate for the native compute layer.
//!
//! ConSmax's pitch is that the normalizer is reduction-free, so the
//! score→prob→PV stream parallelizes without synchronization (paper
//! §III). This module is the crate's only parallelism primitive: a
//! scoped fork-join pool built on `std::thread::scope` — no external
//! deps, nothing vendored — that the native kernels
//! (`runtime/backend/native.rs`) and the model/decode hot paths
//! (`runtime/backend/{model,decode}.rs`) fan work out over.
//!
//! **Pool ownership.** There is no long-lived pool object: each `par_*`
//! call forks scoped workers and joins them before returning, so
//! borrowed inputs (`&[f32]` weights, `&mut [f32]` outputs) flow into
//! workers without `Arc` or cloning. The calling thread runs the first
//! block itself, so `N` configured threads means `N` busy cores, not
//! `N + 1`. Nested `par_*` calls from inside a worker run serially (a
//! thread-local guard), so composing a parallel outer loop (batch rows)
//! with parallel inner kernels (matmuls) never over-subscribes.
//!
//! **Determinism contract.** Partitioning only decides *who* computes an
//! element, never *how*: every output element is produced by exactly one
//! worker running the exact serial code, and no reduction is ever split
//! across workers. Results are therefore bit-identical for every thread
//! count — pinned by `rust/tests/parallel_equivalence.rs` and the
//! `CONSMAX_THREADS=1` CI leg.
//!
//! **Sizing.** `--threads N` on the CLI (via [`set_threads`]) wins over
//! the `CONSMAX_THREADS` environment variable, which wins over
//! `std::thread::available_parallelism`.

//!
//! **Panic containment.** A panic inside a worker block must not abort
//! the process (a caller-side panic racing a worker-side panic would
//! otherwise double-unwind through `thread::scope`) and must not leave
//! any poisoned pool state. Every block — spawned or caller-run — runs
//! under `catch_unwind`; the first payload is re-raised *after* the
//! scope has joined every worker, so callers observe one clean unwind
//! and the pool (which is stateless) is immediately reusable. The
//! serving layer converts that unwind into a recoverable `Err` with
//! [`catch_panics`]; [`inject_worker_panic_once`] is the deterministic
//! chaos seam the fault-injection suite arms to exercise the path.

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use anyhow::{anyhow, Result};

/// Runtime override installed by `--threads` (0 = unset).
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);
/// Process-wide default, resolved once from the environment.
static DEFAULT: OnceLock<usize> = OnceLock::new();

thread_local! {
    /// Set inside pool workers so nested `par_*` calls run serially.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
    /// One-shot fault-injection flag, armed on the *calling* thread
    /// (thread-local so concurrent tests never steal each other's
    /// injections): the next `par_*` call from this thread panics in
    /// one of its worker blocks.
    static INJECT_PANIC: Cell<bool> = const { Cell::new(false) };
}

/// Message carried by an injected worker panic (asserted on in tests).
pub const INJECTED_PANIC_MSG: &str = "injected worker panic (fault plan)";

/// Arm a one-shot panic in the next `par_*` call issued from this
/// thread: with ≥2 workers the first *spawned* worker panics (the real
/// cross-thread unwind path); with 1 it panics in the serial path, so
/// the observable behaviour — one clean unwind out of the `par_*` call —
/// is identical at every thread count. Chaos-testing seam; see
/// [`catch_panics`] for the recovery side.
pub fn inject_worker_panic_once() {
    INJECT_PANIC.with(|c| c.set(true));
}

/// Run `f`, converting any panic that unwinds out of it (including a
/// pool-worker panic re-raised by `par_*` after the scope join) into a
/// clean `Err`. The pool is stateless, so after this returns `Err` the
/// next `par_*` call is safe — nothing is poisoned.
pub fn catch_panics<T>(f: impl FnOnce() -> T) -> Result<T> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(v) => Ok(v),
        Err(payload) => Err(anyhow!("worker panic: {}", panic_message(&payload))),
    }
}

fn panic_message(payload: &(dyn Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Resets the calling thread's in-pool flag even on unwind.
struct PoolGuard;

impl Drop for PoolGuard {
    fn drop(&mut self) {
        IN_POOL.with(|c| c.set(false));
    }
}

fn default_threads() -> usize {
    *DEFAULT.get_or_init(|| {
        std::env::var("CONSMAX_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    })
}

/// Install a process-wide worker count (the `--threads` knob). `0`
/// restores the default (`CONSMAX_THREADS` / available parallelism).
pub fn set_threads(n: usize) {
    OVERRIDE.store(n, Ordering::SeqCst);
}

/// The worker count `par_*` calls will use from the calling thread.
/// Always 1 inside a pool worker (nested parallelism serializes).
pub fn current_threads() -> usize {
    if IN_POOL.with(Cell::get) {
        return 1;
    }
    match OVERRIDE.load(Ordering::SeqCst) {
        0 => default_threads(),
        n => n,
    }
}

/// Split `data` into one contiguous block of whole rows per worker and
/// run `f(first_row_index, block)` on each block in parallel. Blocks
/// are balanced to within one row; with one thread (or one row) this is
/// exactly a serial call `f(0, data)`.
///
/// `data.len()` must be a whole number of rows of `row_len` elements.
pub fn par_row_blocks<T, F>(data: &mut [T], row_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(row_len > 0, "row_len must be positive");
    assert_eq!(
        data.len() % row_len,
        0,
        "data ({}) is not a whole number of rows of {row_len}",
        data.len()
    );
    let n_rows = data.len() / row_len;
    if n_rows == 0 {
        return;
    }
    let inject = INJECT_PANIC.with(Cell::take);
    let threads = current_threads().min(n_rows);
    if threads <= 1 {
        if inject {
            panic!("{INJECTED_PANIC_MSG}");
        }
        f(0, data);
        return;
    }

    // Carve the data into `threads` balanced runs of whole rows.
    let base = n_rows / threads;
    let extra = n_rows % threads;
    let mut blocks: Vec<(usize, &mut [T])> = Vec::with_capacity(threads);
    let mut rest = data;
    let mut first_row = 0usize;
    for t in 0..threads {
        let rows = base + usize::from(t < extra);
        let taken = std::mem::take(&mut rest);
        let (head, tail) = taken.split_at_mut(rows * row_len);
        rest = tail;
        blocks.push((first_row, head));
        first_row += rows;
    }

    // Every block runs under `catch_unwind` so a panicking block can
    // never race a second unwind through the scope join (which would
    // abort). The first payload is re-raised once, after all workers
    // have joined, as a single clean unwind out of this call.
    let f = &f;
    let first_panic: Mutex<Option<Box<dyn Any + Send>>> = Mutex::new(None);
    let record = |payload: Box<dyn Any + Send>| {
        let mut slot = first_panic.lock().unwrap_or_else(|e| e.into_inner());
        slot.get_or_insert(payload);
    };
    let record = &record;
    std::thread::scope(|scope| {
        let mut blocks = blocks.into_iter();
        let own = blocks.next().expect("threads >= 2 implies a first block");
        for (i, (start, block)) in blocks.enumerate() {
            let boom = inject && i == 0;
            scope.spawn(move || {
                IN_POOL.with(|c| c.set(true));
                if let Err(payload) = catch_unwind(AssertUnwindSafe(|| {
                    if boom {
                        panic!("{INJECTED_PANIC_MSG}");
                    }
                    f(start, block);
                })) {
                    record(payload);
                }
            });
        }
        // The caller works too, flagged so nested calls stay serial.
        IN_POOL.with(|c| c.set(true));
        let _guard = PoolGuard;
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(own.0, own.1))) {
            record(payload);
        }
    });
    let panicked = first_panic.into_inner().unwrap_or_else(|e| e.into_inner());
    if let Some(payload) = panicked {
        resume_unwind(payload);
    }
}

/// Run `f(chunk_index, chunk)` over consecutive `chunk_len`-element
/// chunks of `data`, distributing chunks across workers in contiguous
/// runs. `data.len()` must be a multiple of `chunk_len`.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_row_blocks(data, chunk_len, |first, block| {
        for (i, chunk) in block.chunks_mut(chunk_len).enumerate() {
            f(first + i, chunk);
        }
    });
}

/// Run `f(index, item)` over every item, distributing contiguous runs
/// of items across workers.
pub fn par_items<T, F>(items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    par_row_blocks(items, 1, |first, block| {
        for (i, item) in block.iter_mut().enumerate() {
            f(first + i, item);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_row_visited_exactly_once() {
        let mut data = vec![0u32; 12 * 3];
        par_row_blocks(&mut data, 3, |first_row, block| {
            for (i, row) in block.chunks_mut(3).enumerate() {
                for v in row.iter_mut() {
                    *v += 1 + (first_row + i) as u32;
                }
            }
        });
        for (i, row) in data.chunks(3).enumerate() {
            assert!(row.iter().all(|&v| v == 1 + i as u32), "row {i}: {row:?}");
        }
    }

    #[test]
    fn chunk_indices_are_global() {
        let mut data = vec![0usize; 40];
        par_chunks_mut(&mut data, 4, |idx, chunk| {
            for v in chunk.iter_mut() {
                *v = idx;
            }
        });
        for (i, chunk) in data.chunks(4).enumerate() {
            assert!(chunk.iter().all(|&v| v == i), "chunk {i}: {chunk:?}");
        }
    }

    #[test]
    fn items_see_their_own_index() {
        let mut items: Vec<(usize, usize)> = (0..17).map(|i| (i, 0)).collect();
        par_items(&mut items, |idx, item| {
            item.1 = idx;
        });
        assert!(items.iter().all(|&(a, b)| a == b), "{items:?}");
    }

    #[test]
    fn empty_and_tiny_inputs_are_fine() {
        let mut empty: Vec<u8> = Vec::new();
        par_row_blocks(&mut empty, 4, |_, _| panic!("no rows, no calls"));
        let mut one = vec![7u8];
        par_items(&mut one, |i, v| {
            assert_eq!(i, 0);
            *v += 1;
        });
        assert_eq!(one, vec![8]);
    }

    #[test]
    fn override_env_and_nesting_rules() {
        // The single test that touches the global override (other tests
        // in this binary must not call set_threads, so no race).
        set_threads(3);
        assert_eq!(current_threads(), 3);

        // Workers report one thread: nested parallelism serializes.
        let mut seen = vec![0usize; 6];
        par_items(&mut seen, |_, v| {
            *v = current_threads();
        });
        assert!(seen.iter().all(|&v| v == 1), "{seen:?}");
        // ...and the caller's flag is restored after the join.
        assert_eq!(current_threads(), 3);

        set_threads(0);
        assert!(current_threads() >= 1);
    }

    #[test]
    fn worker_panic_surfaces_as_clean_err_and_pool_stays_usable() {
        // A panic in one worker block must unwind out of the par_* call
        // exactly once (no double-panic abort even though every block
        // panics here) and convert to Err at the catch_panics seam.
        let mut data = vec![0u32; 16];
        let err = catch_panics(|| {
            par_items(&mut data, |_, _| panic!("kernel exploded"));
        });
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("kernel exploded"), "{msg}");

        // Nothing is poisoned: the very next call computes normally.
        let mut after = vec![0u32; 16];
        par_items(&mut after, |i, v| *v = i as u32);
        assert!(after.iter().enumerate().all(|(i, &v)| v == i as u32));
    }

    #[test]
    fn injected_panic_fires_once_then_clears() {
        let mut data = vec![0u32; 8];
        inject_worker_panic_once();
        let err = catch_panics(|| par_items(&mut data, |_, v| *v += 1)).unwrap_err();
        assert!(
            format!("{err:#}").contains(INJECTED_PANIC_MSG),
            "unexpected error: {err:#}"
        );

        // One-shot: the same call succeeds immediately afterwards.
        let mut after = vec![0u32; 8];
        catch_panics(|| par_items(&mut after, |_, v| *v += 1)).unwrap();
        assert!(after.iter().all(|&v| v == 1), "{after:?}");
    }

    #[test]
    fn partition_is_invariant_to_worker_count() {
        // The determinism contract at the primitive level: the same
        // writes happen for any thread count.
        let run = || {
            let mut data = vec![0f32; 64];
            par_chunks_mut(&mut data, 8, |idx, chunk| {
                for (e, v) in chunk.iter_mut().enumerate() {
                    *v = (idx * 8 + e) as f32 * 0.5;
                }
            });
            data
        };
        assert_eq!(run(), run());
    }
}
