//! Hardened TCP/HTTP serving front end (std-only, DESIGN.md
//! §Serving-robustness seam).
//!
//! This module puts a wire on the continuous-batching scheduler and is
//! *designed around failure*: every path a real client can break is
//! bounded, observable, and drives the request to exactly one terminal
//! state.
//!
//! * **Bounded ingress + load shedding.** Parsed requests land in a
//!   bounded handoff queue; past the cap the connection gets an
//!   immediate `429` with `Retry-After` (it never queues unboundedly).
//!   Admission itself is the engine's verdict ([`ServeEngine::try_admit`]
//!   — queue depth / estimated-TTFT limits), which also sheds with a
//!   backoff hint.
//! * **Per-token streaming with heartbeats.** Admitted requests stream
//!   NDJSON lines (`{"token":N}` per generated token, `{"hb":1}` when
//!   idle past the heartbeat interval, a final `{"done":true,...}`
//!   terminal line). Writes go through a per-connection bounded outbox
//!   drained by a writer thread, so one slow reader can never stall the
//!   serve loop — an outbox overflow *is* the slow-reader verdict: the
//!   connection is dropped and the request cancelled.
//! * **Disconnect cancellation.** A monitor thread per connection
//!   watches for EOF; the serve loop cancels the request mid-flight
//!   ([`ServeEngine::cancel`] frees the row and its paged KV blocks).
//! * **Graceful drain.** On SIGTERM ([`install_sigterm_drain`]) or
//!   [`request_drain`]: stop admitting (`503`), keep ticking until
//!   residents finish or the drain timeout lapses (then cancel the
//!   remainder), flush stats, return a [`NetReport`].
//! * **Deterministic fault injection.** A [`FaultPlan`] arms faults at
//!   the two seams the chaos suite exercises: a worker panic on a given
//!   tick (`runtime::parallel::inject_worker_panic_once`) and
//!   server-side mid-stream disconnects after N streamed tokens.
//!   Slow readers, malformed requests and KV-pressure spikes need no
//!   injection hooks — real client behaviour and tiny budgets produce
//!   them (`rust/tests/chaos_serving.rs`).
//!
//! The engine behind the wire is abstracted as [`ServeEngine`] so this
//! layer has no dependency on the coordinator; the production
//! implementation is `coordinator::net::EngineAdapter` over `Server`.
//!
//! **Wire protocol.** `POST /generate` with a JSON body
//! `{"prompt": "...", "max_new": 16, "temperature": 0.0,
//! "deadline_ms": 2000}` (all but `prompt` optional) answers
//! `200` + NDJSON stream, `429` + `Retry-After` when shedding, `400` on
//! malformed input, `503` while draining. `GET /stats` returns the
//! engine's gauge snapshot as JSON.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::runtime::parallel;
use crate::util::json::Json;

/// A request as the wire sees it. Decoupled from the coordinator's
/// `GenRequest`: the runtime layer never depends on the coordinator.
#[derive(Debug, Clone)]
pub struct NetRequest {
    /// Connection-order id assigned by the serve loop (also echoed to
    /// the client as `X-Request-Id`).
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    pub temperature: f32,
    /// Relative deadline in ms (from admission); `None` = engine
    /// default.
    pub deadline_ms: Option<u64>,
}

/// Admission verdict from the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetAdmission {
    Admitted,
    /// Overloaded: not enqueued; `retry_after_ms` is the backoff hint.
    Shed { retry_after_ms: u64 },
}

/// Lifecycle events the engine yields from [`ServeEngine::tick`].
/// `Token` events must be exactly-once per token position even across
/// engine-internal replays (preemption, panic recovery).
#[derive(Debug, Clone)]
pub enum NetEvent {
    Token { id: u64, token: i32 },
    Completed { id: u64, text: String, tokens: usize, latency_ms: f64 },
    TimedOut { id: u64 },
    Cancelled { id: u64 },
}

/// What the front end needs from a scheduler. One implementor drives
/// one serve loop; all calls come from the loop's thread.
pub trait ServeEngine {
    /// Bounded admission; a shed request must be counted terminally by
    /// the engine (it will never be re-submitted by this layer).
    fn try_admit(&mut self, req: NetRequest) -> NetAdmission;
    /// Drop a request wherever it lives, freeing its resources
    /// mid-flight. `false` if the id already reached a terminal state.
    fn cancel(&mut self, id: u64) -> bool;
    /// Advance the scheduler one step and return the lifecycle events
    /// since the last tick. Must be safe to call with no work (no-op).
    fn tick(&mut self) -> Result<Vec<NetEvent>>;
    /// Whether any request is queued or in flight.
    fn has_work(&self) -> bool;
    /// Ids of every request still owed a terminal state (drain).
    fn live_ids(&self) -> Vec<u64>;
    /// Gauge snapshot as a JSON object string (`GET /stats`).
    fn stats_json(&self) -> String;
}

/// Front-end knobs (`consmax serve-net` flags map 1:1 onto these).
#[derive(Debug, Clone)]
pub struct NetOptions {
    /// Bounded-ingress cap: parsed-but-unadmitted connections past this
    /// are shed at the door with `429`.
    pub queue_cap: usize,
    /// Idle-stream heartbeat interval (ms).
    pub heartbeat_ms: u64,
    /// How long drain waits for residents before cancelling them (ms).
    pub drain_timeout_ms: u64,
    /// Per-connection outbox depth (queued write commands) before a
    /// reader is judged too slow and disconnected.
    pub outbox_cap: usize,
    /// Start draining after this many admission verdicts (admitted +
    /// shed). `None` = serve until SIGTERM / [`request_drain`].
    pub max_requests: Option<u64>,
    /// Serve-loop sleep when there is nothing to do (µs).
    pub idle_sleep_us: u64,
}

impl Default for NetOptions {
    fn default() -> NetOptions {
        NetOptions {
            queue_cap: 64,
            heartbeat_ms: 500,
            drain_timeout_ms: 5_000,
            outbox_cap: 64,
            max_requests: None,
            idle_sleep_us: 200,
        }
    }
}

/// Deterministic fault injection for the chaos suite. Default = no
/// faults.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Arm a one-shot worker panic just before this serve-loop tick
    /// (0-based count of engine ticks).
    pub panic_on_tick: Option<u64>,
    /// Server-side mid-stream disconnect: after request `id` has
    /// streamed `n` tokens, its connection is closed and the request
    /// cancelled — a deterministic stand-in for a vanishing client.
    pub close_after_tokens: Vec<(u64, usize)>,
}

/// What a serve run did (the drain-time stats flush, also logged).
#[derive(Debug, Clone, Copy, Default)]
pub struct NetReport {
    /// Requests admitted onto the engine.
    pub admitted: u64,
    /// Requests shed with `429` (at the ingress bound or by the
    /// engine's admission limits).
    pub shed: u64,
    /// Malformed requests answered `400`.
    pub rejected: u64,
    /// Requests answered `503` because drain had started.
    pub refused_draining: u64,
    /// Client-vanished cancellations (EOF monitor or injected close).
    pub disconnects: u64,
    /// Slow-reader disconnections (outbox overflow).
    pub slow_readers: u64,
    /// Requests that completed over the wire.
    pub completed: u64,
    /// Requests that hit their deadline.
    pub timed_out: u64,
    /// Engine ticks driven.
    pub ticks: u64,
    /// True when drain finished before the timeout (nothing was
    /// force-cancelled).
    pub drained_clean: bool,
}

// ---- drain signal ---------------------------------------------------------

static DRAIN: AtomicBool = AtomicBool::new(false);

/// Ask the serve loop to drain: stop admitting, finish (or cancel at
/// the timeout) the residents, flush stats, return. Also what the
/// SIGTERM handler calls.
pub fn request_drain() {
    DRAIN.store(true, Ordering::SeqCst);
}

/// Whether a drain has been requested (process-wide).
pub fn drain_requested() -> bool {
    DRAIN.load(Ordering::SeqCst)
}

/// Re-arm serving after a completed drain (tests serving twice in one
/// process).
pub fn reset_drain() {
    DRAIN.store(false, Ordering::SeqCst);
}

/// Route SIGTERM to [`request_drain`] so `kill <pid>` drains instead of
/// killing mid-request. Std-only: the handler is registered through the
/// C `signal` entry point; the handler body is a single atomic store,
/// which is async-signal-safe.
#[cfg(unix)]
pub fn install_sigterm_drain() {
    extern "C" fn on_term(_sig: i32) {
        DRAIN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGTERM: i32 = 15;
    let handler: extern "C" fn(i32) = on_term;
    unsafe {
        signal(SIGTERM, handler as usize);
    }
}

#[cfg(not(unix))]
pub fn install_sigterm_drain() {}

// ---- wire parsing ---------------------------------------------------------

/// Hard caps on untrusted input: header section and body size.
const MAX_HEADER_BYTES: u64 = 16 * 1024;
const MAX_BODY_BYTES: usize = 256 * 1024;

struct WireRequest {
    prompt: String,
    max_new_tokens: usize,
    temperature: f32,
    deadline_ms: Option<u64>,
}

enum Parsed {
    Generate(WireRequest),
    Stats,
}

/// Read and parse one HTTP/1.1 request. `Err(msg)` means "answer 400
/// with this reason and close".
fn read_request(reader: &mut BufReader<TcpStream>) -> std::result::Result<Parsed, String> {
    let mut head = (&mut *reader).take(MAX_HEADER_BYTES);
    let mut line = String::new();
    head.read_line(&mut line)
        .map_err(|e| format!("request line unreadable: {e}"))?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    let mut content_length: usize = 0;
    loop {
        let mut h = String::new();
        let n = head
            .read_line(&mut h)
            .map_err(|e| format!("header unreadable: {e}"))?;
        if n == 0 {
            return Err("truncated header section".into());
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| "bad content-length".to_string())?;
            }
        }
    }

    match (method.as_str(), path.as_str()) {
        ("GET", "/stats") => Ok(Parsed::Stats),
        ("POST", "/generate") => {
            if content_length == 0 {
                return Err("empty body".into());
            }
            if content_length > MAX_BODY_BYTES {
                return Err(format!("body over {MAX_BODY_BYTES} bytes"));
            }
            let mut body = vec![0u8; content_length];
            reader
                .read_exact(&mut body)
                .map_err(|e| format!("body unreadable: {e}"))?;
            let text = std::str::from_utf8(&body)
                .map_err(|_| "body is not utf-8".to_string())?;
            parse_generate(text).map(Parsed::Generate)
        }
        _ => Err(format!("unsupported request {method} {path}")),
    }
}

fn parse_generate(body: &str) -> std::result::Result<WireRequest, String> {
    let v = Json::parse(body).map_err(|e| format!("bad json: {e:?}"))?;
    let prompt = v
        .get("prompt")
        .as_str()
        .ok_or_else(|| "missing string field \"prompt\"".to_string())?
        .to_string();
    let max_new_tokens = match v.get("max_new") {
        Json::Null => 16,
        other => other
            .as_usize()
            .ok_or_else(|| "\"max_new\" must be a non-negative integer".to_string())?,
    };
    let temperature = match v.get("temperature") {
        Json::Null => 0.0,
        other => other
            .as_f64()
            .ok_or_else(|| "\"temperature\" must be a number".to_string())?
            as f32,
    };
    let deadline_ms = match v.get("deadline_ms") {
        Json::Null => None,
        other => Some(
            other
                .as_usize()
                .ok_or_else(|| "\"deadline_ms\" must be a non-negative integer".to_string())?
                as u64,
        ),
    };
    Ok(WireRequest { prompt, max_new_tokens, temperature, deadline_ms })
}

// ---- responses ------------------------------------------------------------

fn http_json(status: &str, extra_headers: &str, body: &str) -> String {
    format!(
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n{extra_headers}Connection: close\r\n\r\n{body}",
        body.len()
    )
}

fn respond_and_close(mut stream: TcpStream, text: &str) {
    let _ = stream.write_all(text.as_bytes());
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Both);
}

fn respond_429(stream: TcpStream, retry_after_ms: u64) {
    let secs = retry_after_ms.div_ceil(1000).max(1);
    let body = format!(
        "{{\"error\":\"overloaded\",\"retry_after_ms\":{retry_after_ms}}}"
    );
    let head = format!("Retry-After: {secs}\r\n");
    respond_and_close(stream, &http_json("429 Too Many Requests", &head, &body));
}

fn respond_400(stream: TcpStream, reason: &str) {
    let mut obj = Json::obj();
    obj.set("error", Json::from(format!("bad request: {reason}")));
    respond_and_close(stream, &http_json("400 Bad Request", "", &obj.to_string()));
}

fn respond_503(stream: TcpStream) {
    let body = "{\"error\":\"draining\"}";
    respond_and_close(stream, &http_json("503 Service Unavailable", "", body));
}

fn stream_head(id: u64) -> String {
    format!(
        "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n\
         X-Request-Id: {id}\r\nConnection: close\r\n\r\n"
    )
}

// ---- connection plumbing --------------------------------------------------

/// A parsed connection handed from a reader thread to the serve loop.
enum Incoming {
    Generate {
        wire: WireRequest,
        stream: TcpStream,
        /// Set by the connection's monitor thread on EOF/error — the
        /// client is gone.
        dead: Arc<AtomicBool>,
    },
    Stats(TcpStream),
}

/// State shared between the listener/reader threads and the serve loop.
struct Shared {
    ingress: Mutex<Vec<Incoming>>,
    ingress_cap: usize,
    draining: AtomicBool,
    stop: AtomicBool,
    rejected: AtomicU64,
    shed_at_door: AtomicU64,
    refused_draining: AtomicU64,
}

/// Commands for a connection's writer thread.
enum WriteCmd {
    /// Write this chunk.
    Line(String),
    /// Write this chunk, then shut the connection down.
    End(String),
    /// Shut the connection down now.
    Close,
}

/// One admitted, streaming connection as the serve loop tracks it.
struct Conn {
    tx: SyncSender<WriteCmd>,
    dead: Arc<AtomicBool>,
    last_write: Instant,
    tokens_sent: usize,
}

fn spawn_writer(
    stream: TcpStream,
    dead: Arc<AtomicBool>,
    cap: usize,
) -> SyncSender<WriteCmd> {
    let (tx, rx) = sync_channel::<WriteCmd>(cap.max(1));
    std::thread::spawn(move || {
        let mut stream = stream;
        for cmd in rx {
            let (text, end) = match &cmd {
                WriteCmd::Line(s) => (s.as_str(), false),
                WriteCmd::End(s) => (s.as_str(), true),
                WriteCmd::Close => ("", true),
            };
            if !text.is_empty() {
                let ok = stream
                    .write_all(text.as_bytes())
                    .and_then(|_| stream.flush())
                    .is_ok();
                if !ok {
                    dead.store(true, Ordering::SeqCst);
                    break;
                }
            }
            if end {
                let _ = stream.shutdown(Shutdown::Both);
                break;
            }
        }
    });
    tx
}

/// Per-connection reader: parse one request, hand it to the serve loop
/// (or answer the error classes directly), then keep watching the
/// socket for EOF so a vanished client cancels its request.
fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(2_000)));
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let parsed = match read_request(&mut reader) {
        Ok(p) => p,
        Err(reason) => {
            shared.rejected.fetch_add(1, Ordering::SeqCst);
            respond_400(stream, &reason);
            return;
        }
    };
    match parsed {
        Parsed::Stats => {
            // answered by the serve loop (it owns the engine)
            let mut q = shared.ingress.lock().unwrap();
            q.push(Incoming::Stats(stream));
        }
        Parsed::Generate(wire) => {
            if shared.draining.load(Ordering::SeqCst) {
                shared.refused_draining.fetch_add(1, Ordering::SeqCst);
                respond_503(stream);
                return;
            }
            {
                let mut q = shared.ingress.lock().unwrap();
                if q.len() >= shared.ingress_cap {
                    drop(q);
                    shared.shed_at_door.fetch_add(1, Ordering::SeqCst);
                    respond_429(stream, 250);
                    return;
                }
                let dead = Arc::new(AtomicBool::new(false));
                q.push(Incoming::Generate {
                    wire,
                    stream: match stream.try_clone() {
                        Ok(s) => s,
                        Err(_) => return,
                    },
                    dead: dead.clone(),
                });
                drop(q);
                // this thread becomes the disconnect monitor
                monitor_eof(stream, dead);
            }
        }
    }
}

/// Block on the socket until EOF or a real error, flagging `dead`.
/// Wakes every read-timeout interval; exits promptly once the writer
/// half shuts the connection down (that read returns EOF too).
fn monitor_eof(stream: TcpStream, dead: Arc<AtomicBool>) {
    let mut stream = stream;
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let mut buf = [0u8; 512];
    loop {
        if dead.load(Ordering::SeqCst) {
            return;
        }
        match stream.read(&mut buf) {
            Ok(0) => {
                dead.store(true, Ordering::SeqCst);
                return;
            }
            Ok(_) => {} // pipelined bytes: ignored, connection still up
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => {
                dead.store(true, Ordering::SeqCst);
                return;
            }
        }
    }
}

// ---- the serve loop -------------------------------------------------------

/// Bind the listening socket (`"127.0.0.1:0"` for an ephemeral test
/// port — read it back with `listener.local_addr()`).
pub fn bind(listen: &str) -> Result<TcpListener> {
    TcpListener::bind(listen).with_context(|| format!("binding {listen}"))
}

/// Run the serving front end over `engine` until a drain completes.
/// Blocks the calling thread (the engine is `&mut` — all scheduling
/// stays here); listener/reader/writer threads only move bytes.
pub fn serve<E: ServeEngine>(
    engine: &mut E,
    listener: TcpListener,
    opts: &NetOptions,
    faults: &FaultPlan,
) -> Result<NetReport> {
    let shared = Arc::new(Shared {
        ingress: Mutex::new(Vec::new()),
        ingress_cap: opts.queue_cap.max(1),
        draining: AtomicBool::new(false),
        stop: AtomicBool::new(false),
        rejected: AtomicU64::new(0),
        shed_at_door: AtomicU64::new(0),
        refused_draining: AtomicU64::new(0),
    });
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let accept_shared = shared.clone();
    let accepter = std::thread::spawn(move || {
        loop {
            if accept_shared.stop.load(Ordering::SeqCst) {
                return;
            }
            match listener.accept() {
                Ok((stream, _addr)) => {
                    // accepted sockets may inherit the listener's
                    // nonblocking mode on some platforms; undo it
                    let _ = stream.set_nonblocking(false);
                    let _ = stream.set_nodelay(true);
                    let conn_shared = accept_shared.clone();
                    std::thread::spawn(move || handle_conn(stream, conn_shared));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(2)),
            }
        }
    });

    let result = serve_loop(engine, &shared, opts, faults);
    shared.stop.store(true, Ordering::SeqCst);
    let _ = accepter.join();
    // whatever is still parked in ingress gets an honest refusal
    for inc in shared.ingress.lock().unwrap().drain(..) {
        match inc {
            Incoming::Generate { stream, .. } => respond_503(stream),
            Incoming::Stats(stream) => {
                respond_and_close(stream, &http_json("200 OK", "", "{}"))
            }
        }
    }
    result
}

fn serve_loop<E: ServeEngine>(
    engine: &mut E,
    shared: &Arc<Shared>,
    opts: &NetOptions,
    faults: &FaultPlan,
) -> Result<NetReport> {
    let mut report = NetReport::default();
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_id: u64 = 1;
    let mut draining = false;
    let mut drain_deadline: Option<Instant> = None;
    let mut drain_forced = false;
    let heartbeat = Duration::from_millis(opts.heartbeat_ms.max(1));

    loop {
        // -- drain trigger: SIGTERM/request_drain or the request budget
        let budget_done = opts
            .max_requests
            .is_some_and(|m| report.admitted + report.shed >= m);
        if !draining && (drain_requested() || budget_done) {
            draining = true;
            shared.draining.store(true, Ordering::SeqCst);
            drain_deadline = Some(
                Instant::now() + Duration::from_millis(opts.drain_timeout_ms),
            );
            log::info!(
                "drain: admissions closed, {} live request(s)",
                engine.live_ids().len()
            );
        }

        // -- ingress: admit, shed, or answer directly --------------------
        let incoming: Vec<Incoming> =
            shared.ingress.lock().unwrap().drain(..).collect();
        for inc in incoming {
            match inc {
                Incoming::Stats(stream) => {
                    let body = engine.stats_json();
                    respond_and_close(stream, &http_json("200 OK", "", &body));
                }
                Incoming::Generate { wire, stream, dead } => {
                    if draining {
                        report.refused_draining += 1;
                        respond_503(stream);
                        continue;
                    }
                    let id = next_id;
                    next_id += 1;
                    let verdict = engine.try_admit(NetRequest {
                        id,
                        prompt: wire.prompt,
                        max_new_tokens: wire.max_new_tokens,
                        temperature: wire.temperature,
                        deadline_ms: wire.deadline_ms,
                    });
                    match verdict {
                        NetAdmission::Shed { retry_after_ms } => {
                            report.shed += 1;
                            respond_429(stream, retry_after_ms);
                        }
                        NetAdmission::Admitted => {
                            report.admitted += 1;
                            let tx =
                                spawn_writer(stream, dead.clone(), opts.outbox_cap);
                            let _ = tx.try_send(WriteCmd::Line(stream_head(id)));
                            conns.insert(
                                id,
                                Conn {
                                    tx,
                                    dead,
                                    last_write: Instant::now(),
                                    tokens_sent: 0,
                                },
                            );
                        }
                    }
                }
            }
        }

        // -- vanished clients: cancel mid-flight, free the row + blocks --
        let gone: Vec<u64> = conns
            .iter()
            .filter(|(_, c)| c.dead.load(Ordering::SeqCst))
            .map(|(&id, _)| id)
            .collect();
        for id in gone {
            conns.remove(&id);
            if engine.cancel(id) {
                report.disconnects += 1;
            }
        }

        // -- injected faults, then one engine tick -----------------------
        if faults.panic_on_tick == Some(report.ticks) {
            parallel::inject_worker_panic_once();
        }
        let had_work = engine.has_work();
        if had_work || draining {
            let events = engine.tick()?;
            report.ticks += 1;
            for ev in events {
                dispatch_event(ev, engine, &mut conns, &mut report, faults);
            }
        }

        // -- heartbeats on idle streams ----------------------------------
        let now = Instant::now();
        let mut kill: Vec<u64> = Vec::new();
        for (&id, conn) in conns.iter_mut() {
            if now.duration_since(conn.last_write) >= heartbeat {
                match conn.tx.try_send(WriteCmd::Line("{\"hb\":1}\n".into())) {
                    Ok(()) => conn.last_write = now,
                    Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                        kill.push(id);
                    }
                }
            }
        }
        for id in kill {
            report.slow_readers += 1;
            conns.remove(&id);
            engine.cancel(id);
        }

        // -- exit: drained (clean or by force) ---------------------------
        if draining {
            let idle = !engine.has_work()
                && conns.is_empty()
                && shared.ingress.lock().unwrap().is_empty();
            if idle {
                report.drained_clean = !drain_forced;
                break;
            }
            if !drain_forced
                && drain_deadline.is_some_and(|d| Instant::now() > d)
            {
                // timeout: cancel whatever is left; the cancellations
                // surface as events on the next tick and close their
                // connections, after which the loop exits idle
                drain_forced = true;
                for id in engine.live_ids() {
                    engine.cancel(id);
                }
            }
        } else if !had_work {
            std::thread::sleep(Duration::from_micros(opts.idle_sleep_us.max(1)));
        }
    }

    report.rejected = shared.rejected.load(Ordering::SeqCst);
    report.shed += shared.shed_at_door.load(Ordering::SeqCst);
    report.refused_draining +=
        shared.refused_draining.load(Ordering::SeqCst);
    log::info!(
        "serve drained: admitted={} completed={} shed={} rejected={} \
         timed_out={} disconnects={} slow_readers={} ticks={} clean={}",
        report.admitted,
        report.completed,
        report.shed,
        report.rejected,
        report.timed_out,
        report.disconnects,
        report.slow_readers,
        report.ticks,
        report.drained_clean
    );
    Ok(report)
}

fn dispatch_event<E: ServeEngine>(
    ev: NetEvent,
    engine: &mut E,
    conns: &mut HashMap<u64, Conn>,
    report: &mut NetReport,
    faults: &FaultPlan,
) {
    match ev {
        NetEvent::Token { id, token } => {
            let Some(conn) = conns.get_mut(&id) else {
                return; // client already gone; engine cancel is in flight
            };
            let line = format!("{{\"token\":{token}}}\n");
            match conn.tx.try_send(WriteCmd::Line(line)) {
                Ok(()) => {
                    conn.last_write = Instant::now();
                    conn.tokens_sent += 1;
                    let sent = conn.tokens_sent;
                    // injected mid-stream disconnect (client vanishes
                    // after its n-th token, deterministically)
                    if faults.close_after_tokens.iter().any(|&(fid, n)| {
                        fid == id && n == sent
                    }) {
                        let _ = conn.tx.try_send(WriteCmd::Close);
                        conns.remove(&id);
                        if engine.cancel(id) {
                            report.disconnects += 1;
                        }
                    }
                }
                Err(_) => {
                    // outbox full (slow reader) or writer gone: drop it
                    report.slow_readers += 1;
                    conns.remove(&id);
                    engine.cancel(id);
                }
            }
        }
        NetEvent::Completed { id, text, tokens, latency_ms } => {
            report.completed += 1;
            if let Some(conn) = conns.remove(&id) {
                let mut obj = Json::obj();
                obj.set("done", Json::from(true));
                obj.set("text", Json::from(text));
                obj.set("tokens", Json::from(tokens));
                obj.set("latency_ms", Json::from(latency_ms));
                let line = format!("{obj}\n");
                let _ = conn.tx.try_send(WriteCmd::End(line));
            }
        }
        NetEvent::TimedOut { id } => {
            report.timed_out += 1;
            if let Some(conn) = conns.remove(&id) {
                let line = "{\"timeout\":true}\n".to_string();
                let _ = conn.tx.try_send(WriteCmd::End(line));
            }
        }
        NetEvent::Cancelled { id } => {
            // disconnect-initiated cancels have no conn left; a
            // drain-forced cancel still owes its client a terminal line
            if let Some(conn) = conns.remove(&id) {
                let line = "{\"cancelled\":true}\n".to_string();
                let _ = conn.tx.try_send(WriteCmd::End(line));
            }
        }
    }
}
