//! PJRT engine (`--features pjrt`): loads the AOT artifacts
//! (`artifacts/*.hlo.txt`) and executes them on the CPU PJRT client from
//! the Rust hot path.
//!
//! The [`Engine`] owns one `PjRtClient` and an executable cache keyed by
//! entry name; executables compile lazily on first use and are reused for
//! the life of the process. All entry points were lowered with
//! `return_tuple=True`, so every execution returns a single tuple literal
//! that is decomposed into per-output [`HostTensor`]s.
//!
//! The engine also implements [`Backend`], so op-level callers can treat
//! it interchangeably with the native backend.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::{EntrySpec, Manifest};
use crate::runtime::backend::Backend;
use crate::runtime::tensor::{DType, HostTensor};

/// Compiled-executable cache + PJRT client + manifest.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative compile time, for the perf logs.
    pub compile_ms: Mutex<f64>,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(BTreeMap::new()),
            compile_ms: Mutex::new(0.0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an entry point.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.entry(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        *self.compile_ms.lock().unwrap() += dt;
        log::info!("compiled {name} in {dt:.0} ms");
        let arc = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Validate inputs against the manifest spec (shape + dtype).
    fn check_inputs(&self, spec: &EntrySpec, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape {
                bail!(
                    "{} input {i}: shape {:?} != manifest {:?}",
                    spec.name,
                    t.shape,
                    s.shape
                );
            }
            let want = DType::parse(&s.dtype)?;
            if t.dtype != want {
                bail!(
                    "{} input {i}: dtype {:?} != manifest {:?}",
                    spec.name,
                    t.dtype,
                    want
                );
            }
        }
        Ok(())
    }

    /// Execute an entry point with host tensors; returns the decomposed
    /// tuple outputs as host tensors. This is the general path; the
    /// training loop uses [`Engine::execute_literals`] to avoid
    /// re-marshalling unchanged inputs.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.entry(name)?.clone();
        self.check_inputs(&spec, inputs)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<_>>()?;
        let outs = self.execute_literals(name, &lits)?;
        outs.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute with pre-marshalled literals, returning raw output literals
    /// (tuple already decomposed). The training hot loop keeps its state as
    /// literals across steps so params never bounce through `HostTensor`.
    pub fn execute_literals(
        &self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.execute_literal_refs(name, &exe, &refs)
    }

    /// Like [`Engine::execute_literals`] but borrowing inputs, so state
    /// literals can be threaded across steps without cloning.
    ///
    /// NOTE: this deliberately avoids `PjRtLoadedExecutable::execute`
    /// (literal inputs): the crate's C wrapper `release()`s every input
    /// device buffer it creates and never frees them, leaking the full
    /// input footprint per call (~130 MB/step for the paper train step —
    /// observed OOM after ~260 steps). Instead we create the device
    /// buffers ourselves and call `execute_b`; the Rust-owned
    /// `PjRtBuffer`s drop (and free) after the call.
    pub fn execute_literal_refs(
        &self,
        name: &str,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let in_buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|lit| self.client.buffer_from_host_literal(None, lit))
            .collect::<std::result::Result<_, _>>()
            .with_context(|| format!("uploading inputs for {name}"))?;
        self.execute_buffer_refs(name, exe, &in_buffers.iter().collect::<Vec<_>>())
    }

    /// Upload a host tensor to a device buffer once (for inputs reused
    /// across many executions — e.g. model parameters in the serving
    /// loop, which would otherwise be re-uploaded on every decode step).
    ///
    /// Uses `BufferFromHostBuffer` with `kImmutableOnlyDuringCall`
    /// semantics — the copy completes before this returns. (Do NOT swap
    /// in `buffer_from_host_literal` here: that PJRT path is async and
    /// requires the source literal to outlive the transfer, which a
    /// caller-temporary violates — observed as corrupted-size aborts.)
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let buf = match t.dtype {
            DType::F32 => self
                .client
                .buffer_from_host_buffer(&t.as_f32()?, &t.shape, None),
            DType::I32 => self
                .client
                .buffer_from_host_buffer(&t.as_i32()?, &t.shape, None),
            DType::U8 => self
                .client
                .buffer_from_host_buffer(&t.data, &t.shape, None),
            other => anyhow::bail!("upload: unsupported dtype {other:?}"),
        };
        buf.context("uploading buffer")
    }

    /// Upload a literal by round-tripping through [`HostTensor`] (used to
    /// re-pin execution outputs device-side; see [`Engine::upload`] for
    /// why the literal cannot be handed to PJRT directly).
    pub fn upload_literal(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.upload(&HostTensor::from_literal(lit)?)
    }

    /// Execute with caller-managed device buffers (the fully-amortized
    /// hot path: no per-call uploads at all for cached inputs).
    pub fn execute_buffer_refs(
        &self,
        name: &str,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .with_context(|| format!("executing {name}"))?;
        let buffer = result
            .into_iter()
            .next()
            .and_then(|mut v| if v.is_empty() { None } else { Some(v.remove(0)) })
            .with_context(|| format!("{name}: empty result"))?;
        let tuple = buffer
            .to_literal_sync()
            .with_context(|| format!("{name}: fetching result"))?;
        tuple
            .to_tuple()
            .with_context(|| format!("{name}: decomposing result tuple"))
    }

    /// Number of loaded (compiled) executables.
    pub fn loaded_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl Backend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        Engine::platform(self)
    }

    fn supports(&self, op: &str) -> bool {
        self.manifest.entries.contains_key(op)
    }

    fn ops(&self) -> Vec<String> {
        self.manifest.entries.keys().cloned().collect()
    }

    fn execute(&self, op: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        Engine::execute(self, op, inputs)
    }
}

// Engine tests require libxla_extension.so and built artifacts; they live
// in rust/tests/runtime_integration.rs so `cargo test --lib` stays fast.
