//! Host-side tensors: the common currency of every backend. Deliberately
//! minimal — a dtype tag, a shape, and a flat byte buffer — so the hot
//! loop can move data without reshaping or copy amplification.
//!
//! The native backend reads/writes these directly; under
//! `--features pjrt` the literal-marshalling methods at the bottom bridge
//! to PJRT.

use anyhow::{bail, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(feature = "pjrt")]
use xla::{ElementType, Literal};

use crate::util::fp16::F16;

/// Supported element types (the subset the AOT artifacts use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    F16,
    Bf16,
    I32,
    I8,
    U8,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 | DType::Bf16 => 2,
            DType::I8 | DType::U8 => 1,
        }
    }

    /// Parse the numpy-style dtype names the manifest uses.
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "float32" => DType::F32,
            "float16" => DType::F16,
            "bfloat16" => DType::Bf16,
            "int32" => DType::I32,
            "int8" => DType::I8,
            "uint8" => DType::U8,
            other => bail!("unsupported dtype {other:?}"),
        })
    }

    #[cfg(feature = "pjrt")]
    pub fn to_element_type(self) -> ElementType {
        match self {
            DType::F32 => ElementType::F32,
            DType::F16 => ElementType::F16,
            DType::Bf16 => ElementType::Bf16,
            DType::I32 => ElementType::S32,
            DType::I8 => ElementType::S8,
            DType::U8 => ElementType::U8,
        }
    }

    #[cfg(feature = "pjrt")]
    pub fn from_element_type(ty: ElementType) -> Result<DType> {
        Ok(match ty {
            ElementType::F32 => DType::F32,
            ElementType::F16 => DType::F16,
            ElementType::Bf16 => DType::Bf16,
            ElementType::S32 => DType::I32,
            ElementType::S8 => DType::I8,
            ElementType::U8 => DType::U8,
            other => bail!("unsupported element type {other:?}"),
        })
    }
}

/// A host tensor: flat little-endian bytes + shape + dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl HostTensor {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    // ----- constructors ---------------------------------------------------

    pub fn from_f32(values: &[f32], shape: &[usize]) -> HostTensor {
        assert_eq!(values.len(), shape.iter().product::<usize>());
        HostTensor {
            dtype: DType::F32,
            shape: shape.to_vec(),
            data: bulk_bytes(values),
        }
    }

    pub fn from_i32(values: &[i32], shape: &[usize]) -> HostTensor {
        assert_eq!(values.len(), shape.iter().product::<usize>());
        HostTensor {
            dtype: DType::I32,
            shape: shape.to_vec(),
            data: bulk_bytes(values),
        }
    }

    pub fn from_i8(values: &[i8], shape: &[usize]) -> HostTensor {
        assert_eq!(values.len(), shape.iter().product::<usize>());
        HostTensor {
            dtype: DType::I8,
            shape: shape.to_vec(),
            data: values.iter().map(|&v| v as u8).collect(),
        }
    }

    pub fn from_f16_bits(bits: &[u16], shape: &[usize]) -> HostTensor {
        assert_eq!(bits.len(), shape.iter().product::<usize>());
        let mut data = Vec::with_capacity(bits.len() * 2);
        for b in bits {
            data.extend_from_slice(&b.to_le_bytes());
        }
        HostTensor { dtype: DType::F16, shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::from_f32(&[v], &[])
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::from_i32(&[v], &[])
    }

    pub fn zeros(dtype: DType, shape: &[usize]) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor { dtype, shape: shape.to_vec(), data: vec![0; n * dtype.size()] }
    }

    // ----- views ------------------------------------------------------------

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not F32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor is {:?}, not I32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_f16_bits(&self) -> Result<Vec<u16>> {
        if self.dtype != DType::F16 {
            bail!("tensor is {:?}, not F16", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]))
            .collect())
    }

    /// f16 tensor widened to f32 values.
    pub fn f16_to_f32(&self) -> Result<Vec<f32>> {
        Ok(self
            .as_f16_bits()?
            .into_iter()
            .map(|b| F16::from_bits(b).to_f32())
            .collect())
    }

    pub fn scalar_as_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }

    // ----- PJRT marshalling (pjrt feature only) -----------------------------

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<Literal> {
        Literal::create_from_shape_and_untyped_data(
            self.dtype.to_element_type(),
            &self.shape,
            &self.data,
        )
        .context("creating literal")
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let ty = lit.ty().context("literal type")?;
        let dtype = DType::from_element_type(ty)?;
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();

        // The crate's typed copies can't express 2-byte floats (its F16 /
        // Bf16 marker types are zero-sized — copying "through" them would
        // scribble past a dangling Vec pointer). Widening f16→f32 is exact,
        // so narrow dtypes are read via a convert() and re-rounded: the
        // original bits are recovered exactly.
        let data: Vec<u8> = match ty {
            // 4-byte scalars: bulk-reinterpret the typed vec (this host is
            // little-endian; HostTensor bytes are defined little-endian).
            // ~5x faster than per-element to_le_bytes on big tensors.
            xla::ElementType::F32 => bulk_bytes(&lit.to_vec::<f32>()?),
            xla::ElementType::S32 => bulk_bytes(&lit.to_vec::<i32>()?),
            xla::ElementType::U8 => lit.to_vec::<u8>()?,
            xla::ElementType::S8 => {
                lit.to_vec::<i8>()?.into_iter().map(|v| v as u8).collect()
            }
            xla::ElementType::F16 => {
                let wide = lit.convert(xla::PrimitiveType::F32)?;
                let vals = wide.to_vec::<f32>()?;
                let mut out = Vec::with_capacity(vals.len() * 2);
                for v in vals {
                    out.extend_from_slice(&F16::from_f32(v).to_bits().to_le_bytes());
                }
                out
            }
            xla::ElementType::Bf16 => {
                let wide = lit.convert(xla::PrimitiveType::F32)?;
                let vals = wide.to_vec::<f32>()?;
                let mut out = Vec::with_capacity(vals.len() * 2);
                for v in vals {
                    out.extend_from_slice(
                        &crate::util::fp16::Bf16::from_f32(v).to_bits().to_le_bytes(),
                    );
                }
                out
            }
            other => bail!("unsupported element type {other:?}"),
        };
        Ok(HostTensor { dtype, shape: dims, data })
    }
}

/// Reinterpret a plain-old-data vec as little-endian bytes (no-op copy on
/// little-endian hosts, which this crate targets; a compile-time check
/// guards the assumption).
fn bulk_bytes<T: Copy>(vals: &[T]) -> Vec<u8> {
    #[cfg(target_endian = "big")]
    compile_error!("HostTensor bytes are little-endian; add byte swaps");
    let len = std::mem::size_of_val(vals);
    let mut out = vec![0u8; len];
    // SAFETY: T is a POD scalar (f32/i32), u8 has alignment 1, and the
    // byte length matches exactly.
    unsafe {
        std::ptr::copy_nonoverlapping(
            vals.as_ptr() as *const u8,
            out.as_mut_ptr(),
            len,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_construction() {
        let t = HostTensor::from_f32(&[1.0, -2.5, 3.25, 0.0], &[2, 2]);
        assert_eq!(t.elems(), 4);
        assert_eq!(t.as_f32().unwrap(), vec![1.0, -2.5, 3.25, 0.0]);
    }

    #[test]
    fn i32_roundtrip_construction() {
        let t = HostTensor::from_i32(&[-1, 2, i32::MAX], &[3]);
        assert_eq!(t.as_i32().unwrap(), vec![-1, 2, i32::MAX]);
    }

    #[test]
    fn scalar_helpers() {
        assert_eq!(HostTensor::scalar_f32(4.5).scalar_as_f32().unwrap(), 4.5);
        let s = HostTensor::scalar_i32(-3);
        assert_eq!(s.shape, Vec::<usize>::new());
        assert_eq!(s.as_i32().unwrap(), vec![-3]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int8").unwrap(), DType::I8);
        assert!(DType::parse("complex64").is_err());
    }

    #[test]
    fn wrong_view_errors() {
        let t = HostTensor::from_f32(&[1.0], &[1]);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn zeros_sized_correctly() {
        let t = HostTensor::zeros(DType::F16, &[3, 5]);
        assert_eq!(t.data.len(), 30);
        assert_eq!(t.as_f16_bits().unwrap(), vec![0u16; 15]);
    }

    #[test]
    fn f16_bits_roundtrip() {
        let bits = vec![0x3C00u16, 0xC000, 0x0000];
        let t = HostTensor::from_f16_bits(&bits, &[3]);
        assert_eq!(t.as_f16_bits().unwrap(), bits);
        assert_eq!(t.f16_to_f32().unwrap(), vec![1.0, -2.0, 0.0]);
    }

    // Literal marshalling tests live in rust/tests/runtime_integration.rs
    // (they need the PJRT shared library loaded).
}
