//! Paged, mixed-precision KV-cache block pool (DESIGN.md §KV-memory
//! seam).
//!
//! A [`KvPool`] owns a fixed arena of `BLOCK_TOKENS`-sized pages shared
//! by every row of a paged [`DecodeSession`]. Each row maps its cached
//! positions through a *block table* (`Vec<u32>` of block ids), so the
//! real serving capacity limit is the pool's **byte budget**
//! (`--kv-mem-mb`), not a fixed slot constant: short requests hold few
//! blocks, long requests hold many, and admission is by free blocks.
//!
//! Three properties make it the memory seam of the serving path:
//!
//! * **pluggable precision** — K/V are stored as f32, IEEE binary16,
//!   bfloat16 (`util/fp16` codecs) or symmetric int8 (one power-of-two
//!   `quant::kv_vec_scale` per stored `head_dim` vector, kept beside
//!   the codes and counted in the block's budget bytes) and dequantized
//!   per block inside the fused attention inner loops. ConSmax's merged
//!   `C·exp(S)` form has no row-max search, so reduced-precision scores
//!   feed the exp stream directly — the software analogue of Hyft/SOLE's
//!   low-precision softmax datapaths (PAPERS.md). The f32 path is
//!   bit-preserving, so a paged-f32 session is *exactly* the dense
//!   oracle.
//! * **refcounted copy-on-write sharing** — full blocks are registered
//!   under a chain hash of the token prefix they encode; a new prompt
//!   whose leading full blocks hash-match an existing prefix retains
//!   those blocks instead of recomputing them (identical prefixes are
//!   prefilled once and shared across rows). Writers privatize shared
//!   blocks before mutating ([`KvPool::make_private`]).
//! * **budget admission** — the pool hands out blocks until the budget
//!   is exhausted; the scheduler admits by [`KvPool::free_blocks`] and
//!   preempts-and-requeues whole requests under pressure (server.rs).
//!
//! Block layout: each block stores `[n_layer, n_head, block_tokens,
//! head_dim]` for K and the same for V, so one (layer, head) tile of a
//! block is a contiguous `[block_tokens, head_dim]` run — the unit the
//! attention kernels gather/dequantize per step.
//!
//! Content hashes are 64-bit FNV-1a chains over token ids from position
//! 0 (K/V at position *i* depend on **all** tokens ≤ *i* through
//! attention, so the chain hash is exactly the content key). Collisions
//! are possible in principle and accepted at this scale, like vLLM's
//! hash-based prefix cache.
//!
//! [`DecodeSession`]: super::DecodeSession

use std::collections::HashMap;

use anyhow::{ensure, Result};

use crate::config::{KvCacheConfig, KvDtype, ModelConfig};
use crate::quant;
use crate::util::fp16::{Bf16, F16};

/// Seed for the first link of a [`chain_hash`] chain (FNV-1a offset).
pub const HASH_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Extend a token-prefix chain hash over `tokens` (FNV-1a over the
/// little-endian bytes of each id). `chain_hash(chain_hash(S, a), b) ==
/// chain_hash(S, a ++ b)`, so per-block hashes compose.
pub fn chain_hash(prev: u64, tokens: &[i32]) -> u64 {
    let mut h = prev;
    for &t in tokens {
        for b in (t as u32).to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Bytes one block occupies across the K and V arenas. For `Int8`
/// pools the per-vector f32 scales ride along with the codes, so they
/// are counted here too — budget admission and the density gauges see
/// the true footprint, not just the code bytes.
fn block_bytes_of(stride: usize, head_dim: usize, dtype: KvDtype) -> usize {
    let payload = 2 * stride * dtype.bytes_per_elem();
    match dtype {
        KvDtype::Int8 => {
            payload + 2 * (stride / head_dim) * std::mem::size_of::<f32>()
        }
        _ => payload,
    }
}

/// Typed storage behind one of the pool's two arenas (K or V).
enum Arena {
    F32(Vec<f32>),
    /// binary16 or bfloat16 bit patterns, per the pool's dtype.
    U16(Vec<u16>),
    /// symmetric int8 codes; the per-vector scales live beside the
    /// arena in `KvPool::{k_scales, v_scales}` and are applied by the
    /// pool's quantizing read/write paths, not here.
    I8(Vec<i8>),
}

impl Arena {
    fn read(&self, dtype: KvDtype, start: usize, dst: &mut [f32]) {
        match self {
            Arena::F32(data) => {
                dst.copy_from_slice(&data[start..start + dst.len()]);
            }
            Arena::U16(data) => match dtype {
                KvDtype::F16 => {
                    for (o, &bits) in
                        dst.iter_mut().zip(&data[start..start + dst.len()])
                    {
                        *o = F16::from_bits(bits).to_f32();
                    }
                }
                _ => {
                    for (o, &bits) in
                        dst.iter_mut().zip(&data[start..start + dst.len()])
                    {
                        *o = Bf16(bits).to_f32();
                    }
                }
            },
            Arena::I8(_) => {
                unreachable!("int8 reads go through KvPool::read_i8")
            }
        }
    }

    fn write(&mut self, dtype: KvDtype, start: usize, src: &[f32]) {
        match self {
            Arena::F32(data) => {
                data[start..start + src.len()].copy_from_slice(src);
            }
            Arena::U16(data) => match dtype {
                KvDtype::F16 => {
                    for (o, &x) in
                        data[start..start + src.len()].iter_mut().zip(src)
                    {
                        *o = F16::from_f32(x).to_bits();
                    }
                }
                _ => {
                    for (o, &x) in
                        data[start..start + src.len()].iter_mut().zip(src)
                    {
                        *o = Bf16::from_f32(x).to_bits();
                    }
                }
            },
            Arena::I8(_) => {
                unreachable!("int8 writes go through KvPool::write_i8")
            }
        }
    }

    /// Copy one block's contents onto another (CoW clone). Blocks never
    /// overlap, so `copy_within` is a straight memmove with no temp.
    fn copy_block(&mut self, src: usize, dst: usize, stride: usize) {
        match self {
            Arena::F32(data) => data.copy_within(src..src + stride, dst),
            Arena::U16(data) => data.copy_within(src..src + stride, dst),
            Arena::I8(data) => data.copy_within(src..src + stride, dst),
        }
    }

    /// The raw int8 codes (Int8 pools only).
    fn i8(&self) -> &[i8] {
        match self {
            Arena::I8(data) => data,
            _ => unreachable!("i8() on a float arena"),
        }
    }

    fn i8_mut(&mut self) -> &mut [i8] {
        match self {
            Arena::I8(data) => data,
            _ => unreachable!("i8_mut() on a float arena"),
        }
    }
}

/// Occupancy snapshot for gauges (`Server::stats`, benches).
#[derive(Debug, Clone, Copy)]
pub struct KvStats {
    pub total_blocks: usize,
    pub free_blocks: usize,
    pub used_blocks: usize,
    /// Blocks referenced by more than one row (prefix sharing at work).
    pub shared_blocks: usize,
    pub block_tokens: usize,
    /// Bytes one block occupies across the K and V arenas.
    pub block_bytes: usize,
    pub dtype: KvDtype,
}

/// The shared block pool: typed K/V arenas + refcounts + free list +
/// the content-hash registry behind prefix sharing.
pub struct KvPool {
    dtype: KvDtype,
    block_tokens: usize,
    ctx: usize,
    n_layer: usize,
    n_head: usize,
    head_dim: usize,
    /// Elements per block in each arena:
    /// `n_layer * n_head * block_tokens * head_dim`.
    stride: usize,
    k: Arena,
    v: Arena,
    /// `Int8` pools only: one power-of-two scale per stored `head_dim`
    /// vector of the matching arena, indexed `arena_offset / head_dim`
    /// (i.e. `(block, layer, head, slot)` flattened). Empty for float
    /// dtypes. CoW clones copy the block's scale range alongside its
    /// codes ([`KvPool::make_private`]).
    k_scales: Vec<f32>,
    v_scales: Vec<f32>,
    refcnt: Vec<u32>,
    /// Free block ids (stack; popping yields ascending ids from fresh).
    free: Vec<u32>,
    /// Content hash a block is registered under (None = unregistered).
    hash_of: Vec<Option<u64>>,
    by_hash: HashMap<u64, u32>,
}

/// The pool geometry `cfg` + `kv` imply, computed without allocating
/// anything: block token span (clamped to ctx), arena elements per
/// block, blocks per full-context row, and bytes per block.
#[derive(Debug, Clone, Copy)]
pub struct KvGeometry {
    pub block_tokens: usize,
    pub stride: usize,
    pub blocks_per_row: usize,
    pub block_bytes: usize,
}

impl KvGeometry {
    pub fn of(cfg: &ModelConfig, kv: &KvCacheConfig) -> KvGeometry {
        let bt = kv.block_tokens.min(cfg.ctx).max(1);
        let stride = cfg.n_layer * cfg.n_head * bt * cfg.head_dim();
        KvGeometry {
            block_tokens: bt,
            stride,
            blocks_per_row: cfg.ctx.div_ceil(bt),
            block_bytes: block_bytes_of(stride, cfg.head_dim(), kv.dtype),
        }
    }
}

/// Validate `kv` against `cfg`'s geometry without allocating arenas —
/// the exact arithmetic [`KvPool::new`] applies. A byte budget smaller
/// than one full `ctx`-token row can never admit *any* request (the
/// preempt pass would find no victim and every step would zero-progress
/// bail), so it is rejected here, at configuration time, with the same
/// message pool construction would produce.
pub fn validate_budget(cfg: &ModelConfig, kv: &KvCacheConfig) -> Result<()> {
    kv.validate()?;
    let geo = KvGeometry::of(cfg, kv);
    if let Some(bytes) = kv.mem_bytes {
        let blocks = bytes / geo.block_bytes;
        ensure!(
            blocks >= geo.blocks_per_row,
            "kv budget too small: {blocks} block(s) of {} bytes \
             cannot hold one full {}-token row ({} blocks; raise \
             --kv-mem-mb or shrink --kv-block)",
            geo.block_bytes,
            cfg.ctx,
            geo.blocks_per_row
        );
    }
    Ok(())
}

impl KvPool {
    /// Build a pool for `cfg`'s geometry. With a byte budget the block
    /// count is `budget / block_bytes` (must fit at least one full
    /// `ctx`-token row, enforced by [`validate_budget`]); without one,
    /// the pool holds `rows` full rows — paging (and sharing) without a
    /// memory cap.
    pub fn new(cfg: &ModelConfig, kv: &KvCacheConfig, rows: usize) -> Result<KvPool> {
        validate_budget(cfg, kv)?;
        let geo = KvGeometry::of(cfg, kv);
        let (bt, stride, per_row) = (geo.block_tokens, geo.stride, geo.blocks_per_row);
        let blocks = match kv.mem_bytes {
            Some(bytes) => bytes / geo.block_bytes,
            None => rows.max(1) * per_row,
        };
        let elems = blocks * stride;
        let (k, v) = match kv.dtype {
            KvDtype::F32 => {
                (Arena::F32(vec![0.0; elems]), Arena::F32(vec![0.0; elems]))
            }
            KvDtype::F16 | KvDtype::Bf16 => {
                (Arena::U16(vec![0; elems]), Arena::U16(vec![0; elems]))
            }
            KvDtype::Int8 => {
                (Arena::I8(vec![0; elems]), Arena::I8(vec![0; elems]))
            }
        };
        let scale_slots = match kv.dtype {
            KvDtype::Int8 => elems / cfg.head_dim(),
            _ => 0,
        };
        Ok(KvPool {
            dtype: kv.dtype,
            block_tokens: bt,
            ctx: cfg.ctx,
            n_layer: cfg.n_layer,
            n_head: cfg.n_head,
            head_dim: cfg.head_dim(),
            stride,
            k,
            v,
            k_scales: vec![1.0; scale_slots],
            v_scales: vec![1.0; scale_slots],
            refcnt: vec![0; blocks],
            free: (0..blocks as u32).rev().collect(),
            hash_of: vec![None; blocks],
            by_hash: HashMap::new(),
        })
    }

    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Blocks needed to hold `tokens` cached positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Blocks one full `ctx`-token row occupies.
    pub fn blocks_per_row(&self) -> usize {
        self.blocks_for(self.ctx)
    }

    pub fn total_blocks(&self) -> usize {
        self.refcnt.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks() - self.free_blocks()
    }

    pub fn shared_blocks(&self) -> usize {
        self.refcnt.iter().filter(|&&c| c > 1).count()
    }

    pub fn is_shared(&self, blk: u32) -> bool {
        self.refcnt[blk as usize] > 1
    }

    pub fn stats(&self) -> KvStats {
        KvStats {
            total_blocks: self.total_blocks(),
            free_blocks: self.free_blocks(),
            used_blocks: self.used_blocks(),
            shared_blocks: self.shared_blocks(),
            block_tokens: self.block_tokens,
            block_bytes: block_bytes_of(self.stride, self.head_dim, self.dtype),
            dtype: self.dtype,
        }
    }

    /// Take a free block (refcount 1, unregistered). `None` = budget
    /// exhausted: the caller preempts or rejects.
    pub fn alloc(&mut self) -> Option<u32> {
        let blk = self.free.pop()?;
        debug_assert_eq!(self.refcnt[blk as usize], 0);
        debug_assert!(self.hash_of[blk as usize].is_none());
        self.refcnt[blk as usize] = 1;
        Some(blk)
    }

    /// Add a reference (a row sharing the block via its table).
    pub fn retain(&mut self, blk: u32) {
        debug_assert!(self.refcnt[blk as usize] > 0, "retain of a free block");
        self.refcnt[blk as usize] += 1;
    }

    /// Drop a reference; the last drop unregisters the block and
    /// returns it to the free list.
    pub fn release(&mut self, blk: u32) {
        let i = blk as usize;
        debug_assert!(self.refcnt[i] > 0, "release of a free block");
        self.refcnt[i] -= 1;
        if self.refcnt[i] == 0 {
            if let Some(h) = self.hash_of[i].take() {
                // only remove the registry entry if it still points here
                if self.by_hash.get(&h) == Some(&blk) {
                    self.by_hash.remove(&h);
                }
            }
            self.free.push(blk);
        }
    }

    /// Look up a full block by prefix content hash.
    pub fn lookup(&self, hash: u64) -> Option<u32> {
        self.by_hash.get(&hash).copied()
    }

    /// Register a live block under a content hash so later prompts can
    /// share it. First writer wins; re-registration is a no-op.
    pub fn register(&mut self, blk: u32, hash: u64) {
        let i = blk as usize;
        debug_assert!(self.refcnt[i] > 0, "register of a free block");
        if self.hash_of[i].is_some() || self.by_hash.contains_key(&hash) {
            return;
        }
        self.hash_of[i] = Some(hash);
        self.by_hash.insert(hash, blk);
    }

    /// Drop a block's registry entry (its content is about to change —
    /// window re-encode overwrites rows in place).
    pub fn unregister(&mut self, blk: u32) {
        let i = blk as usize;
        if let Some(h) = self.hash_of[i].take() {
            if self.by_hash.get(&h) == Some(&blk) {
                self.by_hash.remove(&h);
            }
        }
    }

    /// Copy-on-write: a privately owned handle to `blk`'s contents.
    /// Unshared blocks are returned as-is; shared ones are cloned into a
    /// fresh block (refcount 1, unregistered) and the caller's reference
    /// to the original is dropped. `None` = no free block for the clone.
    pub fn make_private(&mut self, blk: u32) -> Option<u32> {
        if self.refcnt[blk as usize] <= 1 {
            return Some(blk);
        }
        let fresh = self.alloc()?;
        let (src, dst) = (blk as usize * self.stride, fresh as usize * self.stride);
        self.k.copy_block(src, dst, self.stride);
        self.v.copy_block(src, dst, self.stride);
        if self.dtype == KvDtype::Int8 {
            // the codes are meaningless without their per-vector scales
            let spb = self.stride / self.head_dim;
            let (ss, ds) = (blk as usize * spb, fresh as usize * spb);
            self.k_scales.copy_within(ss..ss + spb, ds);
            self.v_scales.copy_within(ss..ss + spb, ds);
        }
        // drop the caller's reference to the shared original (refcnt > 1,
        // so this never frees it)
        self.refcnt[blk as usize] -= 1;
        Some(fresh)
    }

    /// [`KvPool::make_private`] for a block the caller is about to
    /// **fully overwrite** (window re-encode): same ownership move, no
    /// content copy.
    pub fn rehome(&mut self, blk: u32) -> Option<u32> {
        if self.refcnt[blk as usize] <= 1 {
            return Some(blk);
        }
        let fresh = self.alloc()?;
        self.refcnt[blk as usize] -= 1;
        Some(fresh)
    }

    /// Live references to a block (0 = free).
    pub fn refcount(&self, blk: u32) -> u32 {
        self.refcnt[blk as usize]
    }

    /// Element offset of `(l, h, t)`'s head-dim run inside a block.
    #[inline]
    fn off(&self, l: usize, h: usize, t: usize) -> usize {
        ((l * self.n_head + h) * self.block_tokens + t) * self.head_dim
    }

    /// Dequantize `n` consecutive key slots of `(blk, l, h)` starting at
    /// in-block slot `t0` into `dst` (`n * head_dim` f32). For f32 pools
    /// this is a bit-preserving copy; `Int8` pools dequantize each slot
    /// vector with its own stored scale.
    pub fn read_k(&self, blk: u32, l: usize, h: usize, t0: usize, n: usize, dst: &mut [f32]) {
        debug_assert_eq!(dst.len(), n * self.head_dim);
        let start = blk as usize * self.stride + self.off(l, h, t0);
        if self.dtype == KvDtype::Int8 {
            read_i8(self.k.i8(), &self.k_scales, self.head_dim, start, dst);
        } else {
            self.k.read(self.dtype, start, dst);
        }
    }

    /// [`KvPool::read_k`] for the value arena.
    pub fn read_v(&self, blk: u32, l: usize, h: usize, t0: usize, n: usize, dst: &mut [f32]) {
        debug_assert_eq!(dst.len(), n * self.head_dim);
        let start = blk as usize * self.stride + self.off(l, h, t0);
        if self.dtype == KvDtype::Int8 {
            read_i8(self.v.i8(), &self.v_scales, self.head_dim, start, dst);
        } else {
            self.v.read(self.dtype, start, dst);
        }
    }

    /// Encode one token's K/V across every (layer, head) into in-block
    /// slot `t`. `k_all`/`v_all` are `[n_layer * n_head, head_dim]`.
    /// For `Int8` pools each `head_dim` vector is quantized against a
    /// fresh `quant::kv_vec_scale` — the same transform the paged
    /// decode path stages through `KvDtype::roundtrip_vec`, so
    /// committing staged (already-roundtripped) values is bit-stable.
    pub fn write_token(&mut self, blk: u32, t: usize, k_all: &[f32], v_all: &[f32]) {
        debug_assert!(t < self.block_tokens);
        debug_assert_eq!(k_all.len(), self.n_layer * self.n_head * self.head_dim);
        debug_assert_eq!(k_all.len(), v_all.len());
        let hd = self.head_dim;
        let base = blk as usize * self.stride;
        let int8 = self.dtype == KvDtype::Int8;
        for l in 0..self.n_layer {
            for h in 0..self.n_head {
                let src = (l * self.n_head + h) * hd;
                let dst = base + self.off(l, h, t);
                if int8 {
                    write_i8(
                        self.k.i8_mut(),
                        &mut self.k_scales,
                        hd,
                        dst,
                        &k_all[src..src + hd],
                    );
                    write_i8(
                        self.v.i8_mut(),
                        &mut self.v_scales,
                        hd,
                        dst,
                        &v_all[src..src + hd],
                    );
                } else {
                    self.k.write(self.dtype, dst, &k_all[src..src + hd]);
                    self.v.write(self.dtype, dst, &v_all[src..src + hd]);
                }
            }
        }
    }

    /// Encode a whole captured window into a row's block table.
    /// `k`/`v` are `[n_layer, n_head, w, head_dim]` (a prefill capture
    /// buffer); slots `0..w` of the table's blocks are overwritten.
    pub fn write_capture(&mut self, table: &[u32], w: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.n_layer * self.n_head * w * self.head_dim);
        debug_assert_eq!(k.len(), v.len());
        debug_assert!(table.len() * self.block_tokens >= w);
        let hd = self.head_dim;
        for (bi, &blk) in table.iter().enumerate() {
            let t0 = bi * self.block_tokens;
            if t0 >= w {
                break;
            }
            let n = (w - t0).min(self.block_tokens);
            let base = blk as usize * self.stride;
            let int8 = self.dtype == KvDtype::Int8;
            for l in 0..self.n_layer {
                for h in 0..self.n_head {
                    let src = ((l * self.n_head + h) * w + t0) * hd;
                    let dst = base + self.off(l, h, 0);
                    if int8 {
                        write_i8(
                            self.k.i8_mut(),
                            &mut self.k_scales,
                            hd,
                            dst,
                            &k[src..src + n * hd],
                        );
                        write_i8(
                            self.v.i8_mut(),
                            &mut self.v_scales,
                            hd,
                            dst,
                            &v[src..src + n * hd],
                        );
                    } else {
                        self.k.write(self.dtype, dst, &k[src..src + n * hd]);
                        self.v.write(self.dtype, dst, &v[src..src + n * hd]);
                    }
                }
            }
        }
    }
}

/// Dequantize int8 codes starting at arena offset `start` into `dst`
/// (`dst.len()` a multiple of `hd`), one stored scale per `head_dim`
/// vector. `start` is always `head_dim`-aligned (every block offset is
/// a whole number of vectors), so `start / hd + slot` indexes the
/// scale of each consecutive slot.
fn read_i8(codes: &[i8], scales: &[f32], hd: usize, start: usize, dst: &mut [f32]) {
    debug_assert_eq!(start % hd, 0);
    for (s, chunk) in dst.chunks_exact_mut(hd).enumerate() {
        let base = start + s * hd;
        let scale = scales[base / hd];
        for (o, &q) in chunk.iter_mut().zip(&codes[base..base + hd]) {
            *o = quant::dequantize_i8(q, scale);
        }
    }
}

/// Quantize `src` (a multiple of `hd` long) into the int8 arena at
/// offset `start`, fitting one fresh power-of-two scale per `head_dim`
/// vector and recording it in `scales` — the inverse of [`read_i8`].
fn write_i8(codes: &mut [i8], scales: &mut [f32], hd: usize, start: usize, src: &[f32]) {
    debug_assert_eq!(start % hd, 0);
    for (s, vec) in src.chunks_exact(hd).enumerate() {
        let base = start + s * hd;
        let scale = quant::kv_vec_scale(vec);
        scales[base / hd] = scale;
        for (o, &x) in codes[base..base + hd].iter_mut().zip(vec) {
            *o = quant::quantize_i8(x, scale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::{run_property, Gen};

    fn pool(dtype: KvDtype, block_tokens: usize, blocks: usize) -> KvPool {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let stride =
            cfg.n_layer * cfg.n_head * block_tokens * cfg.head_dim();
        let kv = KvCacheConfig {
            dtype,
            block_tokens,
            // budget expressed exactly in blocks (incl. int8 scale bytes)
            mem_bytes: Some(
                blocks * block_bytes_of(stride, cfg.head_dim(), dtype),
            ),
        };
        KvPool::new(&cfg, &kv, 1).unwrap()
    }

    #[test]
    fn chain_hash_composes() {
        let a = [1, 2, 3];
        let b = [4, 5];
        let whole = chain_hash(HASH_SEED, &[1, 2, 3, 4, 5]);
        let split = chain_hash(chain_hash(HASH_SEED, &a), &b);
        assert_eq!(whole, split);
        assert_ne!(whole, chain_hash(HASH_SEED, &[1, 2, 3, 4, 6]));
        // order matters
        assert_ne!(
            chain_hash(HASH_SEED, &[1, 2]),
            chain_hash(HASH_SEED, &[2, 1])
        );
    }

    #[test]
    fn pool_geometry_and_budget() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let p = pool(KvDtype::F32, 16, 8);
        assert_eq!(p.total_blocks(), 8);
        assert_eq!(p.free_blocks(), 8);
        assert_eq!(p.blocks_per_row(), 4); // ctx 64 / 16
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
        // fp16 blocks are half the bytes of f32 blocks
        let s32 = pool(KvDtype::F32, 16, 4).stats();
        let s16 = pool(KvDtype::F16, 16, 4).stats();
        assert_eq!(s32.block_bytes, 2 * s16.block_bytes);
        // a budget below one full row is rejected
        let kv = KvCacheConfig {
            dtype: KvDtype::F32,
            block_tokens: 16,
            mem_bytes: Some(1024),
        };
        assert!(KvPool::new(&cfg, &kv, 1).is_err());
        // block_tokens larger than ctx clamps to one block per row
        let p = pool(KvDtype::F32, 64, 2);
        assert_eq!(p.blocks_per_row(), 1);
    }

    #[test]
    fn alloc_release_refcounts() {
        let mut p = pool(KvDtype::F32, 16, 4);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.free_blocks(), 2);
        p.retain(a);
        assert!(p.is_shared(a));
        assert_eq!(p.shared_blocks(), 1);
        p.release(a);
        assert!(!p.is_shared(a));
        assert_eq!(p.free_blocks(), 2); // still one ref left
        p.release(a);
        p.release(b);
        assert_eq!(p.free_blocks(), 4);
        // pool drains fully, then refuses further allocs
        let all: Vec<u32> = (0..4).map(|_| p.alloc().unwrap()).collect();
        assert!(p.alloc().is_none());
        for blk in all {
            p.release(blk);
        }
        assert_eq!(p.free_blocks(), 4);
    }

    #[test]
    fn register_lookup_and_release_unregisters() {
        let mut p = pool(KvDtype::F32, 16, 4);
        let a = p.alloc().unwrap();
        let h = chain_hash(HASH_SEED, &[7, 8, 9]);
        assert!(p.lookup(h).is_none());
        p.register(a, h);
        assert_eq!(p.lookup(h), Some(a));
        // first writer wins
        let b = p.alloc().unwrap();
        p.register(b, h);
        assert_eq!(p.lookup(h), Some(a));
        p.release(a);
        assert!(p.lookup(h).is_none(), "free block left in the registry");
        p.release(b);
    }

    #[test]
    fn write_read_roundtrip_per_dtype() {
        // the storage transform of every dtype is `roundtrip_vec` over
        // each (layer, head) vector — elementwise for the float dtypes,
        // one shared pow2 scale per vector for int8
        for dtype in
            [KvDtype::F32, KvDtype::F16, KvDtype::Bf16, KvDtype::Int8]
        {
            let mut p = pool(dtype, 4, 16);
            let hd = p.head_dim;
            let lanes = p.n_layer * p.n_head;
            let blk = p.alloc().unwrap();
            let k_all: Vec<f32> =
                (0..lanes * hd).map(|i| (i as f32) * 0.01 - 1.0).collect();
            let v_all: Vec<f32> =
                (0..lanes * hd).map(|i| 2.0 - (i as f32) * 0.02).collect();
            p.write_token(blk, 3, &k_all, &v_all);
            let mut kk = vec![0.0f32; hd];
            let mut vv = vec![0.0f32; hd];
            for l in 0..p.n_layer {
                for h in 0..p.n_head {
                    p.read_k(blk, l, h, 3, 1, &mut kk);
                    p.read_v(blk, l, h, 3, 1, &mut vv);
                    let src = (l * p.n_head + h) * hd;
                    let mut want_k = k_all[src..src + hd].to_vec();
                    let mut want_v = v_all[src..src + hd].to_vec();
                    dtype.roundtrip_vec(&mut want_k);
                    dtype.roundtrip_vec(&mut want_v);
                    for i in 0..hd {
                        assert_eq!(kk[i].to_bits(), want_k[i].to_bits(), "{dtype:?}");
                        assert_eq!(vv[i].to_bits(), want_v[i].to_bits(), "{dtype:?}");
                    }
                }
            }
            p.release(blk);
        }
    }

    #[test]
    fn int8_block_bytes_count_scales() {
        // int8 blocks are codes + per-vector f32 scales; still well
        // under half an f16 block at head_dim 32
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let s8 = pool(KvDtype::Int8, 16, 4).stats();
        let s16 = pool(KvDtype::F16, 16, 4).stats();
        let stride = cfg.n_layer * cfg.n_head * 16 * cfg.head_dim();
        assert_eq!(
            s8.block_bytes,
            2 * stride + 2 * (stride / cfg.head_dim()) * 4
        );
        assert!(s8.block_bytes * 3 < s16.block_bytes * 2, "{s8:?} vs {s16:?}");
    }

    #[test]
    fn int8_make_private_copies_scales_with_codes() {
        let mut p = pool(KvDtype::Int8, 4, 16);
        let a = p.alloc().unwrap();
        let lanes = p.n_layer * p.n_head * p.head_dim;
        // two very different magnitudes in different slots, so a lost
        // scale copy would corrupt the dequantized values
        let big: Vec<f32> = (0..lanes).map(|i| 40.0 + i as f32).collect();
        let tiny: Vec<f32> = (0..lanes).map(|i| 0.001 * (i as f32 + 1.0)).collect();
        p.write_token(a, 0, &big, &big);
        p.write_token(a, 1, &tiny, &tiny);

        p.retain(a);
        let b = p.make_private(a).unwrap();
        assert_ne!(a, b);
        let hd = p.head_dim;
        let (mut got, mut want) = (vec![0.0f32; hd], vec![0.0f32; hd]);
        for l in 0..p.n_layer {
            for h in 0..p.n_head {
                for t in 0..2 {
                    p.read_k(b, l, h, t, 1, &mut got);
                    p.read_k(a, l, h, t, 1, &mut want);
                    assert_eq!(got, want, "K clone diverged at ({l},{h},{t})");
                    p.read_v(b, l, h, t, 1, &mut got);
                    p.read_v(a, l, h, t, 1, &mut want);
                    assert_eq!(got, want, "V clone diverged at ({l},{h},{t})");
                }
            }
        }
        p.release(a);
        p.release(b);
    }

    #[test]
    fn make_private_clones_shared_blocks_only() {
        let mut p = pool(KvDtype::F32, 4, 16);
        let a = p.alloc().unwrap();
        let lanes = p.n_layer * p.n_head * p.head_dim;
        let k_all: Vec<f32> = (0..lanes).map(|i| i as f32).collect();
        p.write_token(a, 0, &k_all, &k_all);
        // unshared: identity
        assert_eq!(p.make_private(a), Some(a));
        // shared: fresh copy, original keeps the other reference
        p.retain(a);
        let b = p.make_private(a).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.refcount(a), 1);
        assert_eq!(p.refcount(b), 1);
        let mut got = vec![0.0f32; p.head_dim];
        p.read_k(b, 0, 0, 0, 1, &mut got);
        assert_eq!(&got[..], &k_all[..p.head_dim], "clone must carry contents");
        p.release(a);
        p.release(b);
        assert_eq!(p.free_blocks(), p.total_blocks());
    }

    #[test]
    fn rehome_moves_ownership_without_copying() {
        let mut p = pool(KvDtype::F32, 16, 4);
        let a = p.alloc().unwrap();
        // unshared: identity (and the registry entry survives)
        assert_eq!(p.rehome(a), Some(a));
        p.retain(a);
        let b = p.rehome(a).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.refcount(a), 1);
        assert_eq!(p.refcount(b), 1);
        p.release(a);
        p.release(b);
        assert_eq!(p.free_blocks(), p.total_blocks());
    }

    /// Satellite property: arbitrary alloc / retain / release /
    /// make_private / register churn never leaks blocks, never aliases
    /// unshared handles, and always drains back to an empty pool.
    #[test]
    fn allocator_property_never_leaks_or_aliases() {
        run_property("kv pool churn", 24, |g: &mut Gen| {
            let blocks = g.usize(4, 12);
            let mut p = pool(KvDtype::F16, 16, blocks.max(4));
            let total = p.total_blocks();
            // rows: lists of (block, expected unique tag written)
            let mut live: Vec<u32> = Vec::new();
            let lanes = p.n_layer * p.n_head * p.head_dim;
            let mut tag = 0f32;
            for _ in 0..g.usize(10, 60) {
                match g.usize(0, 4) {
                    0 => {
                        if let Some(b) = p.alloc() {
                            // stamp fresh blocks with a unique tag
                            tag += 1.0;
                            let buf = vec![tag; lanes];
                            p.write_token(b, 0, &buf, &buf);
                            live.push(b);
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let i = g.usize(0, live.len());
                            let b = live[i];
                            p.retain(b);
                            live.push(b);
                        }
                    }
                    2 => {
                        if !live.is_empty() {
                            let i = g.usize(0, live.len());
                            let b = live.swap_remove(i);
                            p.release(b);
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = g.usize(0, live.len());
                            let b = live[i];
                            if let Some(nb) = p.make_private(b) {
                                if nb != b {
                                    // clone carries the original bytes
                                    let mut got = vec![0.0f32; p.head_dim];
                                    let mut want = vec![0.0f32; p.head_dim];
                                    p.read_k(nb, 0, 0, 0, 1, &mut got);
                                    p.read_k(b, 0, 0, 0, 1, &mut want);
                                    prop_assert!(
                                        got == want,
                                        "CoW clone lost contents"
                                    );
                                }
                                live[i] = nb;
                            }
                        }
                    }
                }
                // conservation: free + live handles' blocks == total
                let held: std::collections::BTreeSet<u32> =
                    live.iter().copied().collect();
                prop_assert!(
                    p.free_blocks() + held.len() == total,
                    "leak: {} free + {} held != {} total",
                    p.free_blocks(),
                    held.len(),
                    total
                );
                // refcount of every held block == number of handles
                for &b in &held {
                    let handles =
                        live.iter().filter(|&&x| x == b).count() as u32;
                    prop_assert!(
                        p.refcount(b) == handles,
                        "block {b}: refcount {} vs {} handles",
                        p.refcount(b),
                        handles
                    );
                }
                // unshared handles never alias each other
                let unshared: Vec<u32> = held
                    .iter()
                    .copied()
                    .filter(|&b| !p.is_shared(b))
                    .collect();
                let uniq: std::collections::BTreeSet<u32> =
                    unshared.iter().copied().collect();
                prop_assert!(uniq.len() == unshared.len(), "aliased blocks");
            }
            // drop every handle: the pool must return to empty
            for b in live.drain(..) {
                p.release(b);
            }
            prop_assert!(
                p.free_blocks() == total,
                "pool did not drain: {} of {}",
                p.free_blocks(),
                total
            );
            prop_assert!(p.shared_blocks() == 0);
            Ok(())
        });
    }
}
