//! The **Normalizer seam** (DESIGN.md §Normalizer seam): every score
//! normalizer the stack supports, resolved from its CLI/config name
//! exactly once at model load. The enum owns what used to be scattered
//! string matches and hand-threaded `is_consmax`/`is_softermax` flags:
//!
//! * the **name registry** ([`Normalizer::parse`] / [`Normalizer::NAMES`]) —
//!   the single place `config.rs` and `model.rs` validate against, so a
//!   zoo addition cannot drift between layers;
//! * the **parameter schema** ([`Normalizer::extra_params`] /
//!   [`Normalizer::required_params`]) — per-(layer, head) β/γ for the
//!   ConSmax family, the learnable per-(layer, head) scale for SSMax;
//! * the **forward form** — reduction-free streaming `score → p` for the
//!   ConSmax family ([`HeadNorm::stream_p`], the paper's point: no row
//!   max/sum barrier), row-reducing normalization for the rest
//!   ([`HeadNorm::normalize_row`], which dispatches to the exact
//!   [`native`] kernels the pre-seam code called, so logits stay
//!   bitwise-identical);
//! * the **backward rule** ([`HeadNorm::backward_row`]) — what makes the
//!   native trainer inherit every zoo member for free. ConSmax's is the
//!   paper's selling point: `∂p/∂s = p` (no softmax Jacobian), so
//!   `ds = p ⊙ dp` plus two scalar reductions for β/γ.
//!
//! The zoo:
//!
//! | name         | row form                          | learnables        |
//! |--------------|-----------------------------------|-------------------|
//! | `softmax`    | `exp(s−m)/Σ`                      | —                 |
//! | `softermax`  | `2^(s−m)/Σ` (base-2 softmax)      | —                 |
//! | `consmax`    | `exp(s−β)/γ` (no reduction)       | β, γ per (l, h)   |
//! | `consmax-v2` | `2^(s−β)/γ` (base-2 ConSmax)      | β, γ per (l, h)   |
//! | `ssmax`      | `softmax(s·ln(n)·s_lh)` (n keys)  | s_lh per (l, h)   |
//!
//! `consmax-v2` is the per-head, exponent-base-2 variant (hardware
//! shifters instead of `exp`; cf. the nanoGPT softmax-variations zoo) —
//! the learnable schema matches ConSmax, only the base changes. `ssmax`
//! is Scalable-Softmax: the score row is rescaled by `s_lh · ln(n)`
//! before a standard softmax so attention does not flatten as the key
//! count `n` grows; at `n = 1`, `ln(1) = 0` collapses the row to the
//! single trivial probability, which is also what softmax emits.

use anyhow::{bail, Result};

use crate::runtime::backend::native;
use crate::runtime::backend::simd;

// `ln 2`: the score-side Jacobian factor of every base-2 normalizer.
use std::f32::consts::LN_2;

/// A score normalizer, resolved from its name once at model load.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Normalizer {
    /// Standard max-subtracted softmax.
    Softmax,
    /// Base-2 softmax (`2^x` row normalization).
    Softermax,
    /// The paper's learnable normalizer: `exp(s − β)/γ`, no reduction.
    Consmax,
    /// ConSmax with exponent base 2: `2^(s − β)/γ`, no reduction.
    ConsmaxV2,
    /// Scalable-Softmax: `softmax(s · s_lh · ln n)` over `n` keys.
    Ssmax,
}

impl Normalizer {
    /// Every accepted `--normalizer` name, in CLI/display order.
    pub const NAMES: [&'static str; 5] =
        ["softmax", "consmax", "softermax", "consmax-v2", "ssmax"];

    /// The help string CLI surfaces print for `--normalizer`.
    pub const HELP: &'static str =
        "softmax|consmax|softermax|consmax-v2|ssmax";

    /// The one registry lookup: name → normalizer. Every layer that
    /// used to re-validate the string (config, model load) calls this.
    pub fn parse(name: &str) -> Result<Normalizer> {
        Ok(match name {
            "softmax" => Normalizer::Softmax,
            "softermax" => Normalizer::Softermax,
            "consmax" => Normalizer::Consmax,
            "consmax-v2" => Normalizer::ConsmaxV2,
            "ssmax" => Normalizer::Ssmax,
            other => {
                bail!("unknown normalizer {other:?} ({})", Normalizer::HELP)
            }
        })
    }

    /// The canonical name (`parse` round-trips it).
    pub fn name(&self) -> &'static str {
        match self {
            Normalizer::Softmax => "softmax",
            Normalizer::Softermax => "softermax",
            Normalizer::Consmax => "consmax",
            Normalizer::ConsmaxV2 => "consmax-v2",
            Normalizer::Ssmax => "ssmax",
        }
    }

    /// Whether the forward form streams score → p per key with no row
    /// reduction (the ConSmax family) — these take the fused
    /// score→p→PV attention tails; the rest collect a score row first.
    pub fn is_streaming(&self) -> bool {
        matches!(self, Normalizer::Consmax | Normalizer::ConsmaxV2)
    }

    /// Whether the normalizer owns per-(layer, head) β/γ parameters.
    pub fn uses_beta_gamma(&self) -> bool {
        matches!(self, Normalizer::Consmax | Normalizer::ConsmaxV2)
    }

    /// Whether the normalizer owns the per-(layer, head) SSMax scale.
    pub fn uses_ssmax_scale(&self) -> bool {
        matches!(self, Normalizer::Ssmax)
    }

    /// Parameters this normalizer appends to the canonical schema
    /// beyond the β/γ rows every builtin config carries (python-preset
    /// parity keeps β/γ in the order even for softmax models).
    pub fn extra_params(&self) -> &'static [&'static str] {
        match self {
            Normalizer::Ssmax => &["ssmax_s"],
            _ => &[],
        }
    }

    /// Parameters that must be present at model load for this
    /// normalizer's attention tail to run.
    pub fn required_params(&self) -> &'static [&'static str] {
        match self {
            Normalizer::Consmax | Normalizer::ConsmaxV2 => &["beta", "gamma"],
            Normalizer::Ssmax => &["ssmax_s"],
            _ => &[],
        }
    }
}

/// Gradients of one attention row's loss w.r.t. the normalizer's own
/// learnables (zero for the parameter-free kinds).
#[derive(Clone, Copy, Debug, Default)]
pub struct NormGrad {
    pub dbeta: f32,
    pub dgamma: f32,
    pub dsscale: f32,
}

/// One (layer, head)'s normalizer, with its scalars resolved: the unit
/// of dispatch at every attention tail (forward, decode, paged,
/// training). Copy-cheap so parallel attention closures capture it by
/// value.
#[derive(Clone, Copy, Debug)]
pub struct HeadNorm {
    pub kind: Normalizer,
    /// ConSmax-family β (0 for the rest).
    pub beta: f32,
    /// ConSmax-family γ (1 for the rest).
    pub gamma: f32,
    /// SSMax per-head scale (0 for the rest).
    pub sscale: f32,
}

impl HeadNorm {
    /// Resolve head `hh`'s scalars out of the model's per-layer rows
    /// (empty slices for normalizers that don't own the parameter).
    pub fn from_rows(
        kind: Normalizer,
        beta_row: &[f32],
        gamma_row: &[f32],
        ssm_row: &[f32],
        hh: usize,
    ) -> HeadNorm {
        HeadNorm {
            kind,
            beta: beta_row.get(hh).copied().unwrap_or(0.0),
            gamma: gamma_row.get(hh).copied().unwrap_or(1.0),
            sscale: ssm_row.get(hh).copied().unwrap_or(0.0),
        }
    }

    /// Streaming score → probability for the reduction-free kinds —
    /// the identical expression, through the identical dispatched
    /// [`simd::exp`] / [`simd::exp2`], as the fused `attend_stream`
    /// kernel, so the batched forward and the decode engine stay
    /// bitwise-equal at every SIMD level.
    #[inline]
    pub fn stream_p(&self, sc: f32) -> f32 {
        match self.kind {
            Normalizer::Consmax => simd::exp(sc - self.beta) / self.gamma,
            Normalizer::ConsmaxV2 => simd::exp2(sc - self.beta) / self.gamma,
            _ => unreachable!("stream_p on a row-reducing normalizer"),
        }
    }

    /// In-place scores → probabilities over one attention row of
    /// `row.len()` keys. Row-reducing kinds dispatch to the exact
    /// pre-seam [`native`] kernels (bitwise-identical logits); the
    /// streaming kinds map [`HeadNorm::stream_p`] so the trainer can
    /// materialize every normalizer's probability row uniformly.
    pub fn normalize_row(&self, row: &mut [f32]) {
        match self.kind {
            Normalizer::Softmax => native::softmax_inplace(row),
            Normalizer::Softermax => native::softermax_inplace(row),
            Normalizer::Ssmax => {
                let c = self.sscale * (row.len() as f32).ln();
                for s in row.iter_mut() {
                    *s *= c;
                }
                native::softmax_inplace(row);
            }
            Normalizer::Consmax | Normalizer::ConsmaxV2 => {
                for s in row.iter_mut() {
                    *s = self.stream_p(*s);
                }
            }
        }
    }

    /// Backward through one attention row: given the forward
    /// probabilities `probs`, the upstream gradient `dprobs`, and (for
    /// SSMax only) the raw pre-scale scores `raw`, write `∂L/∂score`
    /// into `dscores` and return the normalizer's own parameter
    /// gradients.
    ///
    /// With `dot = Σ_j p_j·dp_j`:
    ///
    /// * softmax       `ds_j = p_j (dp_j − dot)` (the softmax Jacobian)
    /// * softermax     `ds_j = ln2 · p_j (dp_j − dot)`
    /// * consmax       `ds_j = p_j dp_j`, `dβ = −dot`, `dγ = −dot/γ`
    /// * consmax-v2    `ds_j = ln2 · p_j dp_j`, `dβ = −ln2·dot`,
    ///   `dγ = −dot/γ`
    /// * ssmax         `dz_j = p_j (dp_j − dot)` through the inner
    ///   softmax over `z = c·raw`, then `ds_j = c·dz_j` and
    ///   `ds_lh = ln(n) · Σ_j dz_j raw_j` through `c = s_lh·ln(n)`
    ///
    /// ConSmax's rule is the paper's training claim made concrete:
    /// `∂p/∂s = p` — a diagonal Jacobian, no cross-key coupling.
    pub fn backward_row(
        &self,
        probs: &[f32],
        dprobs: &[f32],
        raw: &[f32],
        dscores: &mut [f32],
    ) -> NormGrad {
        debug_assert_eq!(probs.len(), dprobs.len());
        debug_assert_eq!(probs.len(), dscores.len());
        let dot: f32 = probs.iter().zip(dprobs).map(|(&p, &dp)| p * dp).sum();
        let mut g = NormGrad::default();
        match self.kind {
            Normalizer::Softmax => {
                for ((ds, &p), &dp) in
                    dscores.iter_mut().zip(probs).zip(dprobs)
                {
                    *ds = p * (dp - dot);
                }
            }
            Normalizer::Softermax => {
                for ((ds, &p), &dp) in
                    dscores.iter_mut().zip(probs).zip(dprobs)
                {
                    *ds = LN_2 * p * (dp - dot);
                }
            }
            Normalizer::Consmax => {
                for ((ds, &p), &dp) in
                    dscores.iter_mut().zip(probs).zip(dprobs)
                {
                    *ds = p * dp;
                }
                g.dbeta = -dot;
                g.dgamma = -dot / self.gamma;
            }
            Normalizer::ConsmaxV2 => {
                for ((ds, &p), &dp) in
                    dscores.iter_mut().zip(probs).zip(dprobs)
                {
                    *ds = LN_2 * p * dp;
                }
                g.dbeta = -LN_2 * dot;
                g.dgamma = -dot / self.gamma;
            }
            Normalizer::Ssmax => {
                debug_assert_eq!(probs.len(), raw.len());
                let ln_n = (probs.len() as f32).ln();
                let c = self.sscale * ln_n;
                for (((ds, &p), &dp), &rw) in
                    dscores.iter_mut().zip(probs).zip(dprobs).zip(raw)
                {
                    let dz = p * (dp - dot);
                    *ds = c * dz;
                    g.dsscale += dz * rw * ln_n;
                }
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn parse_round_trips_every_name() {
        for name in Normalizer::NAMES {
            let n = Normalizer::parse(name).unwrap();
            assert_eq!(n.name(), name);
        }
        assert!(Normalizer::parse("sparsemax").is_err());
        assert!(Normalizer::parse("").is_err());
    }

    #[test]
    fn schema_matches_kind() {
        for name in Normalizer::NAMES {
            let n = Normalizer::parse(name).unwrap();
            assert_eq!(n.uses_beta_gamma(), n.is_streaming());
            assert_eq!(
                n.uses_ssmax_scale(),
                n.extra_params().contains(&"ssmax_s")
            );
            for req in n.required_params() {
                assert!(
                    *req == "beta" || *req == "gamma" || *req == "ssmax_s"
                );
            }
        }
    }

    #[test]
    fn ssmax_single_key_is_trivial() {
        let hn = HeadNorm {
            kind: Normalizer::Ssmax,
            beta: 0.0,
            gamma: 1.0,
            sscale: 0.43,
        };
        let mut row = [3.7f32];
        hn.normalize_row(&mut row);
        assert_eq!(row[0], 1.0);
    }

    /// Central finite differences over `L = Σ w_j p_j(scores, θ)` pin
    /// every backward rule against its own forward, per normalizer.
    #[test]
    fn backward_row_matches_finite_differences() {
        let n = 6usize;
        let h = 1e-2f32;
        let mut rng = Pcg32::seeded(11);
        for name in Normalizer::NAMES {
            let kind = Normalizer::parse(name).unwrap();
            // γ pinned near 1 so FD on small f32 probabilities stays
            // well-conditioned; β/scale arbitrary
            let hn = HeadNorm {
                kind,
                beta: 0.7,
                gamma: 2.0,
                sscale: 0.43,
            };
            let scores: Vec<f32> = rng.normal_vec_f32(n, 0.0, 1.0);
            let w: Vec<f32> = rng.normal_vec_f32(n, 0.0, 1.0);
            let loss = |hn: &HeadNorm, scores: &[f32]| -> f32 {
                let mut row = scores.to_vec();
                hn.normalize_row(&mut row);
                row.iter().zip(&w).map(|(&p, &wj)| p * wj).sum()
            };

            // analytic gradient
            let mut probs = scores.clone();
            hn.normalize_row(&mut probs);
            let mut ds = vec![0.0f32; n];
            let g = hn.backward_row(&probs, &w, &scores, &mut ds);

            for j in 0..n {
                let mut up = scores.clone();
                up[j] += h;
                let mut dn = scores.clone();
                dn[j] -= h;
                let fd = (loss(&hn, &up) - loss(&hn, &dn)) / (2.0 * h);
                assert!(
                    (fd - ds[j]).abs() <= 1e-3 * fd.abs().max(1.0),
                    "{name} ds[{j}]: fd {fd} vs an {}",
                    ds[j]
                );
            }
            let fd_scalar = |bump: &dyn Fn(&mut HeadNorm, f32)| -> f32 {
                let mut a = hn;
                bump(&mut a, h);
                let mut b = hn;
                bump(&mut b, -h);
                (loss(&a, &scores) - loss(&b, &scores)) / (2.0 * h)
            };
            if kind.uses_beta_gamma() {
                let fdb = fd_scalar(&|m, e| m.beta += e);
                assert!(
                    (fdb - g.dbeta).abs() <= 1e-3 * fdb.abs().max(1.0),
                    "{name} dbeta: fd {fdb} vs an {}",
                    g.dbeta
                );
                let fdg = fd_scalar(&|m, e| m.gamma += e);
                assert!(
                    (fdg - g.dgamma).abs() <= 1e-3 * fdg.abs().max(1.0),
                    "{name} dgamma: fd {fdg} vs an {}",
                    g.dgamma
                );
            }
            if kind.uses_ssmax_scale() {
                let fds = fd_scalar(&|m, e| m.sscale += e);
                assert!(
                    (fds - g.dsscale).abs() <= 1e-3 * fds.abs().max(1.0),
                    "{name} dsscale: fd {fds} vs an {}",
                    g.dsscale
                );
            }
        }
    }
}
