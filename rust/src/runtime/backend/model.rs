//! Pure-Rust GPT forward pass over the paper's benchmark architecture
//! (python/compile/model.py §Forward), used by the native backend for
//! evaluation, generation and serving when no PJRT artifacts exist.
//!
//! Semantics mirror the JAX model exactly: pre-LN blocks, causal
//! attention with the configured score normalizer (softmax | consmax |
//! softermax), tanh-approximate GELU, tied LM head. ConSmax runs in its
//! *training* form `exp(s - β)/γ` with per-(layer, head) scalars — the
//! same probabilities the inference form `C·exp(s)` produces once β/γ are
//! merged (asserted in `native.rs` tests).
//!
//! This is a forward-only model (no autodiff): training still goes
//! through the AOT `train_step` under `--features pjrt`. For the paper's
//! model sizes (tiny 2L/64d, paper 6L/384d) a recompute-per-token decode
//! is fast enough to serve the demo workloads, and it keeps the native
//! path free of KV-cache state.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::config::ModelConfig;
use crate::runtime::backend::native;
use crate::runtime::HostTensor;

/// A model with host-resident f32 parameters, ready for forward passes.
pub struct NativeModel {
    pub cfg: ModelConfig,
    params: BTreeMap<String, Vec<f32>>,
}

impl NativeModel {
    /// Build from a parameter list in canonical order (e.g. a
    /// `ParamStore`'s `order`/`params` pair).
    pub fn from_params(
        cfg: &ModelConfig,
        order: &[String],
        tensors: &[HostTensor],
    ) -> Result<NativeModel> {
        ensure!(
            order.len() == tensors.len(),
            "param order ({}) / tensor ({}) length mismatch",
            order.len(),
            tensors.len()
        );
        match cfg.normalizer.as_str() {
            "softmax" | "consmax" | "softermax" => {}
            other => bail!("native model: unknown normalizer {other:?}"),
        }
        let mut params = BTreeMap::new();
        for (name, t) in order.iter().zip(tensors) {
            let want: usize = cfg.shape_of(name)?.iter().product();
            ensure!(
                t.elems() == want,
                "param {name}: {} elements, config wants {want}",
                t.elems()
            );
            params.insert(name.clone(), t.as_f32()?);
        }
        for required in [
            "wte", "wpe", "ln1_g", "ln1_b", "attn_qkv_w", "attn_qkv_b",
            "attn_proj_w", "attn_proj_b", "ln2_g", "ln2_b", "mlp_fc_w",
            "mlp_fc_b", "mlp_proj_w", "mlp_proj_b", "lnf_g", "lnf_b",
        ] {
            ensure!(params.contains_key(required), "missing param {required}");
        }
        if cfg.normalizer == "consmax" {
            ensure!(
                params.contains_key("beta") && params.contains_key("gamma"),
                "consmax model needs beta/gamma params"
            );
        }
        Ok(NativeModel { cfg: cfg.clone(), params })
    }

    fn p(&self, name: &str) -> &[f32] {
        // presence validated in from_params
        self.params.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Per-layer slice of a stacked parameter (leading axis = layer).
    fn layer<'a>(&'a self, name: &str, l: usize, per: usize) -> &'a [f32] {
        &self.p(name)[l * per..(l + 1) * per]
    }

    /// Token ids (b, t) row-major → logits (b, t, vocab) row-major.
    pub fn forward(&self, tokens: &[i32], b: usize, t: usize) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, h, hd, v) = (cfg.n_embd, cfg.n_head, cfg.head_dim(), cfg.vocab);
        ensure!(tokens.len() == b * t, "token buffer is not (b={b}, t={t})");
        ensure!(t >= 1 && t <= cfg.ctx, "sequence length {t} vs ctx {}", cfg.ctx);
        for &tok in tokens {
            ensure!(
                (0..v as i32).contains(&tok),
                "token id {tok} outside vocab {v}"
            );
        }

        let wte = self.p("wte");
        let wpe = self.p("wpe");
        let rows = b * t;
        let mut x = vec![0.0f32; rows * d];
        for r in 0..b {
            for i in 0..t {
                let tok = tokens[r * t + i] as usize;
                let out = &mut x[(r * t + i) * d..(r * t + i + 1) * d];
                let te = &wte[tok * d..(tok + 1) * d];
                let pe = &wpe[i * d..(i + 1) * d];
                for ((o, &a), &p) in out.iter_mut().zip(te).zip(pe) {
                    *o = a + p;
                }
            }
        }

        let scale = 1.0 / (hd as f32).sqrt();
        for l in 0..cfg.n_layer {
            // ---- attention block (pre-LN) -----------------------------
            let xn = layer_norm(
                &x,
                self.layer("ln1_g", l, d),
                self.layer("ln1_b", l, d),
                d,
            );
            let qkv = affine(
                &xn,
                self.layer("attn_qkv_w", l, d * 3 * d),
                self.layer("attn_qkv_b", l, 3 * d),
                rows,
                d,
                3 * d,
            );
            let beta = if self.params.contains_key("beta") {
                self.layer("beta", l, h)
            } else {
                &[]
            };
            let gamma = if self.params.contains_key("gamma") {
                self.layer("gamma", l, h)
            } else {
                &[]
            };

            let mut y = vec![0.0f32; rows * d];
            for r in 0..b {
                for hh in 0..h {
                    for i in 0..t {
                        let qoff = (r * t + i) * 3 * d + hh * hd;
                        // causal scores over keys j <= i; omitting j > i is
                        // the -inf mask (exp(-inf) = 0 in every normalizer)
                        let mut srow = Vec::with_capacity(i + 1);
                        for j in 0..=i {
                            let koff = (r * t + j) * 3 * d + d + hh * hd;
                            let mut acc = 0.0f32;
                            for e in 0..hd {
                                acc += qkv[qoff + e] * qkv[koff + e];
                            }
                            srow.push(acc * scale);
                        }
                        let probs = match cfg.normalizer.as_str() {
                            "consmax" => {
                                native::consmax_train(&srow, beta[hh], gamma[hh])
                            }
                            "softermax" => {
                                native::softermax_rows(&srow, srow.len())
                            }
                            _ => native::softmax_rows(&srow, srow.len()),
                        };
                        let ooff = (r * t + i) * d + hh * hd;
                        for (j, &pj) in probs.iter().enumerate() {
                            let voff = (r * t + j) * 3 * d + 2 * d + hh * hd;
                            for e in 0..hd {
                                y[ooff + e] += pj * qkv[voff + e];
                            }
                        }
                    }
                }
            }
            let proj = affine(
                &y,
                self.layer("attn_proj_w", l, d * d),
                self.layer("attn_proj_b", l, d),
                rows,
                d,
                d,
            );
            for (xv, pv) in x.iter_mut().zip(&proj) {
                *xv += pv;
            }

            // ---- MLP block (pre-LN) -----------------------------------
            let xn2 = layer_norm(
                &x,
                self.layer("ln2_g", l, d),
                self.layer("ln2_b", l, d),
                d,
            );
            let mut hid = affine(
                &xn2,
                self.layer("mlp_fc_w", l, d * 4 * d),
                self.layer("mlp_fc_b", l, 4 * d),
                rows,
                d,
                4 * d,
            );
            for hv in hid.iter_mut() {
                *hv = gelu(*hv);
            }
            let mo = affine(
                &hid,
                self.layer("mlp_proj_w", l, 4 * d * d),
                self.layer("mlp_proj_b", l, d),
                rows,
                4 * d,
                d,
            );
            for (xv, mv) in x.iter_mut().zip(&mo) {
                *xv += mv;
            }
        }

        let xf = layer_norm(&x, self.p("lnf_g"), self.p("lnf_b"), d);
        // tied LM head: logits = xf @ wte^T
        let mut logits = vec![0.0f32; rows * v];
        for r in 0..rows {
            let xr = &xf[r * d..(r + 1) * d];
            let lr = &mut logits[r * v..(r + 1) * v];
            for (vv, o) in lr.iter_mut().enumerate() {
                let wr = &wte[vv * d..(vv + 1) * d];
                let mut acc = 0.0f32;
                for e in 0..d {
                    acc += xr[e] * wr[e];
                }
                *o = acc;
            }
        }
        Ok(logits)
    }

    /// Mean next-token cross-entropy over a flat (b, t) batch, matching
    /// the JAX `loss_fn` (log-softmax over the tied head).
    pub fn loss(&self, x: &[i32], y: &[i32], b: usize, t: usize) -> Result<f64> {
        ensure!(x.len() == y.len(), "x/y length mismatch");
        let logits = self.forward(x, b, t)?;
        let v = self.cfg.vocab;
        let mut total = 0.0f64;
        for (pos, &target) in y.iter().enumerate() {
            ensure!(
                (0..v as i32).contains(&target),
                "target id {target} outside vocab {v}"
            );
            let row = &logits[pos * v..(pos + 1) * v];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = m + row.iter().map(|&l| (l - m).exp()).sum::<f32>().ln();
            total += (lse - row[target as usize]) as f64;
        }
        Ok(total / y.len() as f64)
    }

    /// Next-token logits (b, vocab) for equal-length token sequences,
    /// recomputing the forward pass over a ctx-bounded trailing window —
    /// the native decode step.
    pub fn next_logits(&self, seqs: &[Vec<i32>]) -> Result<Vec<f32>> {
        ensure!(!seqs.is_empty(), "empty decode batch");
        let len = seqs[0].len();
        ensure!(len >= 1, "empty sequences");
        ensure!(
            seqs.iter().all(|s| s.len() == len),
            "decode batch rows must share a length"
        );
        let b = seqs.len();
        let w = len.min(self.cfg.ctx);
        let mut toks = Vec::with_capacity(b * w);
        for s in seqs {
            toks.extend_from_slice(&s[len - w..]);
        }
        let logits = self.forward(&toks, b, w)?;
        let v = self.cfg.vocab;
        let mut out = Vec::with_capacity(b * v);
        for r in 0..b {
            let base = (r * w + (w - 1)) * v;
            out.extend_from_slice(&logits[base..base + v]);
        }
        Ok(out)
    }
}

fn layer_norm(x: &[f32], g: &[f32], b: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    for (row_in, row_out) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let mu = row_in.iter().sum::<f32>() / d as f32;
        let var =
            row_in.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for ((o, &v), (&gg, &bb)) in
            row_out.iter_mut().zip(row_in).zip(g.iter().zip(b))
        {
            *o = (v - mu) * inv * gg + bb;
        }
    }
    out
}

fn affine(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    din: usize,
    dout: usize,
) -> Vec<f32> {
    let mut out = native::matmul(x, w, rows, din, dout);
    for row in out.chunks_exact_mut(dout) {
        for (o, &bv) in row.iter_mut().zip(bias) {
            *o += bv;
        }
    }
    out
}

/// Tanh-approximate GELU, matching `jax.nn.gelu` (approximate=True).
fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn tiny_model(normalizer: &str) -> NativeModel {
        let cfg = ModelConfig::builtin("tiny", normalizer).unwrap();
        let mut rng = Pcg32::seeded(7);
        let mut tensors = Vec::new();
        for name in cfg.param_order.clone() {
            let shape = cfg.shape_of(&name).unwrap().to_vec();
            let n: usize = shape.iter().product();
            let vals: Vec<f32> = match name.as_str() {
                "ln1_g" | "ln2_g" | "lnf_g" => vec![1.0; n],
                "beta" => vec![1.5; n],
                "gamma" => vec![100.0; n],
                _ if name.ends_with("_b") => vec![0.0; n],
                _ => rng.normal_vec_f32(n, 0.0, 0.02),
            };
            tensors.push(HostTensor::from_f32(&vals, &shape));
        }
        NativeModel::from_params(&cfg, &cfg.param_order, &tensors).unwrap()
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        for norm in ["consmax", "softmax", "softermax"] {
            let m = tiny_model(norm);
            let toks: Vec<i32> = (0..2 * 8).map(|i| (i * 13) % 256).collect();
            let logits = m.forward(&toks, 2, 8).unwrap();
            assert_eq!(logits.len(), 2 * 8 * 256, "{norm}");
            assert!(logits.iter().all(|v| v.is_finite()), "{norm}");
        }
    }

    #[test]
    fn untrained_loss_near_uniform() {
        // near-random weights => loss close to ln(256) = 5.545
        let m = tiny_model("consmax");
        let x: Vec<i32> = (0..2 * 32).map(|i| (i * 7) % 256).collect();
        let y: Vec<i32> = (0..2 * 32).map(|i| (i * 7 + 1) % 256).collect();
        let loss = m.loss(&x, &y, 2, 32).unwrap();
        assert!((4.5..6.5).contains(&loss), "loss {loss}");
    }

    #[test]
    fn forward_is_deterministic() {
        let m = tiny_model("consmax");
        let toks: Vec<i32> = (0..16).map(|i| (i * 31) % 256).collect();
        assert_eq!(m.forward(&toks, 1, 16).unwrap(), m.forward(&toks, 1, 16).unwrap());
    }

    #[test]
    fn causality_prefix_logits_stable() {
        // logits at position i must not depend on tokens after i
        let m = tiny_model("consmax");
        let mut a: Vec<i32> = (0..12).map(|i| (i * 11) % 256).collect();
        let la = m.forward(&a, 1, 12).unwrap();
        a[11] = (a[11] + 17) % 256; // change only the last token
        let lb = m.forward(&a, 1, 12).unwrap();
        let v = m.cfg.vocab;
        // positions 0..10 identical; position 11 differs
        assert_eq!(&la[..11 * v], &lb[..11 * v]);
        assert_ne!(&la[11 * v..], &lb[11 * v..]);
    }

    #[test]
    fn next_logits_matches_forward_tail() {
        let m = tiny_model("softmax");
        let seq: Vec<i32> = (0..10).map(|i| (i * 3) % 256).collect();
        let full = m.forward(&seq, 1, 10).unwrap();
        let v = m.cfg.vocab;
        let nl = m.next_logits(&[seq]).unwrap();
        assert_eq!(nl, full[9 * v..].to_vec());
    }

    #[test]
    fn window_clamps_to_ctx() {
        let m = tiny_model("consmax");
        let long: Vec<i32> = (0..200).map(|i| i % 256).collect();
        let nl = m.next_logits(&[long]).unwrap();
        assert_eq!(nl.len(), m.cfg.vocab);
        assert!(nl.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn rejects_bad_tokens() {
        let m = tiny_model("consmax");
        assert!(m.forward(&[300], 1, 1).is_err());
        assert!(m.forward(&[-1], 1, 1).is_err());
        assert!(m.forward(&[0; 4], 2, 3).is_err()); // wrong element count
    }
}
