//! Pure-Rust GPT forward pass over the paper's benchmark architecture
//! (python/compile/model.py §Forward), used by the native backend for
//! evaluation, generation and serving when no PJRT artifacts exist.
//!
//! Semantics mirror the JAX model exactly: pre-LN blocks, causal
//! attention with the configured score normalizer (softmax | consmax |
//! softermax), tanh-approximate GELU, tied LM head. ConSmax runs in its
//! *training* form `exp(s - β)/γ` with per-(layer, head) scalars — the
//! same probabilities the inference form `C·exp(s)` produces once β/γ are
//! merged (asserted in `native.rs` tests).
//!
//! This is a forward-only model (no autodiff): training still goes
//! through the AOT `train_step` under `--features pjrt`. Decoding has two
//! faces:
//!
//! * [`NativeModel::next_logits`] — the **recompute oracle**: a full
//!   forward over the ctx-bounded trailing window per step, O(T²) per
//!   generated token. Kept as the reference the KV engine is tested
//!   against (`rust/tests/decode_engine.rs`) and reachable in serving
//!   via `--decode recompute`.
//! * [`NativeModel::prefill`] + [`NativeModel::decode_step`] — the
//!   **KV-cached engine** over a [`DecodeSession`]: one O(T) incremental
//!   pass per token, per-row true lengths (no left-pad pollution), and —
//!   because ConSmax has no row max/sum — a single fused
//!   score→prob→PV accumulation per cached key in the consmax case.
//!   Both paths produce bitwise-identical logits: they run the same
//!   kernels over the same values in the same order.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::config::ModelConfig;
use crate::runtime::backend::native;
use crate::runtime::backend::DecodeSession;
use crate::runtime::HostTensor;

/// A model with host-resident f32 parameters, ready for forward passes.
pub struct NativeModel {
    pub cfg: ModelConfig,
    params: BTreeMap<String, Vec<f32>>,
}

impl NativeModel {
    /// Build from a parameter list in canonical order (e.g. a
    /// `ParamStore`'s `order`/`params` pair).
    pub fn from_params(
        cfg: &ModelConfig,
        order: &[String],
        tensors: &[HostTensor],
    ) -> Result<NativeModel> {
        ensure!(
            order.len() == tensors.len(),
            "param order ({}) / tensor ({}) length mismatch",
            order.len(),
            tensors.len()
        );
        match cfg.normalizer.as_str() {
            "softmax" | "consmax" | "softermax" => {}
            other => bail!("native model: unknown normalizer {other:?}"),
        }
        let mut params = BTreeMap::new();
        for (name, t) in order.iter().zip(tensors) {
            let want: usize = cfg.shape_of(name)?.iter().product();
            ensure!(
                t.elems() == want,
                "param {name}: {} elements, config wants {want}",
                t.elems()
            );
            params.insert(name.clone(), t.as_f32()?);
        }
        for required in [
            "wte", "wpe", "ln1_g", "ln1_b", "attn_qkv_w", "attn_qkv_b",
            "attn_proj_w", "attn_proj_b", "ln2_g", "ln2_b", "mlp_fc_w",
            "mlp_fc_b", "mlp_proj_w", "mlp_proj_b", "lnf_g", "lnf_b",
        ] {
            ensure!(params.contains_key(required), "missing param {required}");
        }
        if cfg.normalizer == "consmax" {
            ensure!(
                params.contains_key("beta") && params.contains_key("gamma"),
                "consmax model needs beta/gamma params"
            );
        }
        Ok(NativeModel { cfg: cfg.clone(), params })
    }

    fn p(&self, name: &str) -> &[f32] {
        // presence validated in from_params
        self.params.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Per-layer slice of a stacked parameter (leading axis = layer).
    fn layer<'a>(&'a self, name: &str, l: usize, per: usize) -> &'a [f32] {
        &self.p(name)[l * per..(l + 1) * per]
    }

    /// Per-layer β scalars (empty for softmax/softermax models).
    fn beta_row(&self, l: usize) -> &[f32] {
        if self.params.contains_key("beta") {
            self.layer("beta", l, self.cfg.n_head)
        } else {
            &[]
        }
    }

    /// Per-layer γ scalars (empty for softmax/softermax models).
    fn gamma_row(&self, l: usize) -> &[f32] {
        if self.params.contains_key("gamma") {
            self.layer("gamma", l, self.cfg.n_head)
        } else {
            &[]
        }
    }

    /// Token ids (b, t) row-major → logits (b, t, vocab) row-major.
    pub fn forward(&self, tokens: &[i32], b: usize, t: usize) -> Result<Vec<f32>> {
        self.forward_impl(tokens, b, t, false, None)
    }

    /// The shared transformer trunk behind both decode faces.
    ///
    /// * `last_only` — emit logits for each row's final position only
    ///   (b, vocab), skipping the (b, t, vocab) LM-head matmul that
    ///   evaluation needs but decoding discards.
    /// * `capture` — `(session, row)`: store every layer's K/V segments
    ///   into the session's caches at slots `0..t` for that row (b must
    ///   be 1). This is how `prefill` fills a `DecodeSession` with
    ///   exactly the values a plain forward would compute.
    fn forward_impl(
        &self,
        tokens: &[i32],
        b: usize,
        t: usize,
        last_only: bool,
        mut capture: Option<(&mut DecodeSession, usize)>,
    ) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, h, hd, v) = (cfg.n_embd, cfg.n_head, cfg.head_dim(), cfg.vocab);
        ensure!(tokens.len() == b * t, "token buffer is not (b={b}, t={t})");
        ensure!(t >= 1 && t <= cfg.ctx, "sequence length {t} vs ctx {}", cfg.ctx);
        if capture.is_some() {
            ensure!(b == 1, "kv capture expects a single-row forward");
        }
        for &tok in tokens {
            ensure!(
                (0..v as i32).contains(&tok),
                "token id {tok} outside vocab {v}"
            );
        }

        let wte = self.p("wte");
        let wpe = self.p("wpe");
        let rows = b * t;
        let mut x = vec![0.0f32; rows * d];
        for r in 0..b {
            for i in 0..t {
                let tok = tokens[r * t + i] as usize;
                let out = &mut x[(r * t + i) * d..(r * t + i + 1) * d];
                let te = &wte[tok * d..(tok + 1) * d];
                let pe = &wpe[i * d..(i + 1) * d];
                for ((o, &a), &p) in out.iter_mut().zip(te).zip(pe) {
                    *o = a + p;
                }
            }
        }

        let scale = 1.0 / (hd as f32).sqrt();
        for l in 0..cfg.n_layer {
            // ---- attention block (pre-LN) -----------------------------
            let xn = layer_norm(
                &x,
                self.layer("ln1_g", l, d),
                self.layer("ln1_b", l, d),
                d,
            );
            let qkv = affine(
                &xn,
                self.layer("attn_qkv_w", l, d * 3 * d),
                self.layer("attn_qkv_b", l, 3 * d),
                rows,
                d,
                3 * d,
            );
            if let Some((sess, row)) = capture.as_mut() {
                let row = *row;
                for i in 0..t {
                    for hh in 0..h {
                        let kb = sess.kv_start(l, row, hh, i);
                        let ko = i * 3 * d + d + hh * hd;
                        sess.k[kb..kb + hd].copy_from_slice(&qkv[ko..ko + hd]);
                        let vo = ko + d;
                        sess.v[kb..kb + hd].copy_from_slice(&qkv[vo..vo + hd]);
                    }
                }
            }
            let beta = self.beta_row(l);
            let gamma = self.gamma_row(l);

            let mut y = vec![0.0f32; rows * d];
            for r in 0..b {
                for hh in 0..h {
                    for i in 0..t {
                        let qoff = (r * t + i) * 3 * d + hh * hd;
                        // causal scores over keys j <= i; omitting j > i is
                        // the -inf mask (exp(-inf) = 0 in every normalizer)
                        let mut srow = Vec::with_capacity(i + 1);
                        for j in 0..=i {
                            let koff = (r * t + j) * 3 * d + d + hh * hd;
                            let mut acc = 0.0f32;
                            for e in 0..hd {
                                acc += qkv[qoff + e] * qkv[koff + e];
                            }
                            srow.push(acc * scale);
                        }
                        let probs = match cfg.normalizer.as_str() {
                            "consmax" => {
                                native::consmax_train(&srow, beta[hh], gamma[hh])
                            }
                            "softermax" => {
                                native::softermax_rows(&srow, srow.len())
                            }
                            _ => native::softmax_rows(&srow, srow.len()),
                        };
                        let ooff = (r * t + i) * d + hh * hd;
                        for (j, &pj) in probs.iter().enumerate() {
                            let voff = (r * t + j) * 3 * d + 2 * d + hh * hd;
                            for e in 0..hd {
                                y[ooff + e] += pj * qkv[voff + e];
                            }
                        }
                    }
                }
            }
            let proj = affine(
                &y,
                self.layer("attn_proj_w", l, d * d),
                self.layer("attn_proj_b", l, d),
                rows,
                d,
                d,
            );
            for (xv, pv) in x.iter_mut().zip(&proj) {
                *xv += pv;
            }

            // ---- MLP block (pre-LN) -----------------------------------
            let xn2 = layer_norm(
                &x,
                self.layer("ln2_g", l, d),
                self.layer("ln2_b", l, d),
                d,
            );
            let mut hid = affine(
                &xn2,
                self.layer("mlp_fc_w", l, d * 4 * d),
                self.layer("mlp_fc_b", l, 4 * d),
                rows,
                d,
                4 * d,
            );
            for hv in hid.iter_mut() {
                *hv = gelu(*hv);
            }
            let mo = affine(
                &hid,
                self.layer("mlp_proj_w", l, 4 * d * d),
                self.layer("mlp_proj_b", l, d),
                rows,
                4 * d,
                d,
            );
            for (xv, mv) in x.iter_mut().zip(&mo) {
                *xv += mv;
            }
        }

        let xf = layer_norm(&x, self.p("lnf_g"), self.p("lnf_b"), d);
        // tied LM head: logits = xf @ wte^T
        let src_rows: Vec<usize> = if last_only {
            (0..b).map(|r| r * t + (t - 1)).collect()
        } else {
            (0..rows).collect()
        };
        let mut logits = vec![0.0f32; src_rows.len() * v];
        for (o, &sr) in src_rows.iter().enumerate() {
            let xr = &xf[sr * d..(sr + 1) * d];
            let lr = &mut logits[o * v..(o + 1) * v];
            for (vv, ov) in lr.iter_mut().enumerate() {
                let wr = &wte[vv * d..(vv + 1) * d];
                let mut acc = 0.0f32;
                for e in 0..d {
                    acc += xr[e] * wr[e];
                }
                *ov = acc;
            }
        }
        Ok(logits)
    }

    /// Mean next-token cross-entropy over a flat (b, t) batch, matching
    /// the JAX `loss_fn` (log-softmax over the tied head).
    pub fn loss(&self, x: &[i32], y: &[i32], b: usize, t: usize) -> Result<f64> {
        ensure!(x.len() == y.len(), "x/y length mismatch");
        let logits = self.forward(x, b, t)?;
        let v = self.cfg.vocab;
        let mut total = 0.0f64;
        for (pos, &target) in y.iter().enumerate() {
            ensure!(
                (0..v as i32).contains(&target),
                "target id {target} outside vocab {v}"
            );
            let row = &logits[pos * v..(pos + 1) * v];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = m + row.iter().map(|&l| (l - m).exp()).sum::<f32>().ln();
            total += (lse - row[target as usize]) as f64;
        }
        Ok(total / y.len() as f64)
    }

    /// Next-token logits (b, vocab) for equal-length token sequences,
    /// recomputing the forward pass over a ctx-bounded trailing window —
    /// the **recompute oracle** the KV engine is validated against.
    pub fn next_logits(&self, seqs: &[Vec<i32>]) -> Result<Vec<f32>> {
        ensure!(!seqs.is_empty(), "empty decode batch");
        let len = seqs[0].len();
        ensure!(len >= 1, "empty sequences");
        ensure!(
            seqs.iter().all(|s| s.len() == len),
            "decode batch rows must share a length"
        );
        let b = seqs.len();
        let w = len.min(self.cfg.ctx);
        let mut toks = Vec::with_capacity(b * w);
        for s in seqs {
            toks.extend_from_slice(&s[len - w..]);
        }
        // last_only: (b, vocab) — decoding never reads the interior rows
        self.forward_impl(&toks, b, w, true, None)
    }

    fn check_session(&self, sess: &DecodeSession) -> Result<()> {
        ensure!(
            sess.ctx == self.cfg.ctx
                && sess.n_layer == self.cfg.n_layer
                && sess.n_head == self.cfg.n_head
                && sess.head_dim == self.cfg.head_dim(),
            "decode session geometry does not match model config {}",
            self.cfg.key
        );
        Ok(())
    }

    /// Encode each row's prompt into the session (resetting it) and
    /// return next-token logits (b, vocab). Rows may have **different
    /// lengths** — each prefills at its own true length, so no padding
    /// token is ever attended to. Prompts longer than `ctx` are clamped
    /// to their trailing window, matching [`NativeModel::next_logits`].
    pub fn prefill(
        &self,
        sess: &mut DecodeSession,
        rows: &[Vec<i32>],
    ) -> Result<Vec<f32>> {
        ensure!(
            rows.len() == sess.batch(),
            "prefill: {} rows for a session of {}",
            rows.len(),
            sess.batch()
        );
        self.check_session(sess)?;
        let v = self.cfg.vocab;
        let mut out = Vec::with_capacity(rows.len() * v);
        for (r, seq) in rows.iter().enumerate() {
            ensure!(!seq.is_empty(), "prefill: row {r} is empty");
            let w = seq.len().min(self.cfg.ctx);
            let window = &seq[seq.len() - w..];
            sess.reset_row(r, window);
            let logits = self.forward_impl(window, 1, w, true, Some((&mut *sess, r)))?;
            sess.set_len(r, w);
            out.extend_from_slice(&logits);
        }
        Ok(out)
    }

    /// Advance every row of the session by one token; returns next-token
    /// logits (b, vocab).
    pub fn decode_step(
        &self,
        sess: &mut DecodeSession,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let active = vec![true; tokens.len()];
        self.decode_step_active(sess, tokens, &active)
    }

    /// Advance the active rows of the session by one token each; returns
    /// logits (b, vocab) with inactive rows zero-filled.
    ///
    /// The common case is one O(len) incremental pass per row. A row
    /// whose cache is full (`len == ctx`) evicts its oldest token from
    /// the history ring and re-encodes the shifted window — absolute
    /// positional embeddings make the remaining cached K/V stale — which
    /// is exactly the oracle's trailing-window recompute for that step.
    pub fn decode_step_active(
        &self,
        sess: &mut DecodeSession,
        tokens: &[i32],
        active: &[bool],
    ) -> Result<Vec<f32>> {
        ensure!(
            tokens.len() == sess.batch() && active.len() == sess.batch(),
            "decode_step: {} tokens / {} active flags for a session of {}",
            tokens.len(),
            active.len(),
            sess.batch()
        );
        self.check_session(sess)?;
        let v = self.cfg.vocab;
        let ctx = self.cfg.ctx;
        let mut out = vec![0.0f32; sess.batch() * v];
        for (r, (&tok, &is_active)) in tokens.iter().zip(active).enumerate() {
            if !is_active {
                continue;
            }
            ensure!(sess.len_of(r) > 0, "decode_step on row {r} before prefill");
            ensure!(
                (0..v as i32).contains(&tok),
                "token id {tok} outside vocab {v}"
            );
            sess.push_history(r, tok);
            let row_logits = if sess.len_of(r) == ctx {
                // eviction: re-encode the shifted window from slot 0
                let window = sess.history_row(r);
                self.forward_impl(&window, 1, ctx, true, Some((&mut *sess, r)))?
            } else {
                self.decode_token(sess, r, tok)?
            };
            out[r * v..(r + 1) * v].copy_from_slice(&row_logits);
        }
        Ok(out)
    }

    /// One incremental decode pass for row `r`: append K/V for `tok` at
    /// the next cache slot and attend over the row's cached positions.
    /// Performs the same float ops in the same order as `forward_impl`,
    /// so the logits are bitwise identical to a window recompute.
    fn decode_token(
        &self,
        sess: &mut DecodeSession,
        r: usize,
        tok: i32,
    ) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, h, hd, v) = (cfg.n_embd, cfg.n_head, cfg.head_dim(), cfg.vocab);
        let pos = sess.len_of(r);
        debug_assert!(pos < cfg.ctx);

        let wte = self.p("wte");
        let wpe = self.p("wpe");
        let mut x = vec![0.0f32; d];
        {
            let te = &wte[tok as usize * d..(tok as usize + 1) * d];
            let pe = &wpe[pos * d..(pos + 1) * d];
            for ((o, &a), &p) in x.iter_mut().zip(te).zip(pe) {
                *o = a + p;
            }
        }

        let scale = 1.0 / (hd as f32).sqrt();
        for l in 0..cfg.n_layer {
            // ---- attention block (pre-LN) -----------------------------
            let xn = layer_norm(
                &x,
                self.layer("ln1_g", l, d),
                self.layer("ln1_b", l, d),
                d,
            );
            let qkv = affine(
                &xn,
                self.layer("attn_qkv_w", l, d * 3 * d),
                self.layer("attn_qkv_b", l, 3 * d),
                1,
                d,
                3 * d,
            );
            // append this token's K/V at slot `pos`
            for hh in 0..h {
                let kb = sess.kv_start(l, r, hh, pos);
                let ko = d + hh * hd;
                sess.k[kb..kb + hd].copy_from_slice(&qkv[ko..ko + hd]);
                let vo = ko + d;
                sess.v[kb..kb + hd].copy_from_slice(&qkv[vo..vo + hd]);
            }
            let beta = self.beta_row(l);
            let gamma = self.gamma_row(l);

            let mut y = vec![0.0f32; d];
            for hh in 0..h {
                let q = &qkv[hh * hd..(hh + 1) * hd];
                if cfg.normalizer == "consmax" {
                    // ConSmax has no row max/sum (the paper's point), so
                    // score → prob → PV fuses into one pass per cached
                    // key, exactly like the `op_consmax_pv` kernel.
                    let (bh, gh) = (beta[hh], gamma[hh]);
                    for j in 0..=pos {
                        let kb = sess.kv_start(l, r, hh, j);
                        let mut acc = 0.0f32;
                        for e in 0..hd {
                            acc += q[e] * sess.k[kb + e];
                        }
                        let pj = (acc * scale - bh).exp() / gh;
                        for e in 0..hd {
                            y[hh * hd + e] += pj * sess.v[kb + e];
                        }
                    }
                } else {
                    // softmax/softermax reduce over the whole row first
                    let mut srow = Vec::with_capacity(pos + 1);
                    for j in 0..=pos {
                        let kb = sess.kv_start(l, r, hh, j);
                        let mut acc = 0.0f32;
                        for e in 0..hd {
                            acc += q[e] * sess.k[kb + e];
                        }
                        srow.push(acc * scale);
                    }
                    let probs = if cfg.normalizer == "softermax" {
                        native::softermax_rows(&srow, srow.len())
                    } else {
                        native::softmax_rows(&srow, srow.len())
                    };
                    for (j, &pj) in probs.iter().enumerate() {
                        let kb = sess.kv_start(l, r, hh, j);
                        for e in 0..hd {
                            y[hh * hd + e] += pj * sess.v[kb + e];
                        }
                    }
                }
            }
            let proj = affine(
                &y,
                self.layer("attn_proj_w", l, d * d),
                self.layer("attn_proj_b", l, d),
                1,
                d,
                d,
            );
            for (xv, pv) in x.iter_mut().zip(&proj) {
                *xv += pv;
            }

            // ---- MLP block (pre-LN) -----------------------------------
            let xn2 = layer_norm(
                &x,
                self.layer("ln2_g", l, d),
                self.layer("ln2_b", l, d),
                d,
            );
            let mut hid = affine(
                &xn2,
                self.layer("mlp_fc_w", l, d * 4 * d),
                self.layer("mlp_fc_b", l, 4 * d),
                1,
                d,
                4 * d,
            );
            for hv in hid.iter_mut() {
                *hv = gelu(*hv);
            }
            let mo = affine(
                &hid,
                self.layer("mlp_proj_w", l, 4 * d * d),
                self.layer("mlp_proj_b", l, d),
                1,
                4 * d,
                d,
            );
            for (xv, mv) in x.iter_mut().zip(&mo) {
                *xv += mv;
            }
        }

        let xf = layer_norm(&x, self.p("lnf_g"), self.p("lnf_b"), d);
        let mut logits = vec![0.0f32; v];
        for (vv, ov) in logits.iter_mut().enumerate() {
            let wr = &wte[vv * d..(vv + 1) * d];
            let mut acc = 0.0f32;
            for e in 0..d {
                acc += xf[e] * wr[e];
            }
            *ov = acc;
        }
        sess.set_len(r, pos + 1);
        Ok(logits)
    }
}

fn layer_norm(x: &[f32], g: &[f32], b: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    for (row_in, row_out) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let mu = row_in.iter().sum::<f32>() / d as f32;
        let var =
            row_in.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for ((o, &v), (&gg, &bb)) in
            row_out.iter_mut().zip(row_in).zip(g.iter().zip(b))
        {
            *o = (v - mu) * inv * gg + bb;
        }
    }
    out
}

fn affine(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    din: usize,
    dout: usize,
) -> Vec<f32> {
    let mut out = native::matmul(x, w, rows, din, dout);
    for row in out.chunks_exact_mut(dout) {
        for (o, &bv) in row.iter_mut().zip(bias) {
            *o += bv;
        }
    }
    out
}

/// Tanh-approximate GELU, matching `jax.nn.gelu` (approximate=True).
fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn tiny_model(normalizer: &str) -> NativeModel {
        let cfg = ModelConfig::builtin("tiny", normalizer).unwrap();
        let mut rng = Pcg32::seeded(7);
        let mut tensors = Vec::new();
        for name in cfg.param_order.clone() {
            let shape = cfg.shape_of(&name).unwrap().to_vec();
            let n: usize = shape.iter().product();
            let vals: Vec<f32> = match name.as_str() {
                "ln1_g" | "ln2_g" | "lnf_g" => vec![1.0; n],
                "beta" => vec![1.5; n],
                "gamma" => vec![100.0; n],
                _ if name.ends_with("_b") => vec![0.0; n],
                _ => rng.normal_vec_f32(n, 0.0, 0.02),
            };
            tensors.push(HostTensor::from_f32(&vals, &shape));
        }
        NativeModel::from_params(&cfg, &cfg.param_order, &tensors).unwrap()
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        for norm in ["consmax", "softmax", "softermax"] {
            let m = tiny_model(norm);
            let toks: Vec<i32> = (0..2 * 8).map(|i| (i * 13) % 256).collect();
            let logits = m.forward(&toks, 2, 8).unwrap();
            assert_eq!(logits.len(), 2 * 8 * 256, "{norm}");
            assert!(logits.iter().all(|v| v.is_finite()), "{norm}");
        }
    }

    #[test]
    fn untrained_loss_near_uniform() {
        // near-random weights => loss close to ln(256) = 5.545
        let m = tiny_model("consmax");
        let x: Vec<i32> = (0..2 * 32).map(|i| (i * 7) % 256).collect();
        let y: Vec<i32> = (0..2 * 32).map(|i| (i * 7 + 1) % 256).collect();
        let loss = m.loss(&x, &y, 2, 32).unwrap();
        assert!((4.5..6.5).contains(&loss), "loss {loss}");
    }

    #[test]
    fn forward_is_deterministic() {
        let m = tiny_model("consmax");
        let toks: Vec<i32> = (0..16).map(|i| (i * 31) % 256).collect();
        assert_eq!(m.forward(&toks, 1, 16).unwrap(), m.forward(&toks, 1, 16).unwrap());
    }

    #[test]
    fn causality_prefix_logits_stable() {
        // logits at position i must not depend on tokens after i
        let m = tiny_model("consmax");
        let mut a: Vec<i32> = (0..12).map(|i| (i * 11) % 256).collect();
        let la = m.forward(&a, 1, 12).unwrap();
        a[11] = (a[11] + 17) % 256; // change only the last token
        let lb = m.forward(&a, 1, 12).unwrap();
        let v = m.cfg.vocab;
        // positions 0..10 identical; position 11 differs
        assert_eq!(&la[..11 * v], &lb[..11 * v]);
        assert_ne!(&la[11 * v..], &lb[11 * v..]);
    }

    #[test]
    fn next_logits_matches_forward_tail() {
        let m = tiny_model("softmax");
        let seq: Vec<i32> = (0..10).map(|i| (i * 3) % 256).collect();
        let full = m.forward(&seq, 1, 10).unwrap();
        let v = m.cfg.vocab;
        let nl = m.next_logits(&[seq]).unwrap();
        assert_eq!(nl, full[9 * v..].to_vec());
    }

    #[test]
    fn window_clamps_to_ctx() {
        let m = tiny_model("consmax");
        let long: Vec<i32> = (0..200).map(|i| i % 256).collect();
        let nl = m.next_logits(&[long]).unwrap();
        assert_eq!(nl.len(), m.cfg.vocab);
        assert!(nl.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn rejects_bad_tokens() {
        let m = tiny_model("consmax");
        assert!(m.forward(&[300], 1, 1).is_err());
        assert!(m.forward(&[-1], 1, 1).is_err());
        assert!(m.forward(&[0; 4], 2, 3).is_err()); // wrong element count
    }

    #[test]
    fn prefill_matches_next_logits() {
        for norm in ["consmax", "softmax", "softermax"] {
            let m = tiny_model(norm);
            let seq: Vec<i32> = (0..20).map(|i| (i * 5 + 3) % 256).collect();
            let mut sess = DecodeSession::new(&m.cfg, 1);
            let kv = m.prefill(&mut sess, &[seq.clone()]).unwrap();
            let oracle = m.next_logits(&[seq]).unwrap();
            assert_eq!(kv, oracle, "{norm}: prefill vs oracle");
            assert_eq!(sess.len_of(0), 20);
        }
    }

    #[test]
    fn decode_step_extends_bitwise() {
        // one incremental step == recompute over the extended sequence
        for norm in ["consmax", "softmax", "softermax"] {
            let m = tiny_model(norm);
            let mut seq: Vec<i32> = (0..9).map(|i| (i * 7 + 1) % 256).collect();
            let mut sess = DecodeSession::new(&m.cfg, 1);
            m.prefill(&mut sess, &[seq.clone()]).unwrap();
            let kv = m.decode_step(&mut sess, &[42]).unwrap();
            seq.push(42);
            let oracle = m.next_logits(&[seq]).unwrap();
            assert_eq!(kv, oracle, "{norm}: decode_step vs oracle");
        }
    }

    #[test]
    fn decode_session_misuse_rejected() {
        let m = tiny_model("consmax");
        let mut sess = DecodeSession::new(&m.cfg, 2);
        // decode before prefill
        assert!(m.decode_step(&mut sess, &[1, 2]).is_err());
        // batch-size mismatch
        assert!(m.prefill(&mut sess, &[vec![1]]).is_err());
        // empty row
        assert!(m.prefill(&mut sess, &[vec![1], vec![]]).is_err());
        // bad token id after a valid prefill
        m.prefill(&mut sess, &[vec![1, 2], vec![3]]).unwrap();
        assert!(m.decode_step(&mut sess, &[300, 0]).is_err());
    }

    #[test]
    fn inactive_rows_hold_still() {
        let m = tiny_model("consmax");
        let mut sess = DecodeSession::new(&m.cfg, 2);
        m.prefill(&mut sess, &[vec![5, 6, 7], vec![9, 9]]).unwrap();
        let v = m.cfg.vocab;
        let out = m
            .decode_step_active(&mut sess, &[1, 1], &[true, false])
            .unwrap();
        assert_eq!(sess.len_of(0), 4);
        assert_eq!(sess.len_of(1), 2); // untouched
        assert!(out[v..].iter().all(|&x| x == 0.0)); // zero-filled row
        assert!(out[..v].iter().any(|&x| x != 0.0));
    }
}
