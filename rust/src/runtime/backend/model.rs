//! Pure-Rust GPT forward pass over the paper's benchmark architecture
//! (python/compile/model.py §Forward), used by the native backend for
//! evaluation, generation and serving when no PJRT artifacts exist.
//!
//! Semantics mirror the JAX model exactly: pre-LN blocks, causal
//! attention with the configured score normalizer (the [`Normalizer`]
//! zoo: softmax | consmax | softermax | consmax-v2 | ssmax, resolved
//! once at load — DESIGN.md §Normalizer seam), tanh-approximate GELU,
//! tied LM head. ConSmax runs in its *training* form `exp(s - β)/γ`
//! with per-(layer, head) scalars — the same probabilities the
//! inference form `C·exp(s)` produces once β/γ are merged (asserted in
//! `native.rs` tests).
//!
//! The compute layer is parallel, cache-blocked and vectorized
//! (DESIGN.md §Parallel-compute seam, §SIMD-kernel seam): weight
//! matrices are pre-transposed once at load so every matmul is a
//! unit-stride [`native::matmul_bt_into`] running the SIMD lane layer's
//! [`native::dot`]; attention fans out over (batch-row × head) tiles;
//! prefill and decode fan out over batch rows; the LM head splits
//! across vocab chunks. For **ConSmax** the attention inner loop
//! streams score→C·exp→PV per key with no materialized probability row
//! — the paper's reduction-freeness carried into software, with the
//! exponential going through the seam's dispatched polynomial
//! `simd::exp` — while softmax/softermax must collect each score row
//! before normalizing. Thread count and SIMD level never change
//! results within a mode: every output element is produced by one
//! serial reduction in a fixed order
//! (`rust/tests/parallel_equivalence.rs`, `rust/tests/simd_kernels.rs`).
//!
//! Under `--quant int8` (DESIGN.md §Quantization seam) the model builds
//! per-channel symmetric int8 twins of every projection matrix and the
//! tied LM head once at load — the f32 tensors stay resident as the
//! oracle — and the ConSmax attention tail reads its probabilities out
//! of the bit-split LUT's per-(layer, head) response tables
//! ([`native::attend_consmax_lut`]), so serving probabilities are
//! bit-identical to [`crate::quant::BitSplitLut`] and the RTL sim.
//! Activations and accumulation stay f32 throughout, so thread count
//! still never changes results.
//!
//! Forward is one face of the model: the native training stack
//! (`runtime::backend::train`, DESIGN.md §Training seam) adds an
//! activation-tape `forward_train` + `backward` over the same
//! parameters, so `consmax train --backend native` reproduces Fig 6/7
//! with no PJRT. Decoding has two faces:
//!
//! * [`NativeModel::next_logits`] — the **recompute oracle**: a full
//!   forward over the ctx-bounded trailing window per step, O(T²) per
//!   generated token. Kept as the reference the KV engine is tested
//!   against (`rust/tests/decode_engine.rs`) and reachable in serving
//!   via `--decode recompute`.
//! * [`NativeModel::prefill`] + [`NativeModel::decode_step`] — the
//!   **KV-cached engine** over a [`DecodeSession`]: one O(T) incremental
//!   pass per token against per-row scratch arenas (zero heap
//!   allocations per steady-state token), per-row true lengths (no
//!   left-pad pollution), rows decoded in parallel. Both paths produce
//!   bitwise-identical logits: they run the same kernels over the same
//!   values in the same order.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::config::{ModelConfig, QuantMode};
use crate::quant::{self, BitSplitLut, Int8Quantizer, QuantizedMatrix};
use crate::runtime::backend::decode::{
    kv_offset, KvCapture, PagedParts, RowMut, RowScratch,
};
use crate::runtime::backend::kvcache::{chain_hash, KvPool, HASH_SEED};
use crate::runtime::backend::native::{self, gelu, layer_norm, layer_norm_into};
use crate::runtime::backend::normalizer::{HeadNorm, Normalizer};
use crate::runtime::backend::DecodeSession;
use crate::runtime::parallel;
use crate::runtime::HostTensor;
use crate::util::fp16::F16;

/// The stacked per-layer weight matrices that get a pre-transposed twin
/// at load time (their per-layer dims come from `n_embd`).
const TRANSPOSED: [&str; 4] =
    ["attn_qkv_w", "attn_proj_w", "mlp_fc_w", "mlp_proj_w"];

/// A model with host-resident f32 parameters, ready for forward passes.
pub struct NativeModel {
    pub cfg: ModelConfig,
    /// The score normalizer, resolved from `cfg.normalizer` exactly
    /// once at load (DESIGN.md §Normalizer seam); every attention tail
    /// and the trainer dispatch on this enum, never on the string.
    pub(crate) norm: Normalizer,
    pub(crate) params: BTreeMap<String, Vec<f32>>,
    /// The matrices in [`TRANSPOSED`], re-packed per layer as
    /// `[l, dout, din]` so every matmul streams both operands with unit
    /// stride ([`native::matmul_bt_into`]). These live *only* here —
    /// the untransposed originals are dropped from `params` at load.
    params_t: BTreeMap<String, Vec<f32>>,
    /// Serving quantization mode; `Off` keeps the f32 kernels.
    quant: QuantMode,
    /// Per-channel int8 twins of the [`TRANSPOSED`] matrices (one
    /// [`QuantizedMatrix`] per layer) plus `"wte"` (the tied LM head),
    /// built once at load under `--quant int8`. Empty when `Off`.
    params_q: BTreeMap<String, Vec<QuantizedMatrix>>,
    /// Paper-scale score quantizer feeding the LUT attention tail.
    score_quant: Int8Quantizer,
    /// ConSmax LUT response tables, one `[F16; 256]` per (layer, head)
    /// at index `l * n_head + hh`: entry `q as u8` holds
    /// `BitSplitLut::paper().consmax(q, C_lh)` with the merged constant
    /// `C_lh = exp(-β)/γ`. Empty unless consmax + int8.
    consmax_tables: Vec<[F16; 256]>,
}

/// Which logit rows an [`ExtendReq`] wants back from [`NativeModel::extend_rows`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExtendLogits {
    /// Cache writes only — no final LN, no LM head (mid-prompt chunks).
    None,
    /// Logits for the last appended position only (the final prompt
    /// chunk: these are the next-token logits the sampler needs).
    Last,
    /// Logits for **every** appended position (speculative verify: the
    /// target scores all K+1 proposal positions in one pass).
    All,
}

/// One row's batched cache-extension request: append `tokens` after the
/// row's current length, exactly as if fed one at a time through
/// `decode_step_active`, and return the logit rows `logits` asks for.
pub struct ExtendReq<'a> {
    pub slot: usize,
    pub tokens: &'a [i32],
    pub logits: ExtendLogits,
}

impl NativeModel {
    /// Build from a parameter list in canonical order (e.g. a
    /// `ParamStore`'s `order`/`params` pair), with the f32 kernels.
    pub fn from_params(
        cfg: &ModelConfig,
        order: &[String],
        tensors: &[HostTensor],
    ) -> Result<NativeModel> {
        NativeModel::from_params_quant(cfg, order, tensors, QuantMode::Off)
    }

    /// [`NativeModel::from_params`] with an explicit serving
    /// quantization mode. Under [`QuantMode::Int8`] the projection
    /// weights and LM head are quantized per output channel at load
    /// (DESIGN.md §Quantization seam) and a ConSmax model additionally
    /// materializes the bit-split LUT response tables its attention
    /// tail reads from.
    pub fn from_params_quant(
        cfg: &ModelConfig,
        order: &[String],
        tensors: &[HostTensor],
        quant: QuantMode,
    ) -> Result<NativeModel> {
        ensure!(
            order.len() == tensors.len(),
            "param order ({}) / tensor ({}) length mismatch",
            order.len(),
            tensors.len()
        );
        // the single normalizer registry (DESIGN.md §Normalizer seam):
        // config validation and model load resolve through the same parse
        let norm = Normalizer::parse(&cfg.normalizer)?;
        let mut params = BTreeMap::new();
        for (name, t) in order.iter().zip(tensors) {
            let want: usize = cfg.shape_of(name)?.iter().product();
            ensure!(
                t.elems() == want,
                "param {name}: {} elements, config wants {want}",
                t.elems()
            );
            params.insert(name.clone(), t.as_f32()?);
        }
        for required in [
            "wte", "wpe", "ln1_g", "ln1_b", "attn_qkv_w", "attn_qkv_b",
            "attn_proj_w", "attn_proj_b", "ln2_g", "ln2_b", "mlp_fc_w",
            "mlp_fc_b", "mlp_proj_w", "mlp_proj_b", "lnf_g", "lnf_b",
        ] {
            ensure!(params.contains_key(required), "missing param {required}");
        }
        for required in norm.required_params() {
            ensure!(
                params.contains_key(*required),
                "{} model needs the {required:?} param",
                norm.name()
            );
        }

        // Pre-transpose the four per-layer weight matrices once, so the
        // hot loops never touch a strided operand. (`wte` needs no twin:
        // the tied LM head wants it exactly as stored, `(vocab, d)`.)
        let d = cfg.n_embd;
        let dims = |name: &str| -> (usize, usize) {
            match name {
                "attn_qkv_w" => (d, 3 * d),
                "attn_proj_w" => (d, d),
                "mlp_fc_w" => (d, 4 * d),
                _ => (4 * d, d), // mlp_proj_w
            }
        };
        let mut params_t = BTreeMap::new();
        for name in TRANSPOSED {
            let (din, dout) = dims(name);
            // move the original out: these four matrices are only ever
            // read transposed, so keeping both copies would double the
            // model's largest weights in memory
            let src = params.remove(name).expect("validated above");
            let mut packed = Vec::with_capacity(src.len());
            for l in 0..cfg.n_layer {
                packed.extend_from_slice(&native::transpose(
                    &src[l * din * dout..(l + 1) * din * dout],
                    din,
                    dout,
                ));
            }
            params_t.insert(name.to_string(), packed);
        }

        // Int8 serving twins (DESIGN.md §Quantization seam): quantize
        // each pre-transposed projection per layer — one power-of-two
        // scale per output channel — and the tied LM head per vocab
        // row, once at load. The f32 tensors above stay resident as the
        // oracle. For ConSmax, merge each (layer, head) C = exp(-β)/γ
        // and materialize the bit-split LUT's 256-entry response table
        // so the attention tail emits exactly the hardware unit's bits.
        let mut params_q = BTreeMap::new();
        let mut consmax_tables = Vec::new();
        if quant.is_int8() {
            for name in TRANSPOSED {
                let (din, dout) = dims(name);
                let t = params_t.get(name).expect("packed above");
                let per = din * dout;
                let mats: Vec<QuantizedMatrix> = (0..cfg.n_layer)
                    .map(|l| {
                        QuantizedMatrix::from_rows(
                            &t[l * per..(l + 1) * per],
                            dout,
                            din,
                        )
                    })
                    .collect();
                params_q.insert(name.to_string(), mats);
            }
            let wte = params.get("wte").expect("validated above");
            params_q.insert(
                "wte".to_string(),
                vec![QuantizedMatrix::from_rows(wte, cfg.vocab, cfg.n_embd)],
            );
            if norm == Normalizer::Consmax {
                let lut = BitSplitLut::paper();
                let beta = params.get("beta").expect("validated above");
                let gamma = params.get("gamma").expect("validated above");
                for (&b, &g) in beta.iter().zip(gamma) {
                    consmax_tables
                        .push(lut.response_table(quant::merge_beta_gamma(b, g)));
                }
            }
        }
        Ok(NativeModel {
            cfg: cfg.clone(),
            norm,
            params,
            params_t,
            quant,
            params_q,
            score_quant: Int8Quantizer::paper(),
            consmax_tables,
        })
    }

    pub(crate) fn p(&self, name: &str) -> &[f32] {
        // presence validated in from_params
        self.params.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Per-layer slice of a stacked parameter (leading axis = layer).
    pub(crate) fn layer<'a>(
        &'a self,
        name: &str,
        l: usize,
        per: usize,
    ) -> &'a [f32] {
        &self.p(name)[l * per..(l + 1) * per]
    }

    /// Per-layer slice of a pre-transposed stacked weight.
    pub(crate) fn layer_t<'a>(
        &'a self,
        name: &str,
        l: usize,
        per: usize,
    ) -> &'a [f32] {
        let t = self.params_t.get(name).map(Vec::as_slice).unwrap_or(&[]);
        &t[l * per..(l + 1) * per]
    }

    /// Layer `l`'s per-(layer, head) β row — one scalar per head, *not*
    /// per layer (empty when the normalizer doesn't own β/γ).
    pub(crate) fn beta_row(&self, l: usize) -> &[f32] {
        if self.params.contains_key("beta") {
            self.layer("beta", l, self.cfg.n_head)
        } else {
            &[]
        }
    }

    /// Layer `l`'s per-(layer, head) γ row — one scalar per head, *not*
    /// per layer (empty when the normalizer doesn't own β/γ).
    pub(crate) fn gamma_row(&self, l: usize) -> &[f32] {
        if self.params.contains_key("gamma") {
            self.layer("gamma", l, self.cfg.n_head)
        } else {
            &[]
        }
    }

    /// Layer `l`'s per-(layer, head) SSMax scale row (empty unless the
    /// model is `ssmax`).
    pub(crate) fn ssmax_row(&self, l: usize) -> &[f32] {
        if self.params.contains_key("ssmax_s") {
            self.layer("ssmax_s", l, self.cfg.n_head)
        } else {
            &[]
        }
    }

    /// Head `hh` of layer `l`'s resolved normalizer — the dispatch unit
    /// every attention tail (and the trainer) shares.
    pub(crate) fn head_norm(&self, l: usize, hh: usize) -> HeadNorm {
        HeadNorm::from_rows(
            self.norm,
            self.beta_row(l),
            self.gamma_row(l),
            self.ssmax_row(l),
            hh,
        )
    }

    /// The serving quantization mode this model was loaded with.
    pub fn quant_mode(&self) -> QuantMode {
        self.quant
    }

    /// Layer `l`'s int8 twin of a pre-transposed weight (int8 only).
    fn layer_q(&self, name: &str, l: usize) -> &QuantizedMatrix {
        &self.params_q.get(name).expect("int8 weights built at load")[l]
    }

    /// The (layer, head) LUT response table (consmax + int8 only).
    fn consmax_table(&self, l: usize, hh: usize) -> &[F16; 256] {
        &self.consmax_tables[l * self.cfg.n_head + hh]
    }

    /// `out = x @ W^T + bias` against layer `l` of a stacked projection:
    /// the pre-transposed f32 tile kernel, or its per-channel int8 twin
    /// under `--quant int8`. Activations and accumulation are f32 either
    /// way, and every output element is still one serial reduction, so
    /// thread count never changes results.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn affine_layer(
        &self,
        x: &[f32],
        w_name: &str,
        b_name: &str,
        l: usize,
        rows: usize,
        din: usize,
        dout: usize,
        out: &mut [f32],
    ) {
        if self.quant.is_int8() {
            native::matmul_bt_i8_into(x, self.layer_q(w_name, l), rows, out);
        } else {
            native::matmul_bt_into(
                x,
                self.layer_t(w_name, l, din * dout),
                rows,
                din,
                dout,
                out,
            );
        }
        let bias = self.layer(b_name, l, dout);
        for row in out.chunks_exact_mut(dout) {
            for (o, &bv) in row.iter_mut().zip(bias) {
                *o += bv;
            }
        }
    }

    /// Tied LM head (`logits = x @ wte^T`), int8-routed like the
    /// projections under `--quant int8`.
    pub(crate) fn lm_head_into(&self, x: &[f32], rows: usize, out: &mut [f32]) {
        if self.quant.is_int8() {
            native::matmul_bt_i8_into(x, &self.params_q["wte"][0], rows, out);
        } else {
            native::matmul_bt_into(
                x,
                self.p("wte"),
                rows,
                self.cfg.n_embd,
                self.cfg.vocab,
                out,
            );
        }
    }

    /// The shared attention-tail dispatch over a contiguous (l, hh) K/V
    /// region spanning cached positions `0..=pos` — the **single site**
    /// every incremental path routes through (dense decode, paged
    /// decode-after-gather, and the chunked/speculative extensions), so
    /// all of them run the same kernels over the same values in the
    /// same order and stay bitwise interchangeable. `srow` is a
    /// `>= pos + 1` scratch row the reducing normalizers collect scores
    /// into; the streaming ConSmax family never touches it.
    #[allow(clippy::too_many_arguments)]
    fn attend_cached(
        &self,
        l: usize,
        hh: usize,
        q: &[f32],
        kreg: &[f32],
        vreg: &[f32],
        pos: usize,
        srow: &mut [f32],
        yh: &mut [f32],
    ) {
        let hd = self.cfg.head_dim();
        let scale = 1.0 / (hd as f32).sqrt();
        let hn = self.head_norm(l, hh);
        match self.norm {
            // The ConSmax family has no row max/sum (the paper's
            // point): score → p → PV streams per cached key, exactly
            // the fused loop of the batched forward. Int8 consmax reads
            // its probabilities from the (l, hh) LUT response table —
            // the hardware unit's bits — instead.
            Normalizer::Consmax if self.quant.is_int8() => {
                native::attend_consmax_lut(
                    q,
                    kreg,
                    vreg,
                    hd,
                    scale,
                    &self.score_quant,
                    self.consmax_table(l, hh),
                    yh,
                );
            }
            Normalizer::Consmax => {
                native::attend_consmax(
                    q, kreg, vreg, hd, scale, hn.beta, hn.gamma, yh,
                );
            }
            Normalizer::ConsmaxV2 => {
                native::attend_consmax2(
                    q, kreg, vreg, hd, scale, hn.beta, hn.gamma, yh,
                );
            }
            // the row-reducing normalizers collect the whole score row
            // first, into the caller's scratch buffer
            _ => {
                native::attend_scores(q, kreg, hd, scale, &mut srow[..=pos]);
                hn.normalize_row(&mut srow[..=pos]);
                native::attend_pv(&srow[..=pos], vreg, hd, yh);
            }
        }
    }

    /// Token ids (b, t) row-major → logits (b, t, vocab) row-major.
    pub fn forward(&self, tokens: &[i32], b: usize, t: usize) -> Result<Vec<f32>> {
        self.forward_impl(tokens, b, t, false, None)
    }

    /// The shared transformer trunk behind both decode faces.
    ///
    /// * `last_only` — emit logits for each row's final position only
    ///   (b, vocab), skipping the (b, t, vocab) LM-head matmul that
    ///   evaluation needs but decoding discards.
    /// * `capture` — a writable K/V target: store every layer's K/V
    ///   segments at slots `0..t` (b must be 1). This is how `prefill`
    ///   fills a dense `DecodeSession` row — or a transient buffer the
    ///   paged path encodes into pool blocks — with exactly the values
    ///   a plain forward would compute.
    fn forward_impl(
        &self,
        tokens: &[i32],
        b: usize,
        t: usize,
        last_only: bool,
        mut capture: Option<&mut KvCapture<'_>>,
    ) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, h, hd, v) = (cfg.n_embd, cfg.n_head, cfg.head_dim(), cfg.vocab);
        ensure!(tokens.len() == b * t, "token buffer is not (b={b}, t={t})");
        ensure!(t >= 1 && t <= cfg.ctx, "sequence length {t} vs ctx {}", cfg.ctx);
        if capture.is_some() {
            ensure!(b == 1, "kv capture expects a single-row forward");
        }
        for &tok in tokens {
            ensure!(
                (0..v as i32).contains(&tok),
                "token id {tok} outside vocab {v}"
            );
        }

        let wte = self.p("wte");
        let wpe = self.p("wpe");
        let rows = b * t;
        let mut x = vec![0.0f32; rows * d];
        for r in 0..b {
            for i in 0..t {
                let tok = tokens[r * t + i] as usize;
                let out = &mut x[(r * t + i) * d..(r * t + i + 1) * d];
                let te = &wte[tok * d..(tok + 1) * d];
                let pe = &wpe[i * d..(i + 1) * d];
                for ((o, &a), &p) in out.iter_mut().zip(te).zip(pe) {
                    *o = a + p;
                }
            }
        }

        let norm = self.norm;
        let scale = 1.0 / (hd as f32).sqrt();
        for l in 0..cfg.n_layer {
            // ---- attention block (pre-LN) -----------------------------
            let xn = layer_norm(
                &x,
                self.layer("ln1_g", l, d),
                self.layer("ln1_b", l, d),
                d,
            );
            let mut qkv = vec![0.0f32; rows * 3 * d];
            self.affine_layer(
                &xn,
                "attn_qkv_w",
                "attn_qkv_b",
                l,
                rows,
                d,
                3 * d,
                &mut qkv,
            );
            if let Some(cap) = capture.as_deref_mut() {
                debug_assert!(t <= cap.slots);
                for i in 0..t {
                    for hh in 0..h {
                        let kb = cap.kv_start(l, hh, i);
                        let ko = i * 3 * d + d + hh * hd;
                        cap.k[kb..kb + hd].copy_from_slice(&qkv[ko..ko + hd]);
                        let vo = ko + d;
                        cap.v[kb..kb + hd].copy_from_slice(&qkv[vo..vo + hd]);
                    }
                }
            }
            let beta = self.beta_row(l);
            let gamma = self.gamma_row(l);
            let ssm = self.ssmax_row(l);
            // int8 serving: the ConSmax tail reads its probabilities out
            // of this layer's LUT response tables — the exact bits the
            // hardware unit emits — instead of the f32 training form
            let lut_row: Option<&[[F16; 256]]> =
                if norm == Normalizer::Consmax && self.quant.is_int8() {
                    Some(&self.consmax_tables[l * h..(l + 1) * h])
                } else {
                    None
                };
            let squant = self.score_quant;

            // Causal attention, parallel over (row, head) pairs: each
            // pair owns one (t, head_dim) output tile. Omitting j > i is
            // the -inf mask (exp(-inf) = 0 in every normalizer).
            // The ConSmax family streams score→p→PV per key — no
            // probability row ever exists — while the row-reducing
            // normalizers collect each score row first.
            let mut yh = vec![0.0f32; b * h * t * hd];
            {
                let qkv = &qkv;
                parallel::par_chunks_mut(&mut yh, t * hd, |pair, tile| {
                    let (r, hh) = (pair / h, pair % h);
                    let hn = HeadNorm::from_rows(norm, beta, gamma, ssm, hh);
                    let mut srow: Vec<f32> = Vec::new();
                    for i in 0..t {
                        let qoff = (r * t + i) * 3 * d + hh * hd;
                        let q = &qkv[qoff..qoff + hd];
                        if norm.is_streaming() {
                            let table = lut_row.map(|ts| &ts[hh]);
                            for j in 0..=i {
                                let koff = (r * t + j) * 3 * d + d + hh * hd;
                                let sc =
                                    native::dot(q, &qkv[koff..koff + hd]) * scale;
                                // same per-key op order — and, via
                                // `stream_p`, the same dispatched
                                // `simd::exp`/`simd::exp2` — as the
                                // fused `attend_stream` kernel and
                                // `attend_consmax_lut`, so decode and
                                // recompute stay bitwise at any SIMD
                                // level
                                let pj = match table {
                                    Some(tab) => tab
                                        [squant.quantize(sc) as u8 as usize]
                                        .to_f32(),
                                    None => hn.stream_p(sc),
                                };
                                let yrow = &mut tile[i * hd..(i + 1) * hd];
                                let vrow = &qkv[koff + d..koff + d + hd];
                                for (o, &vv) in yrow.iter_mut().zip(vrow) {
                                    *o += pj * vv;
                                }
                            }
                        } else {
                            srow.clear();
                            for j in 0..=i {
                                let koff = (r * t + j) * 3 * d + d + hh * hd;
                                srow.push(
                                    native::dot(q, &qkv[koff..koff + hd]) * scale,
                                );
                            }
                            hn.normalize_row(&mut srow);
                            for (j, &pj) in srow.iter().enumerate() {
                                let voff = (r * t + j) * 3 * d + 2 * d + hh * hd;
                                let yrow = &mut tile[i * hd..(i + 1) * hd];
                                let vrow = &qkv[voff..voff + hd];
                                for (o, &vv) in yrow.iter_mut().zip(vrow) {
                                    *o += pj * vv;
                                }
                            }
                        }
                    }
                });
            }

            // gather the head tiles back into the (rows, d) layout
            let mut y = vec![0.0f32; rows * d];
            for r in 0..b {
                for hh in 0..h {
                    let base = (r * h + hh) * t * hd;
                    let tile = &yh[base..base + t * hd];
                    for i in 0..t {
                        let ooff = (r * t + i) * d + hh * hd;
                        y[ooff..ooff + hd]
                            .copy_from_slice(&tile[i * hd..(i + 1) * hd]);
                    }
                }
            }

            let mut proj = vec![0.0f32; rows * d];
            self.affine_layer(
                &y,
                "attn_proj_w",
                "attn_proj_b",
                l,
                rows,
                d,
                d,
                &mut proj,
            );
            for (xv, pv) in x.iter_mut().zip(&proj) {
                *xv += pv;
            }

            // ---- MLP block (pre-LN) -----------------------------------
            let xn2 = layer_norm(
                &x,
                self.layer("ln2_g", l, d),
                self.layer("ln2_b", l, d),
                d,
            );
            let mut hid = vec![0.0f32; rows * 4 * d];
            self.affine_layer(
                &xn2,
                "mlp_fc_w",
                "mlp_fc_b",
                l,
                rows,
                d,
                4 * d,
                &mut hid,
            );
            for hv in hid.iter_mut() {
                *hv = gelu(*hv);
            }
            let mut mo = vec![0.0f32; rows * d];
            self.affine_layer(
                &hid,
                "mlp_proj_w",
                "mlp_proj_b",
                l,
                rows,
                4 * d,
                d,
                &mut mo,
            );
            for (xv, mv) in x.iter_mut().zip(&mo) {
                *xv += mv;
            }
        }

        let xf = layer_norm(&x, self.p("lnf_g"), self.p("lnf_b"), d);
        // tied LM head: logits = xf @ wte^T — `wte` (vocab, d) is
        // already the transposed operand `matmul_bt` wants; the kernel
        // splits the work over rows, or vocab chunks when b == 1
        if last_only {
            let mut sel = vec![0.0f32; b * d];
            for r in 0..b {
                let sr = r * t + (t - 1);
                sel[r * d..(r + 1) * d].copy_from_slice(&xf[sr * d..(sr + 1) * d]);
            }
            let mut logits = vec![0.0f32; b * v];
            self.lm_head_into(&sel, b, &mut logits);
            Ok(logits)
        } else {
            let mut logits = vec![0.0f32; rows * v];
            self.lm_head_into(&xf, rows, &mut logits);
            Ok(logits)
        }
    }

    /// Mean next-token cross-entropy over a flat (b, t) batch, matching
    /// the JAX `loss_fn` (log-softmax over the tied head).
    pub fn loss(&self, x: &[i32], y: &[i32], b: usize, t: usize) -> Result<f64> {
        ensure!(x.len() == y.len(), "x/y length mismatch");
        let logits = self.forward(x, b, t)?;
        let v = self.cfg.vocab;
        let mut total = 0.0f64;
        for (pos, &target) in y.iter().enumerate() {
            ensure!(
                (0..v as i32).contains(&target),
                "target id {target} outside vocab {v}"
            );
            let row = &logits[pos * v..(pos + 1) * v];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = m + row.iter().map(|&l| (l - m).exp()).sum::<f32>().ln();
            total += (lse - row[target as usize]) as f64;
        }
        Ok(total / y.len() as f64)
    }

    /// Next-token logits (b, vocab) for equal-length token sequences,
    /// recomputing the forward pass over a ctx-bounded trailing window —
    /// the **recompute oracle** the KV engine is validated against.
    pub fn next_logits(&self, seqs: &[Vec<i32>]) -> Result<Vec<f32>> {
        ensure!(!seqs.is_empty(), "empty decode batch");
        let len = seqs[0].len();
        ensure!(len >= 1, "empty sequences");
        ensure!(
            seqs.iter().all(|s| s.len() == len),
            "decode batch rows must share a length"
        );
        let b = seqs.len();
        let w = len.min(self.cfg.ctx);
        let mut toks = Vec::with_capacity(b * w);
        for s in seqs {
            toks.extend_from_slice(&s[len - w..]);
        }
        // last_only: (b, vocab) — decoding never reads the interior rows
        self.forward_impl(&toks, b, w, true, None)
    }

    fn check_session(&self, sess: &DecodeSession) -> Result<()> {
        ensure!(
            sess.ctx == self.cfg.ctx
                && sess.n_layer == self.cfg.n_layer
                && sess.n_head == self.cfg.n_head
                && sess.head_dim == self.cfg.head_dim(),
            "decode session geometry does not match model config {}",
            self.cfg.key
        );
        Ok(())
    }

    /// Encode each row's prompt into the session (resetting it) and
    /// return next-token logits (b, vocab). Rows may have **different
    /// lengths** — each prefills at its own true length, so no padding
    /// token is ever attended to — and prefill **in parallel** (each row
    /// is an independent captured forward). Prompts longer than `ctx`
    /// are clamped to their trailing window, matching
    /// [`NativeModel::next_logits`].
    pub fn prefill(
        &self,
        sess: &mut DecodeSession,
        rows: &[Vec<i32>],
    ) -> Result<Vec<f32>> {
        ensure!(
            rows.len() == sess.batch(),
            "prefill: {} rows for a session of {}",
            rows.len(),
            sess.batch()
        );
        let pairs: Vec<(usize, &[i32])> = rows
            .iter()
            .enumerate()
            .map(|(r, seq)| (r, seq.as_slice()))
            .collect();
        self.prefill_rows(sess, &pairs)
    }

    /// Encode prompts into a **subset** of the session's rows — the
    /// join seam of the continuous-batching scheduler. Each `(slot,
    /// prompt)` pair resets that row and prefills it at its own length
    /// (in parallel across joiners), while every other row's cache,
    /// length and history stay untouched, so requests join a live
    /// session mid-flight without disturbing in-flight neighbors.
    /// Returns next-token logits `(pairs.len(), vocab)` in input order.
    pub fn prefill_rows(
        &self,
        sess: &mut DecodeSession,
        pairs: &[(usize, &[i32])],
    ) -> Result<Vec<f32>> {
        self.check_session(sess)?;
        let v = self.cfg.vocab;
        let mut seen = vec![false; sess.batch()];
        for &(slot, seq) in pairs {
            ensure!(
                slot < sess.batch(),
                "prefill_rows: slot {slot} out of range for a session of {}",
                sess.batch()
            );
            ensure!(!seq.is_empty(), "prefill_rows: slot {slot} got an empty prompt");
            ensure!(!seen[slot], "prefill_rows: duplicate slot {slot}");
            seen[slot] = true;
            for &tok in seq {
                ensure!(
                    (0..v as i32).contains(&tok),
                    "token id {tok} outside vocab {v}"
                );
            }
        }
        if sess.is_paged() {
            return self.prefill_rows_paged(sess, pairs);
        }
        let ctx = self.cfg.ctx;
        let mut out = vec![0.0f32; pairs.len() * v];

        struct Work<'a> {
            row: RowMut<'a>,
            logits: &'a mut [f32],
            seq: &'a [i32],
            err: Option<anyhow::Error>,
        }
        let mut views: Vec<Option<RowMut<'_>>> =
            sess.rows_mut().into_iter().map(Some).collect();
        let mut items: Vec<Work<'_>> = Vec::with_capacity(pairs.len());
        for (&(slot, seq), logits) in pairs.iter().zip(out.chunks_mut(v)) {
            let row = match views[slot].take() {
                Some(row) => row,
                None => bail!("prefill_rows: duplicate slot {slot}"),
            };
            items.push(Work { row, logits, seq, err: None });
        }
        parallel::par_items(&mut items, |_, it| {
            let w = it.seq.len().min(ctx);
            let window = &it.seq[it.seq.len() - w..];
            it.row.reset(window);
            let res = {
                let mut cap = it.row.capture();
                self.forward_impl(window, 1, w, true, Some(&mut cap))
            };
            match res {
                Ok(logits) => {
                    it.logits.copy_from_slice(&logits);
                    *it.row.len = w;
                }
                Err(e) => it.err = Some(e),
            }
        });
        if let Some(e) = items.into_iter().find_map(|it| it.err) {
            return Err(e);
        }
        Ok(out)
    }

    /// Advance every row of the session by one token; returns next-token
    /// logits (b, vocab).
    pub fn decode_step(
        &self,
        sess: &mut DecodeSession,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let active = vec![true; tokens.len()];
        self.decode_step_active(sess, tokens, &active)
    }

    /// Advance the active rows of the session by one token each — **in
    /// parallel** across rows; returns logits (b, vocab) with inactive
    /// rows zero-filled.
    ///
    /// The common case is one O(len) incremental pass per row against
    /// the row's scratch arena (no allocation in the per-row compute;
    /// the step allocates only the returned logits buffer and the O(b)
    /// row-view scaffolding). A row whose
    /// cache is full (`len == ctx`) evicts its oldest token from the
    /// history ring and re-encodes the shifted window — absolute
    /// positional embeddings make the remaining cached K/V stale — which
    /// is exactly the oracle's trailing-window recompute for that step.
    pub fn decode_step_active(
        &self,
        sess: &mut DecodeSession,
        tokens: &[i32],
        active: &[bool],
    ) -> Result<Vec<f32>> {
        ensure!(
            tokens.len() == sess.batch() && active.len() == sess.batch(),
            "decode_step: {} tokens / {} active flags for a session of {}",
            tokens.len(),
            active.len(),
            sess.batch()
        );
        self.check_session(sess)?;
        let v = self.cfg.vocab;
        let ctx = self.cfg.ctx;
        // validate everything up front so the parallel region can't
        // leave a half-mutated batch behind a mid-batch error
        for (r, (&tok, &is_active)) in tokens.iter().zip(active).enumerate() {
            if !is_active {
                continue;
            }
            ensure!(sess.len_of(r) > 0, "decode_step on row {r} before prefill");
            ensure!(
                (0..v as i32).contains(&tok),
                "token id {tok} outside vocab {v}"
            );
        }
        if sess.is_paged() {
            return self.decode_step_active_paged(sess, tokens, active);
        }
        let mut out = vec![0.0f32; sess.batch() * v];

        struct Work<'a> {
            row: RowMut<'a>,
            logits: &'a mut [f32],
            tok: i32,
            err: Option<anyhow::Error>,
        }
        let mut items: Vec<Work<'_>> = Vec::new();
        for (((row, logits), &tok), &is_active) in sess
            .rows_mut()
            .into_iter()
            .zip(out.chunks_mut(v))
            .zip(tokens)
            .zip(active)
        {
            if is_active {
                items.push(Work { row, logits, tok, err: None });
            }
        }
        parallel::par_items(&mut items, |_, it| {
            it.row.push_history(it.tok);
            if *it.row.len == ctx {
                // eviction: re-encode the shifted window from slot 0
                let window = it.row.history_vec();
                let res = {
                    let mut cap = it.row.capture();
                    self.forward_impl(&window, 1, ctx, true, Some(&mut cap))
                };
                match res {
                    Ok(logits) => it.logits.copy_from_slice(&logits),
                    Err(e) => it.err = Some(e),
                }
            } else {
                self.decode_token_into(&mut it.row, it.tok, &mut it.logits[..]);
            }
        });
        if let Some(e) = items.into_iter().find_map(|it| it.err) {
            return Err(e);
        }
        Ok(out)
    }

    /// Append a **batch of tokens** to each requested row's cache in one
    /// pass — the shared primitive behind chunked prefill (extend a
    /// partially fed prompt) and speculative verify (score K draft
    /// positions with one target step).
    ///
    /// Per row, all `m` new positions run through each layer together:
    /// one multi-row LN, one `m`-row QKV/proj/MLP matmul (amortizing the
    /// memory-bound weight streaming that dominates single-token
    /// decode), then a per-position causal attention tail over exactly
    /// the span a token-by-token feed would see. [`native::matmul_bt_into`]
    /// computes each output row as an independent serial reduction, so
    /// every row's activations — and therefore the cache writes and any
    /// returned logits — are **bitwise identical** to feeding the same
    /// tokens one at a time through `decode_step_active`. (On paged
    /// rows the staged-roundtrip contract extends this to every KV
    /// dtype: staged bits == stored bits.)
    ///
    /// Requirements per request: the row is prefilled (`len >= 1`),
    /// `tokens` is non-empty, and `len + tokens.len() <= ctx` — batched
    /// extension never evicts; the scheduler falls back to one-token
    /// steps at the context horizon.
    pub fn extend_rows(
        &self,
        sess: &mut DecodeSession,
        reqs: &[ExtendReq<'_>],
    ) -> Result<Vec<Vec<f32>>> {
        self.check_session(sess)?;
        let v = self.cfg.vocab;
        let ctx = self.cfg.ctx;
        let mut seen = vec![false; sess.batch()];
        for req in reqs {
            ensure!(
                req.slot < sess.batch(),
                "extend_rows: slot {} out of range for a session of {}",
                req.slot,
                sess.batch()
            );
            ensure!(!seen[req.slot], "extend_rows: duplicate slot {}", req.slot);
            seen[req.slot] = true;
            ensure!(
                !req.tokens.is_empty(),
                "extend_rows: slot {} got no tokens",
                req.slot
            );
            let len = sess.len_of(req.slot);
            ensure!(len >= 1, "extend_rows on row {} before prefill", req.slot);
            ensure!(
                len + req.tokens.len() <= ctx,
                "extend_rows would overflow ctx on row {}: \
                 {} cached + {} new > {}",
                req.slot,
                len,
                req.tokens.len(),
                ctx
            );
            for &tok in req.tokens {
                ensure!(
                    (0..v as i32).contains(&tok),
                    "token id {tok} outside vocab {v}"
                );
            }
        }
        let mut out: Vec<Vec<f32>> = reqs
            .iter()
            .map(|req| {
                let rows = match req.logits {
                    ExtendLogits::None => 0,
                    ExtendLogits::Last => 1,
                    ExtendLogits::All => req.tokens.len(),
                };
                vec![0.0f32; rows * v]
            })
            .collect();
        if sess.is_paged() {
            // serial per row, like every paged mutation path: block
            // allocation and the CoW resolves need the pool mutably
            for (req, o) in reqs.iter().zip(out.iter_mut()) {
                self.extend_row_paged(sess, req.slot, req.tokens, req.logits, o)?;
            }
            return Ok(out);
        }
        struct Work<'a> {
            row: RowMut<'a>,
            tokens: &'a [i32],
            logits: ExtendLogits,
            out: &'a mut [f32],
        }
        let mut views: Vec<Option<RowMut<'_>>> =
            sess.rows_mut().into_iter().map(Some).collect();
        let mut items: Vec<Work<'_>> = Vec::with_capacity(reqs.len());
        for (req, o) in reqs.iter().zip(out.iter_mut()) {
            let row = views[req.slot].take().expect("validated unique slot");
            items.push(Work {
                row,
                tokens: req.tokens,
                logits: req.logits,
                out: o,
            });
        }
        parallel::par_items(&mut items, |_, it| {
            self.extend_row_dense(&mut it.row, it.tokens, it.logits, it.out);
        });
        Ok(out)
    }

    /// Dense per-row worker for [`Self::extend_rows`] — infallible (all
    /// validation happened up front), so it can run under `par_items`.
    fn extend_row_dense(
        &self,
        row: &mut RowMut<'_>,
        tokens: &[i32],
        mode: ExtendLogits,
        out: &mut [f32],
    ) {
        let cfg = &self.cfg;
        let (d, h, hd) = (cfg.n_embd, cfg.n_head, cfg.head_dim());
        let m = tokens.len();
        let pos0 = *row.len;
        debug_assert!(pos0 >= 1 && pos0 + m <= cfg.ctx);

        let wte = self.p("wte");
        let wpe = self.p("wpe");

        // m-row activation buffers: the per-token scratch arena is sized
        // for one row, and a chunk's allocation is amortized by the
        // batched matmuls it buys
        let mut x = vec![0.0f32; m * d];
        let mut xn = vec![0.0f32; m * d];
        let mut qkv = vec![0.0f32; m * 3 * d];
        let mut y = vec![0.0f32; m * d];
        let mut proj = vec![0.0f32; m * d];
        let mut hid = vec![0.0f32; m * 4 * d];

        for (i, &tok) in tokens.iter().enumerate() {
            row.push_history(tok);
            let te = &wte[tok as usize * d..(tok as usize + 1) * d];
            let pe = &wpe[(pos0 + i) * d..(pos0 + i + 1) * d];
            for (o, (&a, &p)) in
                x[i * d..(i + 1) * d].iter_mut().zip(te.iter().zip(pe))
            {
                *o = a + p;
            }
        }

        for l in 0..cfg.n_layer {
            layer_norm_into(
                &x,
                self.layer("ln1_g", l, d),
                self.layer("ln1_b", l, d),
                d,
                &mut xn,
            );
            self.affine_layer(&xn, "attn_qkv_w", "attn_qkv_b", l, m, d, 3 * d, &mut qkv);
            // append all m positions' K/V first; the causal spans below
            // never read past their own position
            for i in 0..m {
                for hh in 0..h {
                    let kb = row.kv_start(l, hh, pos0 + i);
                    let ko = i * 3 * d + d + hh * hd;
                    row.k[kb..kb + hd].copy_from_slice(&qkv[ko..ko + hd]);
                    let vo = ko + d;
                    row.v[kb..kb + hd].copy_from_slice(&qkv[vo..vo + hd]);
                }
            }
            y.fill(0.0);
            for i in 0..m {
                let pos = pos0 + i;
                for hh in 0..h {
                    let qo = i * 3 * d + hh * hd;
                    let q = &qkv[qo..qo + hd];
                    let base = row.kv_start(l, hh, 0);
                    let span = (pos + 1) * hd;
                    let kreg = &row.k[base..base + span];
                    let vreg = &row.v[base..base + span];
                    let yh = &mut y[i * d + hh * hd..i * d + (hh + 1) * hd];
                    self.attend_cached(
                        l,
                        hh,
                        q,
                        kreg,
                        vreg,
                        pos,
                        &mut row.scratch.srow,
                        yh,
                    );
                }
            }
            self.affine_layer(&y, "attn_proj_w", "attn_proj_b", l, m, d, d, &mut proj);
            for (xv, pv) in x.iter_mut().zip(proj.iter()) {
                *xv += pv;
            }
            layer_norm_into(
                &x,
                self.layer("ln2_g", l, d),
                self.layer("ln2_b", l, d),
                d,
                &mut xn,
            );
            self.affine_layer(&xn, "mlp_fc_w", "mlp_fc_b", l, m, d, 4 * d, &mut hid);
            for hv in hid.iter_mut() {
                *hv = gelu(*hv);
            }
            self.affine_layer(&hid, "mlp_proj_w", "mlp_proj_b", l, m, 4 * d, d, &mut proj);
            for (xv, pv) in x.iter_mut().zip(proj.iter()) {
                *xv += pv;
            }
        }

        match mode {
            ExtendLogits::None => {}
            ExtendLogits::Last => {
                let lastx = &x[(m - 1) * d..m * d];
                let mut ln = vec![0.0f32; d];
                layer_norm_into(lastx, self.p("lnf_g"), self.p("lnf_b"), d, &mut ln);
                self.lm_head_into(&ln, 1, out);
            }
            ExtendLogits::All => {
                layer_norm_into(&x, self.p("lnf_g"), self.p("lnf_b"), d, &mut xn);
                self.lm_head_into(&xn, m, out);
            }
        }
        *row.len = pos0 + m;
    }

    /// Paged per-row worker for [`Self::extend_rows`]: resolve all `m`
    /// write-target blocks up front (alloc at boundaries, CoW-privatize
    /// a shared mid-block landing spot), run the batched layer pass with
    /// the new K/V *staged* through the pool dtype, then commit — the
    /// same stage/attend/commit discipline as `decode_token_paged`, once
    /// per chunk instead of once per token (and one gather/dequant of
    /// the cached prefix per head instead of m).
    ///
    /// Freshly filled extension blocks are deliberately **not**
    /// registered in the prefix registry: decode-time blocks were never
    /// shareable on the token-by-token path either, and speculative
    /// rollback must be able to pop them without touching the registry.
    /// The cost is that a chunk-fed *prompt* tail doesn't publish its
    /// full blocks for CoW reuse — prefix sharing still covers the
    /// first-chunk window, which `prefill_rows` registers as before.
    fn extend_row_paged(
        &self,
        sess: &mut DecodeSession,
        slot: usize,
        tokens: &[i32],
        mode: ExtendLogits,
        out: &mut [f32],
    ) -> Result<()> {
        let cfg = &self.cfg;
        let (d, h, hd) = (cfg.n_embd, cfg.n_head, cfg.head_dim());
        let m = tokens.len();

        let parts = sess.paged_parts().expect("paged extend on a dense session");
        let PagedParts { pool, tables, len, history, scratch } = parts;
        let bt = pool.block_tokens();
        let dtype = pool.dtype();
        let pos0 = len[slot];
        debug_assert!(pos0 >= 1 && pos0 + m <= cfg.ctx);

        // -- resolve write targets for every appended position up front;
        //    on exhaustion undo this call's own allocations and bail (the
        //    scheduler budgets `paged_extend_demand` beforehand, so this
        //    is a backstop, not a steady state)
        {
            let table = &mut tables[slot];
            let appended0 = table.len();
            for i in 0..m {
                let pos = pos0 + i;
                if pos == table.len() * bt {
                    match pool.alloc() {
                        Some(blk) => table.push(blk),
                        None => {
                            while table.len() > appended0 {
                                let blk = table.pop().expect("just appended");
                                pool.release(blk);
                            }
                            bail!(
                                "kv pool exhausted mid-extension ({} free \
                                 blocks); the scheduler must budget \
                                 paged_extend_demand first",
                                pool.free_blocks()
                            );
                        }
                    }
                } else if i == 0 {
                    // only the first position can land mid-block in a
                    // pre-existing (possibly shared) block; later in-chunk
                    // positions continue a block this call just allocated
                    let bi = pos / bt;
                    if pool.is_shared(table[bi]) {
                        let Some(blk) = pool.make_private(table[bi]) else {
                            bail!("kv pool exhausted resolving copy-on-write");
                        };
                        table[bi] = blk;
                    }
                }
            }
        }

        // pos0 + m <= ctx, so the history ring never wraps here
        for &tok in tokens {
            history[slot].push_back(tok);
        }

        let wte = self.p("wte");
        let wpe = self.p("wpe");
        let lanes = cfg.n_layer * h * hd;
        // staged K/V for all m new positions — per-token
        // `[n_layer * n_head, head_dim]` lanes round-tripped through the
        // pool dtype (staged bits == stored bits)
        let mut staged_k = vec![0.0f32; m * lanes];
        let mut staged_v = vec![0.0f32; m * lanes];

        let mut x = vec![0.0f32; m * d];
        let mut xn = vec![0.0f32; m * d];
        let mut qkv = vec![0.0f32; m * 3 * d];
        let mut y = vec![0.0f32; m * d];
        let mut proj = vec![0.0f32; m * d];
        let mut hid = vec![0.0f32; m * 4 * d];

        for (i, &tok) in tokens.iter().enumerate() {
            let te = &wte[tok as usize * d..(tok as usize + 1) * d];
            let pe = &wpe[(pos0 + i) * d..(pos0 + i + 1) * d];
            for (o, (&a, &p)) in
                x[i * d..(i + 1) * d].iter_mut().zip(te.iter().zip(pe))
            {
                *o = a + p;
            }
        }

        let table: &[u32] = &tables[slot];
        let sc = &mut scratch[slot];
        for l in 0..cfg.n_layer {
            layer_norm_into(
                &x,
                self.layer("ln1_g", l, d),
                self.layer("ln1_b", l, d),
                d,
                &mut xn,
            );
            self.affine_layer(&xn, "attn_qkv_w", "attn_qkv_b", l, m, d, 3 * d, &mut qkv);
            for i in 0..m {
                for hh in 0..h {
                    let lane = i * lanes + (l * h + hh) * hd;
                    let ko = i * 3 * d + d + hh * hd;
                    let vo = ko + d;
                    staged_k[lane..lane + hd].copy_from_slice(&qkv[ko..ko + hd]);
                    staged_v[lane..lane + hd].copy_from_slice(&qkv[vo..vo + hd]);
                    dtype.roundtrip_vec(&mut staged_k[lane..lane + hd]);
                    dtype.roundtrip_vec(&mut staged_v[lane..lane + hd]);
                }
            }
            y.fill(0.0);
            for hh in 0..h {
                // gather/dequant the cached (l, hh) prefix once per head,
                // then place each new position's staged lane and attend
                // its causal span — same kernels, same bits, one gather
                // instead of m
                let mut t0 = 0usize;
                for &blk in table {
                    if t0 >= pos0 {
                        break;
                    }
                    let n = (pos0 - t0).min(bt);
                    pool.read_k(blk, l, hh, 0, n, &mut sc.kgath[t0 * hd..(t0 + n) * hd]);
                    pool.read_v(blk, l, hh, 0, n, &mut sc.vgath[t0 * hd..(t0 + n) * hd]);
                    t0 += n;
                }
                debug_assert_eq!(t0, pos0);
                for i in 0..m {
                    let pos = pos0 + i;
                    let lane = i * lanes + (l * h + hh) * hd;
                    sc.kgath[pos * hd..(pos + 1) * hd]
                        .copy_from_slice(&staged_k[lane..lane + hd]);
                    sc.vgath[pos * hd..(pos + 1) * hd]
                        .copy_from_slice(&staged_v[lane..lane + hd]);
                    let qo = i * 3 * d + hh * hd;
                    let q = &qkv[qo..qo + hd];
                    let span = (pos + 1) * hd;
                    let yh = &mut y[i * d + hh * hd..i * d + (hh + 1) * hd];
                    let (kg, vg, sr) =
                        (&sc.kgath[..span], &sc.vgath[..span], &mut sc.srow);
                    self.attend_cached(l, hh, q, kg, vg, pos, sr, yh);
                }
            }
            self.affine_layer(&y, "attn_proj_w", "attn_proj_b", l, m, d, d, &mut proj);
            for (xv, pv) in x.iter_mut().zip(proj.iter()) {
                *xv += pv;
            }
            layer_norm_into(
                &x,
                self.layer("ln2_g", l, d),
                self.layer("ln2_b", l, d),
                d,
                &mut xn,
            );
            self.affine_layer(&xn, "mlp_fc_w", "mlp_fc_b", l, m, d, 4 * d, &mut hid);
            for hv in hid.iter_mut() {
                *hv = gelu(*hv);
            }
            self.affine_layer(&hid, "mlp_proj_w", "mlp_proj_b", l, m, 4 * d, d, &mut proj);
            for (xv, pv) in x.iter_mut().zip(proj.iter()) {
                *xv += pv;
            }
        }

        match mode {
            ExtendLogits::None => {}
            ExtendLogits::Last => {
                let lastx = &x[(m - 1) * d..m * d];
                let mut ln = vec![0.0f32; d];
                layer_norm_into(lastx, self.p("lnf_g"), self.p("lnf_b"), d, &mut ln);
                self.lm_head_into(&ln, 1, out);
            }
            ExtendLogits::All => {
                layer_norm_into(&x, self.p("lnf_g"), self.p("lnf_b"), d, &mut xn);
                self.lm_head_into(&xn, m, out);
            }
        }

        // -- commit the staged K/V into the resolved blocks
        for i in 0..m {
            let pos = pos0 + i;
            pool.write_token(
                table[pos / bt],
                pos % bt,
                &staged_k[i * lanes..(i + 1) * lanes],
                &staged_v[i * lanes..(i + 1) * lanes],
            );
        }
        len[slot] = pos0 + m;
        Ok(())
    }

    /// One incremental decode pass for a session row: append K/V for
    /// `tok` at the next cache slot and attend over the row's cached
    /// positions, entirely against the row's pre-sized scratch arena —
    /// no heap allocation anywhere on this path. Performs the same float
    /// ops in the same order as `forward_impl`, so the logits are
    /// bitwise identical to a window recompute.
    fn decode_token_into(&self, row: &mut RowMut<'_>, tok: i32, out: &mut [f32]) {
        let cfg = &self.cfg;
        let (d, h, hd, v) = (cfg.n_embd, cfg.n_head, cfg.head_dim(), cfg.vocab);
        let ctx = cfg.ctx;
        let pos = *row.len;
        debug_assert!(pos < ctx);
        debug_assert_eq!(out.len(), v);

        let wte = self.p("wte");
        let wpe = self.p("wpe");

        let s = &mut *row.scratch;
        {
            let te = &wte[tok as usize * d..(tok as usize + 1) * d];
            let pe = &wpe[pos * d..(pos + 1) * d];
            for ((o, &a), &p) in s.x.iter_mut().zip(te).zip(pe) {
                *o = a + p;
            }
        }

        for l in 0..cfg.n_layer {
            // ---- attention block (pre-LN) -----------------------------
            layer_norm_into(
                &s.x,
                self.layer("ln1_g", l, d),
                self.layer("ln1_b", l, d),
                d,
                &mut s.xn,
            );
            self.affine_layer(
                &s.xn,
                "attn_qkv_w",
                "attn_qkv_b",
                l,
                1,
                d,
                3 * d,
                &mut s.qkv,
            );
            // append this token's K/V at slot `pos`
            for hh in 0..h {
                let kb = kv_offset(h, ctx, hd, l, hh, pos);
                let ko = d + hh * hd;
                row.k[kb..kb + hd].copy_from_slice(&s.qkv[ko..ko + hd]);
                let vo = ko + d;
                row.v[kb..kb + hd].copy_from_slice(&s.qkv[vo..vo + hd]);
            }
            s.y.fill(0.0);
            for hh in 0..h {
                let q = &s.qkv[hh * hd..(hh + 1) * hd];
                // a dense row's (l, hh) slots are one contiguous
                // [ctx, hd] run, so the shared attention-tail kernels
                // (also the paged path's post-gather kernels) stream it
                // directly — same float ops, same order as ever
                let base = kv_offset(h, ctx, hd, l, hh, 0);
                let span = (pos + 1) * hd;
                let kreg = &row.k[base..base + span];
                let vreg = &row.v[base..base + span];
                let yh = &mut s.y[hh * hd..(hh + 1) * hd];
                self.attend_cached(l, hh, q, kreg, vreg, pos, &mut s.srow, yh);
            }
            self.affine_layer(
                &s.y,
                "attn_proj_w",
                "attn_proj_b",
                l,
                1,
                d,
                d,
                &mut s.proj,
            );
            for (xv, pv) in s.x.iter_mut().zip(s.proj.iter()) {
                *xv += pv;
            }

            // ---- MLP block (pre-LN) -----------------------------------
            layer_norm_into(
                &s.x,
                self.layer("ln2_g", l, d),
                self.layer("ln2_b", l, d),
                d,
                &mut s.xn,
            );
            self.affine_layer(
                &s.xn,
                "mlp_fc_w",
                "mlp_fc_b",
                l,
                1,
                d,
                4 * d,
                &mut s.hid,
            );
            for hv in s.hid.iter_mut() {
                *hv = gelu(*hv);
            }
            self.affine_layer(
                &s.hid,
                "mlp_proj_w",
                "mlp_proj_b",
                l,
                1,
                4 * d,
                d,
                &mut s.proj,
            );
            for (xv, mv) in s.x.iter_mut().zip(s.proj.iter()) {
                *xv += mv;
            }
        }

        layer_norm_into(&s.x, self.p("lnf_g"), self.p("lnf_b"), d, &mut s.xn);
        // vocab-chunked LM head straight into the caller's logits row
        self.lm_head_into(&s.xn, 1, out);
        *row.len = pos + 1;
    }

    // -----------------------------------------------------------------
    // paged engine (DESIGN.md §KV-memory seam)
    //
    // The paged twins of prefill/decode keep the public API unchanged —
    // `prefill_rows` / `decode_step_active` dispatch on the session's
    // backing — and are pinned bitwise-identical to the dense oracle at
    // f32 storage (`rust/tests/kvcache_paged.rs`).
    // -----------------------------------------------------------------

    /// Paged twin of [`NativeModel::prefill_rows`]. Rows prefill
    /// serially (each captured forward still fans out internally), so a
    /// prompt's full blocks — registered under their prefix chain hash
    /// as they fill — are immediately shareable by the *next* row of
    /// the same call: identical prefixes are prefilled once.
    fn prefill_rows_paged(
        &self,
        sess: &mut DecodeSession,
        pairs: &[(usize, &[i32])],
    ) -> Result<Vec<f32>> {
        let v = self.cfg.vocab;
        let ctx = self.cfg.ctx;
        let mut out = vec![0.0f32; pairs.len() * v];
        for (&(slot, seq), logits) in pairs.iter().zip(out.chunks_mut(v)) {
            let w = seq.len().min(ctx);
            let window = &seq[seq.len() - w..];
            self.prefill_row_paged(sess, slot, window, logits)?;
        }
        Ok(out)
    }

    /// Prefill one paged row over `window` (1..=ctx tokens): retain
    /// hash-matched full prefix blocks (refcounted sharing), then either
    /// capture-forward the whole window (cold) or extend the shared
    /// prefix token-by-token through the incremental kernel (warm) —
    /// extension is bitwise the recompute forward, so both paths emit
    /// the exact dense-prefill logits at f32 storage.
    fn prefill_row_paged(
        &self,
        sess: &mut DecodeSession,
        slot: usize,
        window: &[i32],
        out: &mut [f32],
    ) -> Result<()> {
        let w = window.len();
        debug_assert!(w >= 1 && w <= self.cfg.ctx);
        sess.reset_row(slot);

        let parts = sess.paged_parts().expect("paged prefill on a dense session");
        let PagedParts { pool, tables, len, history, scratch } = parts;
        let bt = pool.block_tokens();

        history[slot].clear();
        history[slot].extend(window.iter().copied());

        // chain hash at every full-block boundary of the window: K/V at
        // position i depend on all tokens <= i, so the chained prefix
        // hash is exactly a full block's content key
        let full = w / bt;
        let mut hashes = Vec::with_capacity(full);
        let mut h = HASH_SEED;
        for chunk in window.chunks_exact(bt) {
            h = chain_hash(h, chunk);
            hashes.push(h);
        }
        debug_assert_eq!(hashes.len(), full);

        // longest run of already-resident prefix blocks; always leave
        // at least one window token to compute so prefill emits logits
        let cap = if w % bt == 0 { full.saturating_sub(1) } else { full };
        let table = &mut tables[slot];
        for &hsh in hashes.iter().take(cap) {
            match pool.lookup(hsh) {
                Some(blk) => {
                    pool.retain(blk);
                    table.push(blk);
                }
                None => break,
            }
        }
        let shared = table.len() * bt;

        if shared == 0 {
            // cold path: one captured batch forward over the window,
            // encoded into freshly allocated blocks afterwards
            let hd = self.cfg.head_dim();
            let elems = self.cfg.n_layer * self.cfg.n_head * w * hd;
            let mut tk = vec![0.0f32; elems];
            let mut tv = vec![0.0f32; elems];
            let logits = {
                let mut cap_buf = KvCapture {
                    n_head: self.cfg.n_head,
                    head_dim: hd,
                    slots: w,
                    k: &mut tk,
                    v: &mut tv,
                };
                self.forward_impl(window, 1, w, true, Some(&mut cap_buf))?
            };
            out.copy_from_slice(&logits);
            for _ in 0..pool.blocks_for(w) {
                let Some(blk) = pool.alloc() else {
                    bail!(
                        "kv pool exhausted during prefill ({} free blocks); \
                         the scheduler must admit by free blocks",
                        pool.free_blocks()
                    );
                };
                table.push(blk);
            }
            pool.write_capture(table, w, &tk, &tv);
            for (i, &hsh) in hashes.iter().enumerate() {
                pool.register(table[i], hsh);
            }
            len[slot] = w;
        } else {
            // warm path: the shared prefix is already cached; extend it
            // one token at a time through the incremental kernel
            len[slot] = shared;
            for (off, &tok) in window[shared..].iter().enumerate() {
                let pos = shared + off;
                if pos == table.len() * bt {
                    let Some(blk) = pool.alloc() else {
                        bail!("kv pool exhausted during prefill");
                    };
                    table.push(blk);
                }
                // only the last window token's logits are the prefill
                // output; earlier extension tokens skip the LM head
                let want = if pos + 1 == w { Some(&mut *out) } else { None };
                self.decode_token_paged(
                    pool,
                    table,
                    &mut scratch[slot],
                    tok,
                    pos,
                    want,
                );
                let sc = &scratch[slot];
                pool.write_token(
                    table[pos / bt],
                    pos % bt,
                    &sc.staged_k,
                    &sc.staged_v,
                );
                len[slot] = pos + 1;
                // a block that just filled becomes shareable
                if (pos + 1) % bt == 0 {
                    let bi = pos / bt;
                    if bi < hashes.len() {
                        pool.register(table[bi], hashes[bi]);
                    }
                }
            }
        }
        Ok(())
    }

    /// Paged twin of the dense step, in four phases: (1, serial) push
    /// history and resolve each active row's write-target block —
    /// allocate on a block boundary, CoW-privatize a shared target;
    /// (2, serial) window re-encode for rows at `ctx`; (3, parallel)
    /// one incremental pass per remaining row against the **read-only**
    /// shared pool, staging each row's new K/V in its scratch;
    /// (4, serial) encode the staged K/V into the pool and bump
    /// lengths. The scheduler guarantees phase 1 cannot run out of
    /// blocks by preempting until `paged_step_demand` fits.
    fn decode_step_active_paged(
        &self,
        sess: &mut DecodeSession,
        tokens: &[i32],
        active: &[bool],
    ) -> Result<Vec<f32>> {
        let v = self.cfg.vocab;
        let ctx = self.cfg.ctx;
        let b = sess.batch();
        let mut out = vec![0.0f32; b * v];

        // -- phase 1 (serial): history + write-target resolution ------
        let mut evict = vec![false; b];
        let mut step = vec![false; b];
        {
            let parts =
                sess.paged_parts().expect("paged step on a dense session");
            let PagedParts { pool, tables, len, history, .. } = parts;
            let bt = pool.block_tokens();
            for r in 0..b {
                if !active[r] {
                    continue;
                }
                let hist = &mut history[r];
                if hist.len() == ctx {
                    hist.pop_front();
                }
                hist.push_back(tokens[r]);
                if len[r] == ctx {
                    evict[r] = true;
                    continue;
                }
                let pos = len[r];
                let table = &mut tables[r];
                if pos == table.len() * bt {
                    let Some(blk) = pool.alloc() else {
                        bail!(
                            "kv pool exhausted mid-step ({} free blocks); \
                             the scheduler must preempt by \
                             paged_step_demand first",
                            pool.free_blocks()
                        );
                    };
                    table.push(blk);
                } else {
                    let bi = pos / bt;
                    if pool.is_shared(table[bi]) {
                        let Some(blk) = pool.make_private(table[bi]) else {
                            bail!("kv pool exhausted resolving copy-on-write");
                        };
                        table[bi] = blk;
                    }
                }
                step[r] = true;
            }
        }

        // -- phase 2 (serial): window re-encode for rows at ctx -------
        for r in 0..b {
            if evict[r] {
                self.reencode_window_paged(
                    sess,
                    r,
                    &mut out[r * v..(r + 1) * v],
                )?;
            }
        }

        // -- phase 3 (parallel): incremental pass, pool read-only -----
        {
            let parts =
                sess.paged_parts().expect("paged step on a dense session");
            let PagedParts { pool, tables, len, scratch, .. } = parts;
            let pool: &KvPool = pool;
            let tables: &[Vec<u32>] = tables;
            struct Work<'a> {
                table: &'a [u32],
                scratch: &'a mut RowScratch,
                logits: &'a mut [f32],
                tok: i32,
                pos: usize,
            }
            let mut items: Vec<Work<'_>> = Vec::new();
            let mut logit_rows: Vec<Option<&mut [f32]>> =
                out.chunks_mut(v).map(Some).collect();
            for (r, sc) in scratch.iter_mut().enumerate() {
                if !step[r] {
                    continue;
                }
                items.push(Work {
                    table: &tables[r],
                    scratch: sc,
                    logits: logit_rows[r].take().expect("one logits row"),
                    tok: tokens[r],
                    pos: len[r],
                });
            }
            parallel::par_items(&mut items, |_, it| {
                self.decode_token_paged(
                    pool,
                    it.table,
                    it.scratch,
                    it.tok,
                    it.pos,
                    Some(&mut *it.logits),
                );
            });
        }

        // -- phase 4 (serial): encode staged K/V, bump lengths --------
        {
            let parts =
                sess.paged_parts().expect("paged step on a dense session");
            let PagedParts { pool, tables, len, scratch, .. } = parts;
            let bt = pool.block_tokens();
            for r in 0..b {
                if !step[r] {
                    continue;
                }
                let pos = len[r];
                let sc = &scratch[r];
                pool.write_token(
                    tables[r][pos / bt],
                    pos % bt,
                    &sc.staged_k,
                    &sc.staged_v,
                );
                len[r] = pos + 1;
            }
        }
        Ok(out)
    }

    /// Window re-encode for a full paged row (the eviction path):
    /// recompute the shifted window with a captured forward — exactly
    /// the oracle's trailing-window recompute — then re-encode it over
    /// the row's blocks, CoW-privatizing any still-shared block and
    /// dropping stale registry entries before the in-place overwrite
    /// (frees and re-acquires exactly the shared ones).
    fn reencode_window_paged(
        &self,
        sess: &mut DecodeSession,
        r: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let ctx = self.cfg.ctx;
        let hd = self.cfg.head_dim();
        let window: Vec<i32> = {
            let parts =
                sess.paged_parts().expect("paged re-encode on a dense session");
            parts.history[r].iter().copied().collect()
        };
        ensure!(window.len() == ctx, "re-encode window must span ctx");
        let elems = self.cfg.n_layer * self.cfg.n_head * ctx * hd;
        let mut tk = vec![0.0f32; elems];
        let mut tv = vec![0.0f32; elems];
        let logits = {
            let mut cap = KvCapture {
                n_head: self.cfg.n_head,
                head_dim: hd,
                slots: ctx,
                k: &mut tk,
                v: &mut tv,
            };
            self.forward_impl(&window, 1, ctx, true, Some(&mut cap))?
        };
        out.copy_from_slice(&logits);

        let parts =
            sess.paged_parts().expect("paged re-encode on a dense session");
        let PagedParts { pool, tables, len, .. } = parts;
        let table = &mut tables[r];
        for slot in table.iter_mut() {
            let blk = *slot;
            if pool.is_shared(blk) {
                // about to be fully overwritten: move ownership to a
                // fresh block without copying the shared contents
                let Some(fresh) = pool.rehome(blk) else {
                    bail!("kv pool exhausted during window re-encode");
                };
                *slot = fresh;
            } else {
                // contents are about to change: drop the stale entry
                pool.unregister(blk);
            }
        }
        pool.write_capture(table, ctx, &tk, &tv);
        len[r] = ctx;
        Ok(())
    }

    /// One incremental decode pass for a paged row. The new token's K/V
    /// are *staged* in the row's scratch — round-tripped through the
    /// pool dtype so this step's attention reads exactly what later
    /// steps will read back from storage — and the attention tail runs
    /// a **gather/dequant-per-block inner loop**: each (layer, head)
    /// tile of each table block is decoded once into the row's gather
    /// buffers, then the same [`native::attend_consmax`] /
    /// [`native::attend_scores`] / [`native::attend_pv`] kernels as the
    /// dense path stream the contiguous region (f32 storage ⇒ bitwise
    /// the dense logits). Reads the pool immutably — the parallel phase
    /// shares it across rows; the caller commits the staged K/V.
    ///
    /// `out = None` skips the LM head (final LN + the d×vocab matmul,
    /// the largest matmul of a decode step): warm prefill only needs
    /// the cache writes for every window token but the last.
    fn decode_token_paged(
        &self,
        pool: &KvPool,
        table: &[u32],
        scratch: &mut RowScratch,
        tok: i32,
        pos: usize,
        out: Option<&mut [f32]>,
    ) {
        let cfg = &self.cfg;
        let (d, h, hd, v) = (cfg.n_embd, cfg.n_head, cfg.head_dim(), cfg.vocab);
        debug_assert!(pos < cfg.ctx);
        debug_assert!(table.len() * pool.block_tokens() > pos);

        let wte = self.p("wte");
        let wpe = self.p("wpe");
        let bt = pool.block_tokens();
        let dtype = pool.dtype();

        let s = &mut *scratch;
        {
            let te = &wte[tok as usize * d..(tok as usize + 1) * d];
            let pe = &wpe[pos * d..(pos + 1) * d];
            for ((o, &a), &p) in s.x.iter_mut().zip(te).zip(pe) {
                *o = a + p;
            }
        }

        for l in 0..cfg.n_layer {
            // ---- attention block (pre-LN) -----------------------------
            layer_norm_into(
                &s.x,
                self.layer("ln1_g", l, d),
                self.layer("ln1_b", l, d),
                d,
                &mut s.xn,
            );
            self.affine_layer(
                &s.xn,
                "attn_qkv_w",
                "attn_qkv_b",
                l,
                1,
                d,
                3 * d,
                &mut s.qkv,
            );
            // stage this token's K/V for every head, round-tripped
            // through the storage dtype per head_dim vector (f32:
            // bit-identical; int8: the same per-vector scale fit the
            // pool applies at encode, so staged bits == stored bits)
            for hh in 0..h {
                let lane = (l * h + hh) * hd;
                let ko = d + hh * hd;
                let vo = ko + d;
                s.staged_k[lane..lane + hd]
                    .copy_from_slice(&s.qkv[ko..ko + hd]);
                s.staged_v[lane..lane + hd]
                    .copy_from_slice(&s.qkv[vo..vo + hd]);
                dtype.roundtrip_vec(&mut s.staged_k[lane..lane + hd]);
                dtype.roundtrip_vec(&mut s.staged_v[lane..lane + hd]);
            }
            s.y.fill(0.0);
            for hh in 0..h {
                // gather/dequant the cached (l, hh) tiles block by block
                let mut t0 = 0usize;
                for &blk in table {
                    if t0 >= pos {
                        break;
                    }
                    let n = (pos - t0).min(bt);
                    pool.read_k(
                        blk,
                        l,
                        hh,
                        0,
                        n,
                        &mut s.kgath[t0 * hd..(t0 + n) * hd],
                    );
                    pool.read_v(
                        blk,
                        l,
                        hh,
                        0,
                        n,
                        &mut s.vgath[t0 * hd..(t0 + n) * hd],
                    );
                    t0 += n;
                }
                debug_assert_eq!(t0, pos);
                // the new token's staged K/V occupy slot `pos`
                let lane = (l * h + hh) * hd;
                s.kgath[pos * hd..(pos + 1) * hd]
                    .copy_from_slice(&s.staged_k[lane..lane + hd]);
                s.vgath[pos * hd..(pos + 1) * hd]
                    .copy_from_slice(&s.staged_v[lane..lane + hd]);

                let q = &s.qkv[hh * hd..(hh + 1) * hd];
                let span = (pos + 1) * hd;
                let yh = &mut s.y[hh * hd..(hh + 1) * hd];
                // split-borrow srow away from kgath/vgath for the helper
                let (kg, vg, sr) = (&s.kgath[..span], &s.vgath[..span], &mut s.srow);
                self.attend_cached(l, hh, q, kg, vg, pos, sr, yh);
            }
            self.affine_layer(
                &s.y,
                "attn_proj_w",
                "attn_proj_b",
                l,
                1,
                d,
                d,
                &mut s.proj,
            );
            for (xv, pv) in s.x.iter_mut().zip(s.proj.iter()) {
                *xv += pv;
            }

            // ---- MLP block (pre-LN) -----------------------------------
            layer_norm_into(
                &s.x,
                self.layer("ln2_g", l, d),
                self.layer("ln2_b", l, d),
                d,
                &mut s.xn,
            );
            self.affine_layer(
                &s.xn,
                "mlp_fc_w",
                "mlp_fc_b",
                l,
                1,
                d,
                4 * d,
                &mut s.hid,
            );
            for hv in s.hid.iter_mut() {
                *hv = gelu(*hv);
            }
            self.affine_layer(
                &s.hid,
                "mlp_proj_w",
                "mlp_proj_b",
                l,
                1,
                4 * d,
                d,
                &mut s.proj,
            );
            for (xv, mv) in s.x.iter_mut().zip(s.proj.iter()) {
                *xv += mv;
            }
        }

        if let Some(out) = out {
            debug_assert_eq!(out.len(), v);
            layer_norm_into(&s.x, self.p("lnf_g"), self.p("lnf_b"), d, &mut s.xn);
            self.lm_head_into(&s.xn, 1, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    const NORMALIZERS: [&str; 5] =
        ["consmax", "softmax", "softermax", "consmax-v2", "ssmax"];

    fn tiny_tensors(cfg: &ModelConfig) -> Vec<HostTensor> {
        let mut rng = Pcg32::seeded(7);
        let mut tensors = Vec::new();
        for name in cfg.param_order.clone() {
            let shape = cfg.shape_of(&name).unwrap().to_vec();
            let n: usize = shape.iter().product();
            let vals: Vec<f32> = match name.as_str() {
                "ln1_g" | "ln2_g" | "lnf_g" => vec![1.0; n],
                "beta" => vec![1.5; n],
                "gamma" => vec![100.0; n],
                "ssmax_s" => vec![0.43; n],
                _ if name.ends_with("_b") => vec![0.0; n],
                _ => rng.normal_vec_f32(n, 0.0, 0.02),
            };
            tensors.push(HostTensor::from_f32(&vals, &shape));
        }
        tensors
    }

    fn tiny_model(normalizer: &str) -> NativeModel {
        tiny_model_quant(normalizer, QuantMode::Off)
    }

    fn tiny_model_quant(normalizer: &str, quant: QuantMode) -> NativeModel {
        let cfg = ModelConfig::builtin("tiny", normalizer).unwrap();
        let tensors = tiny_tensors(&cfg);
        NativeModel::from_params_quant(&cfg, &cfg.param_order, &tensors, quant)
            .unwrap()
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        for norm in NORMALIZERS {
            let m = tiny_model(norm);
            let toks: Vec<i32> = (0..2 * 8).map(|i| (i * 13) % 256).collect();
            let logits = m.forward(&toks, 2, 8).unwrap();
            assert_eq!(logits.len(), 2 * 8 * 256, "{norm}");
            assert!(logits.iter().all(|v| v.is_finite()), "{norm}");
        }
    }

    #[test]
    fn untrained_loss_near_uniform() {
        // near-random weights => loss close to ln(256) = 5.545
        let m = tiny_model("consmax");
        let x: Vec<i32> = (0..2 * 32).map(|i| (i * 7) % 256).collect();
        let y: Vec<i32> = (0..2 * 32).map(|i| (i * 7 + 1) % 256).collect();
        let loss = m.loss(&x, &y, 2, 32).unwrap();
        assert!((4.5..6.5).contains(&loss), "loss {loss}");
    }

    #[test]
    fn forward_is_deterministic() {
        let m = tiny_model("consmax");
        let toks: Vec<i32> = (0..16).map(|i| (i * 31) % 256).collect();
        assert_eq!(m.forward(&toks, 1, 16).unwrap(), m.forward(&toks, 1, 16).unwrap());
    }

    #[test]
    fn causality_prefix_logits_stable() {
        // logits at position i must not depend on tokens after i
        let m = tiny_model("consmax");
        let mut a: Vec<i32> = (0..12).map(|i| (i * 11) % 256).collect();
        let la = m.forward(&a, 1, 12).unwrap();
        a[11] = (a[11] + 17) % 256; // change only the last token
        let lb = m.forward(&a, 1, 12).unwrap();
        let v = m.cfg.vocab;
        // positions 0..10 identical; position 11 differs
        assert_eq!(&la[..11 * v], &lb[..11 * v]);
        assert_ne!(&la[11 * v..], &lb[11 * v..]);
    }

    #[test]
    fn next_logits_matches_forward_tail() {
        let m = tiny_model("softmax");
        let seq: Vec<i32> = (0..10).map(|i| (i * 3) % 256).collect();
        let full = m.forward(&seq, 1, 10).unwrap();
        let v = m.cfg.vocab;
        let nl = m.next_logits(&[seq]).unwrap();
        assert_eq!(nl, full[9 * v..].to_vec());
    }

    #[test]
    fn window_clamps_to_ctx() {
        let m = tiny_model("consmax");
        let long: Vec<i32> = (0..200).map(|i| i % 256).collect();
        let nl = m.next_logits(&[long]).unwrap();
        assert_eq!(nl.len(), m.cfg.vocab);
        assert!(nl.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn rejects_bad_tokens() {
        let m = tiny_model("consmax");
        assert!(m.forward(&[300], 1, 1).is_err());
        assert!(m.forward(&[-1], 1, 1).is_err());
        assert!(m.forward(&[0; 4], 2, 3).is_err()); // wrong element count
    }

    #[test]
    fn prefill_matches_next_logits() {
        for norm in NORMALIZERS {
            let m = tiny_model(norm);
            let seq: Vec<i32> = (0..20).map(|i| (i * 5 + 3) % 256).collect();
            let mut sess = DecodeSession::new(&m.cfg, 1);
            let kv = m.prefill(&mut sess, &[seq.clone()]).unwrap();
            let oracle = m.next_logits(&[seq]).unwrap();
            assert_eq!(kv, oracle, "{norm}: prefill vs oracle");
            assert_eq!(sess.len_of(0), 20);
        }
    }

    #[test]
    fn decode_step_extends_bitwise() {
        // one incremental step == recompute over the extended sequence
        for norm in NORMALIZERS {
            let m = tiny_model(norm);
            let mut seq: Vec<i32> = (0..9).map(|i| (i * 7 + 1) % 256).collect();
            let mut sess = DecodeSession::new(&m.cfg, 1);
            m.prefill(&mut sess, &[seq.clone()]).unwrap();
            let kv = m.decode_step(&mut sess, &[42]).unwrap();
            seq.push(42);
            let oracle = m.next_logits(&[seq]).unwrap();
            assert_eq!(kv, oracle, "{norm}: decode_step vs oracle");
        }
    }

    #[test]
    fn prefill_rows_joins_without_disturbing_neighbors() {
        // prefill rows {0, 2} of a live 3-row session while row 1 is
        // mid-flight: joiner logits match a fresh solo prefill and the
        // in-flight row's state is untouched
        let m = tiny_model("consmax");
        let mut sess = DecodeSession::new(&m.cfg, 3);
        let resident: Vec<i32> = (0..12).map(|i| (i * 3 + 2) % 256).collect();
        m.prefill(
            &mut sess,
            &[vec![1, 2], resident.clone(), vec![3, 4]],
        )
        .unwrap();
        m.decode_step_active(&mut sess, &[0, 9, 0], &[false, true, false])
            .unwrap();
        let len_mid = sess.len_of(1);

        let a: Vec<i32> = (0..7).map(|i| (i * 11 + 5) % 256).collect();
        let b: Vec<i32> = (0..15).map(|i| (i * 13 + 1) % 256).collect();
        let joined = m
            .prefill_rows(
                &mut sess,
                &[(2, a.as_slice()), (0, b.as_slice())],
            )
            .unwrap();
        let v = m.cfg.vocab;
        assert_eq!(joined.len(), 2 * v);
        assert_eq!(sess.len_of(2), 7);
        assert_eq!(sess.len_of(0), 15);
        assert_eq!(sess.len_of(1), len_mid, "in-flight row disturbed");

        let mut solo = DecodeSession::new(&m.cfg, 1);
        let ora = m.prefill(&mut solo, &[a]).unwrap();
        assert_eq!(&joined[..v], ora.as_slice(), "slot 2 vs solo prefill");
        let orb = m.prefill(&mut solo, &[b]).unwrap();
        assert_eq!(&joined[v..], orb.as_slice(), "slot 0 vs solo prefill");

        // the mid-flight row still decodes as if nothing happened
        let step = m
            .decode_step_active(&mut sess, &[0, 17, 0], &[false, true, false])
            .unwrap();
        assert!(step[v..2 * v].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn prefill_rows_rejects_bad_slots() {
        let m = tiny_model("consmax");
        let mut sess = DecodeSession::new(&m.cfg, 2);
        let seq = [1i32, 2, 3];
        // out-of-range slot
        assert!(m.prefill_rows(&mut sess, &[(2, seq.as_slice())]).is_err());
        // duplicate slot
        assert!(m
            .prefill_rows(&mut sess, &[(0, seq.as_slice()), (0, seq.as_slice())])
            .is_err());
        // empty prompt
        assert!(m.prefill_rows(&mut sess, &[(0, [].as_slice())]).is_err());
        // empty join set is a no-op
        assert_eq!(m.prefill_rows(&mut sess, &[]).unwrap().len(), 0);
    }

    #[test]
    fn decode_session_misuse_rejected() {
        let m = tiny_model("consmax");
        let mut sess = DecodeSession::new(&m.cfg, 2);
        // decode before prefill
        assert!(m.decode_step(&mut sess, &[1, 2]).is_err());
        // batch-size mismatch
        assert!(m.prefill(&mut sess, &[vec![1]]).is_err());
        // empty row
        assert!(m.prefill(&mut sess, &[vec![1], vec![]]).is_err());
        // bad token id after a valid prefill
        m.prefill(&mut sess, &[vec![1, 2], vec![3]]).unwrap();
        assert!(m.decode_step(&mut sess, &[300, 0]).is_err());
    }

    #[test]
    fn inactive_rows_hold_still() {
        let m = tiny_model("consmax");
        let mut sess = DecodeSession::new(&m.cfg, 2);
        m.prefill(&mut sess, &[vec![5, 6, 7], vec![9, 9]]).unwrap();
        let v = m.cfg.vocab;
        let out = m
            .decode_step_active(&mut sess, &[1, 1], &[true, false])
            .unwrap();
        assert_eq!(sess.len_of(0), 4);
        assert_eq!(sess.len_of(1), 2); // untouched
        assert!(out[v..].iter().all(|&x| x == 0.0)); // zero-filled row
        assert!(out[..v].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn int8_forward_finite_and_loss_near_uniform() {
        for norm in NORMALIZERS {
            let m = tiny_model_quant(norm, QuantMode::Int8);
            assert!(m.quant_mode().is_int8());
            let x: Vec<i32> = (0..2 * 32).map(|i| (i * 7) % 256).collect();
            let y: Vec<i32> = (0..2 * 32).map(|i| (i * 7 + 1) % 256).collect();
            let loss = m.loss(&x, &y, 2, 32).unwrap();
            // int8 weights perturb near-random logits only slightly:
            // loss stays near ln(256) = 5.545
            assert!((4.0..7.0).contains(&loss), "{norm}: loss {loss}");
        }
    }

    #[test]
    fn int8_decode_matches_recompute_bitwise() {
        // dense KV stores raw f32, so the int8 model's incremental
        // engine and its own recompute oracle run identical ops over
        // identical values — logits stay bitwise equal, exactly like
        // the f32 model (the int8 accuracy question lives in the eval
        // gate, not here)
        for norm in NORMALIZERS {
            let m = tiny_model_quant(norm, QuantMode::Int8);
            let mut seq: Vec<i32> = (0..9).map(|i| (i * 7 + 1) % 256).collect();
            let mut sess = DecodeSession::new(&m.cfg, 1);
            let pre = m.prefill(&mut sess, &[seq.clone()]).unwrap();
            assert_eq!(pre, m.next_logits(&[seq.clone()]).unwrap(), "{norm}");
            let kv = m.decode_step(&mut sess, &[42]).unwrap();
            seq.push(42);
            assert_eq!(kv, m.next_logits(&[seq]).unwrap(), "{norm}");
        }
    }

    #[test]
    fn int8_consmax_probs_come_from_the_lut() {
        // recompute one (layer 0, head 0) attention probability by hand
        // through BitSplitLut and confirm the model's table holds the
        // identical bits for every code
        let m = tiny_model_quant("consmax", QuantMode::Int8);
        let lut = crate::quant::BitSplitLut::paper();
        let c = crate::quant::merge_beta_gamma(
            m.beta_row(0)[0],
            m.gamma_row(0)[0],
        );
        let table = m.consmax_table(0, 0);
        for code in -128i16..=127 {
            let q = code as i8;
            assert_eq!(
                table[q as u8 as usize].to_bits(),
                lut.consmax(q, c).to_bits(),
                "code {q}"
            );
        }
    }

    #[test]
    fn transposed_weights_match_originals() {
        // params_t really is the per-layer transpose of the input weights
        // (the untransposed originals are dropped from the model at load)
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let tensors = tiny_tensors(&cfg);
        let idx = cfg
            .param_order
            .iter()
            .position(|n| n == "attn_qkv_w")
            .unwrap();
        let original = tensors[idx].as_f32().unwrap();
        let m = NativeModel::from_params(&cfg, &cfg.param_order, &tensors).unwrap();
        let d = cfg.n_embd;
        let (din, dout) = (d, 3 * d);
        for l in 0..cfg.n_layer {
            let w = &original[l * din * dout..(l + 1) * din * dout];
            let wt = m.layer_t("attn_qkv_w", l, din * dout);
            for i in 0..din {
                for j in 0..dout {
                    assert_eq!(w[i * dout + j], wt[j * din + i], "l{l} ({i},{j})");
                }
            }
        }
    }
}
