//! Per-batch decode state for the native KV-cached decode engine.
//!
//! A [`DecodeSession`] holds per-layer K/V caches sized
//! `[n_layer, b, n_head, ctx, head_dim]` plus the per-row bookkeeping
//! that makes batched serving correct:
//!
//! * **per-row true lengths** — rows of a batch prefill at their own
//!   prompt length and attend only to their own cached positions, so a
//!   short prompt in a mixed batch is never polluted by padding (the
//!   left-pad bug the recompute path had);
//! * **token history ring** — the last `ctx` token ids per row. The
//!   model's positional embeddings are *absolute* (`wpe[i]`, `i < ctx`),
//!   so once a row fills its cache, evicting the oldest entry shifts
//!   every remaining position: the cached K/V become stale and the row
//!   is re-encoded over the shifted window (exactly the trailing-window
//!   semantics of the recompute oracle `NativeModel::next_logits`). The
//!   ring makes that re-encode self-contained. Within `ctx` — the whole
//!   serving regime, since prompts are clamped to `ctx - max_new` — a
//!   decode step is a single O(len) incremental pass per token.
//!
//! The session owns no parameters; [`NativeModel::prefill`] and
//! [`NativeModel::decode_step`] drive it.
//!
//! [`NativeModel::prefill`]: super::NativeModel::prefill
//! [`NativeModel::decode_step`]: super::NativeModel::decode_step

use std::collections::VecDeque;

use crate::config::ModelConfig;

/// KV caches + per-row lengths for one decode batch.
pub struct DecodeSession {
    b: usize,
    pub(crate) ctx: usize,
    pub(crate) n_layer: usize,
    pub(crate) n_head: usize,
    pub(crate) head_dim: usize,
    /// Cached keys, `[n_layer, b, n_head, ctx, head_dim]` row-major.
    pub(crate) k: Vec<f32>,
    /// Cached values, same layout as `k`.
    pub(crate) v: Vec<f32>,
    /// Valid cached positions per row (`<= ctx`).
    len: Vec<usize>,
    /// Last `ctx` token ids per row (window re-encode on eviction).
    history: Vec<VecDeque<i32>>,
}

impl DecodeSession {
    /// Fresh session for `b` rows of `cfg`'s geometry; caches zeroed,
    /// every row empty until [`NativeModel::prefill`] fills it.
    ///
    /// [`NativeModel::prefill`]: super::NativeModel::prefill
    pub fn new(cfg: &ModelConfig, b: usize) -> DecodeSession {
        let elems = cfg.n_layer * b * cfg.n_head * cfg.ctx * cfg.head_dim();
        DecodeSession {
            b,
            ctx: cfg.ctx,
            n_layer: cfg.n_layer,
            n_head: cfg.n_head,
            head_dim: cfg.head_dim(),
            k: vec![0.0; elems],
            v: vec![0.0; elems],
            len: vec![0; b],
            history: (0..b).map(|_| VecDeque::with_capacity(cfg.ctx)).collect(),
        }
    }

    /// Number of rows in the batch.
    pub fn batch(&self) -> usize {
        self.b
    }

    /// Valid cached positions for row `r`.
    pub fn len_of(&self, r: usize) -> usize {
        self.len[r]
    }

    /// Start offset of the `head_dim` run for (layer, row, head, slot).
    pub(crate) fn kv_start(&self, l: usize, r: usize, h: usize, slot: usize) -> usize {
        (((l * self.b + r) * self.n_head + h) * self.ctx + slot) * self.head_dim
    }

    pub(crate) fn set_len(&mut self, r: usize, len: usize) {
        debug_assert!(len <= self.ctx);
        self.len[r] = len;
    }

    /// Reset row `r` to a fresh window of tokens (history only; the
    /// caches are overwritten by the subsequent captured forward).
    pub(crate) fn reset_row(&mut self, r: usize, window: &[i32]) {
        debug_assert!(window.len() <= self.ctx);
        self.len[r] = 0;
        self.history[r].clear();
        self.history[r].extend(window.iter().copied());
    }

    /// Append a token to row `r`'s history ring, evicting the oldest
    /// entry once the ring holds `ctx` tokens.
    pub(crate) fn push_history(&mut self, r: usize, tok: i32) {
        if self.history[r].len() == self.ctx {
            self.history[r].pop_front();
        }
        self.history[r].push_back(tok);
    }

    /// Row `r`'s current token window, oldest first.
    pub(crate) fn history_row(&self, r: usize) -> Vec<i32> {
        self.history[r].iter().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_session_geometry() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let s = DecodeSession::new(&cfg, 3);
        assert_eq!(s.batch(), 3);
        assert_eq!(
            s.k.len(),
            cfg.n_layer * 3 * cfg.n_head * cfg.ctx * cfg.head_dim()
        );
        assert_eq!(s.k.len(), s.v.len());
        for r in 0..3 {
            assert_eq!(s.len_of(r), 0);
        }
    }

    #[test]
    fn kv_start_is_dense_and_disjoint() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let s = DecodeSession::new(&cfg, 2);
        let hd = cfg.head_dim();
        let mut seen = std::collections::BTreeSet::new();
        for l in 0..cfg.n_layer {
            for r in 0..2 {
                for h in 0..cfg.n_head {
                    for slot in 0..cfg.ctx {
                        let start = s.kv_start(l, r, h, slot);
                        assert!(start + hd <= s.k.len());
                        assert!(seen.insert(start), "overlap at {start}");
                    }
                }
            }
        }
        assert_eq!(seen.len() * hd, s.k.len());
    }

    #[test]
    fn history_ring_evicts_oldest() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let mut s = DecodeSession::new(&cfg, 1);
        s.reset_row(0, &[1, 2, 3]);
        for t in 4..=(cfg.ctx as i32 + 3) {
            s.push_history(0, t);
        }
        let h = s.history_row(0);
        assert_eq!(h.len(), cfg.ctx);
        assert_eq!(h[0], 4); // 1, 2, 3 evicted
        assert_eq!(*h.last().unwrap(), cfg.ctx as i32 + 3);
    }
}
