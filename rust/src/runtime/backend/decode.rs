//! Per-batch decode state for the native KV-cached decode engine.
//!
//! A [`DecodeSession`] holds per-layer K/V caches sized
//! `[b, n_layer, n_head, ctx, head_dim]` — **batch-major**, so each
//! row's entire cache is one contiguous run and a batch splits into
//! disjoint [`RowMut`] views that decode in parallel across the worker
//! pool (`runtime::parallel`) — plus the per-row bookkeeping that makes
//! batched serving correct:
//!
//! * **per-row true lengths** — rows of a batch prefill at their own
//!   prompt length and attend only to their own cached positions, so a
//!   short prompt in a mixed batch is never polluted by padding (the
//!   left-pad bug the recompute path had);
//! * **token history ring** — the last `ctx` token ids per row. The
//!   model's positional embeddings are *absolute* (`wpe[i]`, `i < ctx`),
//!   so once a row fills its cache, evicting the oldest entry shifts
//!   every remaining position: the cached K/V become stale and the row
//!   is re-encoded over the shifted window (exactly the trailing-window
//!   semantics of the recompute oracle `NativeModel::next_logits`). The
//!   ring makes that re-encode self-contained. Within `ctx` — the whole
//!   serving regime, since prompts are clamped to `ctx - max_new` — a
//!   decode step is a single O(len) incremental pass per token;
//! * **per-row scratch arenas** ([`RowScratch`]) — every activation
//!   buffer a decode step needs (embedding, LN, QKV, head outputs,
//!   score row, MLP hidden), sized once at session creation. The
//!   per-row compute path (`NativeModel::decode_token_into`) performs
//!   **zero heap allocations per token**: it reads weights, writes the
//!   row's cache slots and scratch, and emits logits straight into the
//!   caller's output slice. (Per *step*, the engine still allocates
//!   the returned `(b, vocab)` logits buffer and the O(b) row-view
//!   list — output, not workspace.)
//!
//! The session owns no parameters; [`NativeModel::prefill`] and
//! [`NativeModel::decode_step`] drive it.
//!
//! [`NativeModel::prefill`]: super::NativeModel::prefill
//! [`NativeModel::decode_step`]: super::NativeModel::decode_step

use std::collections::VecDeque;

use crate::config::ModelConfig;

/// Offset of the `head_dim` run for (layer, head, slot) inside one
/// row's `[n_layer, n_head, ctx, head_dim]` cache block.
#[inline]
pub(crate) fn kv_offset(
    n_head: usize,
    ctx: usize,
    head_dim: usize,
    l: usize,
    h: usize,
    slot: usize,
) -> usize {
    ((l * n_head + h) * ctx + slot) * head_dim
}

/// Pre-sized activation buffers for one row's incremental decode step.
/// Allocated once per session row; reused every token.
pub(crate) struct RowScratch {
    /// Residual stream for the new token (`n_embd`).
    pub x: Vec<f32>,
    /// LayerNorm output, also reused for the final LN (`n_embd`).
    pub xn: Vec<f32>,
    /// Fused QKV projection of the new token (`3 * n_embd`).
    pub qkv: Vec<f32>,
    /// Concatenated attention head outputs (`n_embd`).
    pub y: Vec<f32>,
    /// Score row over cached positions (`ctx`; softmax/softermax only —
    /// the ConSmax path streams and never materializes it).
    pub srow: Vec<f32>,
    /// MLP hidden activations (`4 * n_embd`).
    pub hid: Vec<f32>,
    /// Attention/MLP projection output (`n_embd`).
    pub proj: Vec<f32>,
}

impl RowScratch {
    fn new(cfg: &ModelConfig) -> RowScratch {
        let d = cfg.n_embd;
        RowScratch {
            x: vec![0.0; d],
            xn: vec![0.0; d],
            qkv: vec![0.0; 3 * d],
            y: vec![0.0; d],
            srow: vec![0.0; cfg.ctx],
            hid: vec![0.0; 4 * d],
            proj: vec![0.0; d],
        }
    }
}

/// KV caches + per-row lengths for one decode batch.
pub struct DecodeSession {
    b: usize,
    pub(crate) ctx: usize,
    pub(crate) n_layer: usize,
    pub(crate) n_head: usize,
    pub(crate) head_dim: usize,
    /// Cached keys, `[b, n_layer, n_head, ctx, head_dim]` row-major.
    k: Vec<f32>,
    /// Cached values, same layout as `k`.
    v: Vec<f32>,
    /// Valid cached positions per row (`<= ctx`).
    len: Vec<usize>,
    /// Last `ctx` token ids per row (window re-encode on eviction).
    history: Vec<VecDeque<i32>>,
    /// Per-row activation arenas for the zero-alloc decode step.
    scratch: Vec<RowScratch>,
}

/// Mutable view of one row of a [`DecodeSession`]: its contiguous K/V
/// block, length, history ring and scratch arena. Rows are disjoint, so
/// a batch of `RowMut`s decodes in parallel with no shared state.
pub(crate) struct RowMut<'a> {
    pub ctx: usize,
    pub n_head: usize,
    pub head_dim: usize,
    /// This row's keys, `[n_layer, n_head, ctx, head_dim]` row-major.
    pub k: &'a mut [f32],
    /// This row's values, same layout as `k`.
    pub v: &'a mut [f32],
    /// Valid cached positions (`<= ctx`).
    pub len: &'a mut usize,
    /// Token window, oldest first.
    pub history: &'a mut VecDeque<i32>,
    /// The row's activation arena.
    pub scratch: &'a mut RowScratch,
}

impl RowMut<'_> {
    /// Start offset of the `head_dim` run for (layer, head, slot).
    pub(crate) fn kv_start(&self, l: usize, h: usize, slot: usize) -> usize {
        kv_offset(self.n_head, self.ctx, self.head_dim, l, h, slot)
    }

    /// Reset to a fresh window of tokens (history only; the caches are
    /// overwritten by the subsequent captured forward).
    pub(crate) fn reset(&mut self, window: &[i32]) {
        debug_assert!(window.len() <= self.ctx);
        *self.len = 0;
        self.history.clear();
        self.history.extend(window.iter().copied());
    }

    /// Append a token to the history ring, evicting the oldest entry
    /// once the ring holds `ctx` tokens. Never reallocates: the ring is
    /// built with `ctx` capacity.
    pub(crate) fn push_history(&mut self, tok: i32) {
        if self.history.len() == self.ctx {
            self.history.pop_front();
        }
        self.history.push_back(tok);
    }

    /// The current token window, oldest first (eviction re-encode only
    /// — the steady-state step never calls this).
    pub(crate) fn history_vec(&self) -> Vec<i32> {
        self.history.iter().copied().collect()
    }
}

impl DecodeSession {
    /// Fresh session for `b` rows of `cfg`'s geometry; caches zeroed,
    /// every row empty until [`NativeModel::prefill`] fills it.
    ///
    /// [`NativeModel::prefill`]: super::NativeModel::prefill
    pub fn new(cfg: &ModelConfig, b: usize) -> DecodeSession {
        let elems = b * cfg.n_layer * cfg.n_head * cfg.ctx * cfg.head_dim();
        DecodeSession {
            b,
            ctx: cfg.ctx,
            n_layer: cfg.n_layer,
            n_head: cfg.n_head,
            head_dim: cfg.head_dim(),
            k: vec![0.0; elems],
            v: vec![0.0; elems],
            len: vec![0; b],
            history: (0..b).map(|_| VecDeque::with_capacity(cfg.ctx)).collect(),
            scratch: (0..b).map(|_| RowScratch::new(cfg)).collect(),
        }
    }

    /// Number of rows in the batch.
    pub fn batch(&self) -> usize {
        self.b
    }

    /// Valid cached positions for row `r`.
    pub fn len_of(&self, r: usize) -> usize {
        self.len[r]
    }

    /// Clear one row back to the empty state (length zero, empty
    /// history) without touching any other row — the slot-lifecycle
    /// seam of the continuous-batching scheduler: a finished request
    /// frees its slot in O(1), and the next
    /// [`NativeModel::prefill_rows`] overwrites the row's cache in
    /// place. Per-row KV blocks are disjoint (batch-major layout), so
    /// in-flight neighbors never observe the reset.
    ///
    /// [`NativeModel::prefill_rows`]: super::NativeModel::prefill_rows
    pub fn reset_row(&mut self, r: usize) {
        self.len[r] = 0;
        self.history[r].clear();
    }

    /// Split the session into disjoint per-row mutable views — the unit
    /// of parallelism for batched prefill and decode.
    pub(crate) fn rows_mut(&mut self) -> Vec<RowMut<'_>> {
        let per = self.n_layer * self.n_head * self.ctx * self.head_dim;
        let (ctx, n_head, head_dim) = (self.ctx, self.n_head, self.head_dim);
        let mut rows = Vec::with_capacity(self.b);
        for ((((k, v), len), history), scratch) in self
            .k
            .chunks_mut(per)
            .zip(self.v.chunks_mut(per))
            .zip(self.len.iter_mut())
            .zip(self.history.iter_mut())
            .zip(self.scratch.iter_mut())
        {
            rows.push(RowMut {
                ctx,
                n_head,
                head_dim,
                k,
                v,
                len,
                history,
                scratch,
            });
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_session_geometry() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let s = DecodeSession::new(&cfg, 3);
        assert_eq!(s.batch(), 3);
        assert_eq!(
            s.k.len(),
            3 * cfg.n_layer * cfg.n_head * cfg.ctx * cfg.head_dim()
        );
        assert_eq!(s.k.len(), s.v.len());
        for r in 0..3 {
            assert_eq!(s.len_of(r), 0);
        }
        // scratch arenas pre-sized for the zero-alloc decode step
        for sc in &s.scratch {
            assert_eq!(sc.x.len(), cfg.n_embd);
            assert_eq!(sc.qkv.len(), 3 * cfg.n_embd);
            assert_eq!(sc.srow.len(), cfg.ctx);
            assert_eq!(sc.hid.len(), 4 * cfg.n_embd);
        }
    }

    #[test]
    fn row_views_are_contiguous_and_dense() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let mut s = DecodeSession::new(&cfg, 2);
        let hd = cfg.head_dim();
        let per = cfg.n_layer * cfg.n_head * cfg.ctx * hd;
        let rows = s.rows_mut();
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert_eq!(row.k.len(), per);
            assert_eq!(row.v.len(), per);
            // kv_start covers the row's block densely and disjointly
            let mut seen = std::collections::BTreeSet::new();
            for l in 0..cfg.n_layer {
                for h in 0..cfg.n_head {
                    for slot in 0..cfg.ctx {
                        let start = row.kv_start(l, h, slot);
                        assert!(start + hd <= per);
                        assert!(seen.insert(start), "overlap at {start}");
                    }
                }
            }
            assert_eq!(seen.len() * hd, per);
        }
    }

    #[test]
    fn row_writes_land_in_their_own_block() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let mut s = DecodeSession::new(&cfg, 2);
        {
            let mut rows = s.rows_mut();
            rows[0].k[0] = 1.0;
            let last = rows[1].k.len() - 1;
            rows[1].k[last] = 2.0;
            *rows[1].len = 5;
        }
        assert_eq!(s.k[0], 1.0);
        assert_eq!(*s.k.last().unwrap(), 2.0);
        assert_eq!(s.len_of(0), 0);
        assert_eq!(s.len_of(1), 5);
    }

    #[test]
    fn reset_row_clears_only_that_row() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let mut s = DecodeSession::new(&cfg, 2);
        {
            let mut rows = s.rows_mut();
            rows[0].reset(&[1, 2, 3]);
            *rows[0].len = 3;
            rows[1].reset(&[7, 8]);
            *rows[1].len = 2;
        }
        s.reset_row(0);
        assert_eq!(s.len_of(0), 0);
        assert!(s.history[0].is_empty());
        // the neighboring in-flight row is untouched
        assert_eq!(s.len_of(1), 2);
        assert_eq!(s.history[1].iter().copied().collect::<Vec<_>>(), vec![7, 8]);
    }

    #[test]
    fn history_ring_evicts_oldest() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let mut s = DecodeSession::new(&cfg, 1);
        let mut rows = s.rows_mut();
        rows[0].reset(&[1, 2, 3]);
        for t in 4..=(cfg.ctx as i32 + 3) {
            rows[0].push_history(t);
        }
        let h = rows[0].history_vec();
        assert_eq!(h.len(), cfg.ctx);
        assert_eq!(h[0], 4); // 1, 2, 3 evicted
        assert_eq!(*h.last().unwrap(), cfg.ctx as i32 + 3);
    }
}
