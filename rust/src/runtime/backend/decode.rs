//! Per-batch decode state for the native KV-cached decode engine.
//!
//! A [`DecodeSession`] holds per-row K/V caches behind one of two
//! backings plus the per-row bookkeeping that makes batched serving
//! correct:
//!
//! * **dense** ([`DecodeSession::new`]) — the original layout: one
//!   contiguous `[n_layer, n_head, ctx, head_dim]` f32 slab per row,
//!   batch-major, split into disjoint [`RowMut`] views that decode in
//!   parallel. Preserved bit-identical as the oracle the paged layout
//!   is tested against.
//! * **paged** ([`DecodeSession::new_paged`]) — rows map their cached
//!   positions through *block tables* into a shared [`KvPool`]
//!   (`runtime/backend/kvcache.rs`): fixed-size pages, pluggable
//!   f32/f16/bf16 storage, refcounted copy-on-write prefix sharing, and
//!   a byte budget that replaces any fixed slot constant as the real
//!   serving capacity limit (DESIGN.md §KV-memory seam).
//!
//! Shared per-row bookkeeping (both backings):
//!
//! * **per-row true lengths** — rows of a batch prefill at their own
//!   prompt length and attend only to their own cached positions, so a
//!   short prompt in a mixed batch is never polluted by padding;
//! * **token history ring** — the last `ctx` token ids per row. The
//!   model's positional embeddings are *absolute* (`wpe[i]`, `i < ctx`),
//!   so once a row fills its cache, evicting the oldest entry shifts
//!   every remaining position: the cached K/V become stale and the row
//!   is re-encoded over the shifted window (exactly the trailing-window
//!   semantics of the recompute oracle `NativeModel::next_logits`).
//!   Within `ctx` — the whole serving regime, since prompts are clamped
//!   to `ctx - max_new` — a decode step is a single O(len) incremental
//!   pass per token;
//! * **per-row scratch arenas** ([`RowScratch`]) — every activation
//!   buffer a decode step needs, sized once at session creation. The
//!   per-row compute path performs **zero heap allocations per token**.
//!   Paged rows additionally carry per-block gather/dequant buffers and
//!   a one-token K/V staging area, so the parallel decode phase only
//!   *reads* the shared pool; encoded writes commit serially afterwards.
//!
//! The session owns no parameters; [`NativeModel::prefill`] and
//! [`NativeModel::decode_step`] drive it. The per-token compute those
//! entry points run — `native::dot` scores and the fused
//! `native::attend_stream` ConSmax tails (which never materialize a
//! probability row) — sits on the SIMD microkernel seam (DESIGN.md
//! §SIMD-kernel seam), so dense and paged decode inherit the
//! vectorized kernels and stay bitwise equal to the streaming forward
//! pass at any SIMD level.
//!
//! [`NativeModel::prefill`]: super::NativeModel::prefill
//! [`NativeModel::decode_step`]: super::NativeModel::decode_step

use std::collections::VecDeque;

use anyhow::Result;

use crate::config::{KvCacheConfig, ModelConfig};
use crate::runtime::backend::kvcache::{KvPool, KvStats};

/// Offset of the `head_dim` run for (layer, head, slot) inside one
/// row's `[n_layer, n_head, slots, head_dim]` cache block.
#[inline]
pub(crate) fn kv_offset(
    n_head: usize,
    slots: usize,
    head_dim: usize,
    l: usize,
    h: usize,
    slot: usize,
) -> usize {
    ((l * n_head + h) * slots + slot) * head_dim
}

/// A writable `[n_layer, n_head, slots, head_dim]` K/V target for the
/// trunk's capture pass: either a dense row's cache slab (`slots ==
/// ctx`) or a transient prefill buffer (`slots == window length`) that
/// is encoded into pool blocks afterwards.
pub(crate) struct KvCapture<'a> {
    pub n_head: usize,
    pub head_dim: usize,
    /// Slot stride of the target buffer.
    pub slots: usize,
    pub k: &'a mut [f32],
    pub v: &'a mut [f32],
}

impl KvCapture<'_> {
    /// Start offset of the `head_dim` run for (layer, head, slot).
    pub(crate) fn kv_start(&self, l: usize, h: usize, slot: usize) -> usize {
        kv_offset(self.n_head, self.slots, self.head_dim, l, h, slot)
    }
}

/// Pre-sized activation buffers for one row's incremental decode step.
/// Allocated once per session row; reused every token.
pub(crate) struct RowScratch {
    /// Residual stream for the new token (`n_embd`).
    pub x: Vec<f32>,
    /// LayerNorm output, also reused for the final LN (`n_embd`).
    pub xn: Vec<f32>,
    /// Fused QKV projection of the new token (`3 * n_embd`).
    pub qkv: Vec<f32>,
    /// Concatenated attention head outputs (`n_embd`).
    pub y: Vec<f32>,
    /// Score row over cached positions (`ctx`; reducing normalizers —
    /// softmax, softermax, ssmax — only: the streaming ConSmax family
    /// never materializes it).
    pub srow: Vec<f32>,
    /// MLP hidden activations (`4 * n_embd`).
    pub hid: Vec<f32>,
    /// Attention/MLP projection output (`n_embd`).
    pub proj: Vec<f32>,
    /// Paged rows only: the new token's K, every (layer, head) lane,
    /// `[n_layer * n_head, head_dim]`, already round-tripped through the
    /// pool dtype so attention reads see exactly what later steps will
    /// read back from storage.
    pub staged_k: Vec<f32>,
    /// Paged rows only: staged V, same layout as `staged_k`.
    pub staged_v: Vec<f32>,
    /// Paged rows only: per-(layer, head) gather/dequant buffer for
    /// cached keys, `[ctx, head_dim]`.
    pub kgath: Vec<f32>,
    /// Paged rows only: gathered values, same layout as `kgath`.
    pub vgath: Vec<f32>,
}

impl RowScratch {
    fn new(cfg: &ModelConfig, paged: bool) -> RowScratch {
        let d = cfg.n_embd;
        let lanes = if paged { cfg.n_layer * cfg.n_head * cfg.head_dim() } else { 0 };
        let gath = if paged { cfg.ctx * cfg.head_dim() } else { 0 };
        RowScratch {
            x: vec![0.0; d],
            xn: vec![0.0; d],
            qkv: vec![0.0; 3 * d],
            y: vec![0.0; d],
            srow: vec![0.0; cfg.ctx],
            hid: vec![0.0; 4 * d],
            proj: vec![0.0; d],
            staged_k: vec![0.0; lanes],
            staged_v: vec![0.0; lanes],
            kgath: vec![0.0; gath],
            vgath: vec![0.0; gath],
        }
    }
}

/// Which memory model backs the session's K/V.
enum KvBacking {
    /// One dense f32 `[n_layer, n_head, ctx, head_dim]` slab per row,
    /// batch-major (`[b, ...]` overall) — the bit-exact oracle layout.
    Dense { k: Vec<f32>, v: Vec<f32> },
    /// Shared block pool + one block table per row.
    Paged { pool: KvPool, tables: Vec<Vec<u32>> },
}

/// KV caches + per-row lengths for one decode batch.
pub struct DecodeSession {
    b: usize,
    pub(crate) ctx: usize,
    pub(crate) n_layer: usize,
    pub(crate) n_head: usize,
    pub(crate) head_dim: usize,
    store: KvBacking,
    /// Valid cached positions per row (`<= ctx`).
    len: Vec<usize>,
    /// Last `ctx` token ids per row (window re-encode on eviction).
    history: Vec<VecDeque<i32>>,
    /// Per-row activation arenas for the zero-alloc decode step.
    scratch: Vec<RowScratch>,
}

/// Mutable view of one **dense** row of a [`DecodeSession`]: its
/// contiguous K/V block, length, history ring and scratch arena. Rows
/// are disjoint, so a batch of `RowMut`s decodes in parallel with no
/// shared state. (Paged rows go through [`PagedParts`] instead: the
/// pool is shared, so the parallel phase reads it immutably and commits
/// writes serially.)
pub(crate) struct RowMut<'a> {
    pub ctx: usize,
    pub n_head: usize,
    pub head_dim: usize,
    /// This row's keys, `[n_layer, n_head, ctx, head_dim]` row-major.
    pub k: &'a mut [f32],
    /// This row's values, same layout as `k`.
    pub v: &'a mut [f32],
    /// Valid cached positions (`<= ctx`).
    pub len: &'a mut usize,
    /// Token window, oldest first.
    pub history: &'a mut VecDeque<i32>,
    /// The row's activation arena.
    pub scratch: &'a mut RowScratch,
}

impl RowMut<'_> {
    /// Start offset of the `head_dim` run for (layer, head, slot).
    pub(crate) fn kv_start(&self, l: usize, h: usize, slot: usize) -> usize {
        kv_offset(self.n_head, self.ctx, self.head_dim, l, h, slot)
    }

    /// A capture view over this row's cache slab (prefill / re-encode).
    pub(crate) fn capture(&mut self) -> KvCapture<'_> {
        KvCapture {
            n_head: self.n_head,
            head_dim: self.head_dim,
            slots: self.ctx,
            k: &mut *self.k,
            v: &mut *self.v,
        }
    }

    /// Reset to a fresh window of tokens (history only; the caches are
    /// overwritten by the subsequent captured forward).
    pub(crate) fn reset(&mut self, window: &[i32]) {
        debug_assert!(window.len() <= self.ctx);
        *self.len = 0;
        self.history.clear();
        self.history.extend(window.iter().copied());
    }

    /// Append a token to the history ring, evicting the oldest entry
    /// once the ring holds `ctx` tokens. Never reallocates: the ring is
    /// built with `ctx` capacity.
    pub(crate) fn push_history(&mut self, tok: i32) {
        if self.history.len() == self.ctx {
            self.history.pop_front();
        }
        self.history.push_back(tok);
    }

    /// The current token window, oldest first (eviction re-encode only
    /// — the steady-state step never calls this).
    pub(crate) fn history_vec(&self) -> Vec<i32> {
        self.history.iter().copied().collect()
    }
}

/// Split borrows of a **paged** session's fields, so the engine can
/// sequence its phases (serial block allocation → parallel compute over
/// a shared `&KvPool` → serial encoded commit) without fighting the
/// borrow checker.
pub(crate) struct PagedParts<'a> {
    pub pool: &'a mut KvPool,
    pub tables: &'a mut [Vec<u32>],
    pub len: &'a mut [usize],
    pub history: &'a mut [VecDeque<i32>],
    pub scratch: &'a mut [RowScratch],
}

impl DecodeSession {
    /// Fresh **dense** session for `b` rows of `cfg`'s geometry; caches
    /// zeroed, every row empty until [`NativeModel::prefill`] fills it.
    ///
    /// [`NativeModel::prefill`]: super::NativeModel::prefill
    pub fn new(cfg: &ModelConfig, b: usize) -> DecodeSession {
        let elems = b * cfg.n_layer * cfg.n_head * cfg.ctx * cfg.head_dim();
        DecodeSession {
            b,
            ctx: cfg.ctx,
            n_layer: cfg.n_layer,
            n_head: cfg.n_head,
            head_dim: cfg.head_dim(),
            store: KvBacking::Dense { k: vec![0.0; elems], v: vec![0.0; elems] },
            len: vec![0; b],
            history: (0..b).map(|_| VecDeque::with_capacity(cfg.ctx)).collect(),
            scratch: (0..b).map(|_| RowScratch::new(cfg, false)).collect(),
        }
    }

    /// Fresh **paged** session: `b` row slots over a shared block pool
    /// sized by `kv` (dtype, block size, byte budget — see
    /// [`KvCacheConfig`]). Row capacity is bounded by the pool, not by
    /// `b`: a row only holds the blocks its cached tokens need.
    pub fn new_paged(
        cfg: &ModelConfig,
        b: usize,
        kv: &KvCacheConfig,
    ) -> Result<DecodeSession> {
        let pool = KvPool::new(cfg, kv, b)?;
        Ok(DecodeSession {
            b,
            ctx: cfg.ctx,
            n_layer: cfg.n_layer,
            n_head: cfg.n_head,
            head_dim: cfg.head_dim(),
            store: KvBacking::Paged {
                pool,
                tables: (0..b).map(|_| Vec::new()).collect(),
            },
            len: vec![0; b],
            history: (0..b).map(|_| VecDeque::with_capacity(cfg.ctx)).collect(),
            scratch: (0..b).map(|_| RowScratch::new(cfg, true)).collect(),
        })
    }

    /// Number of rows in the batch.
    pub fn batch(&self) -> usize {
        self.b
    }

    /// Valid cached positions for row `r`.
    pub fn len_of(&self, r: usize) -> usize {
        self.len[r]
    }

    /// Whether this session runs over the paged block pool.
    pub fn is_paged(&self) -> bool {
        matches!(self.store, KvBacking::Paged { .. })
    }

    /// Pool occupancy gauges (None for dense sessions).
    pub fn kv_stats(&self) -> Option<KvStats> {
        match &self.store {
            KvBacking::Paged { pool, .. } => Some(pool.stats()),
            KvBacking::Dense { .. } => None,
        }
    }

    /// Free blocks in the paged pool (None for dense sessions).
    pub fn kv_free_blocks(&self) -> Option<usize> {
        match &self.store {
            KvBacking::Paged { pool, .. } => Some(pool.free_blocks()),
            KvBacking::Dense { .. } => None,
        }
    }

    /// Blocks `tokens` cached positions occupy (None for dense).
    pub fn kv_blocks_for(&self, tokens: usize) -> Option<usize> {
        match &self.store {
            KvBacking::Paged { pool, .. } => {
                Some(pool.blocks_for(tokens.clamp(1, self.ctx)))
            }
            KvBacking::Dense { .. } => None,
        }
    }

    /// Worst-case fresh blocks the next `decode_step_active` over
    /// `active` needs: one per row crossing into a new block, plus the
    /// CoW moves of rows about to window-re-encode. The scheduler
    /// preempts until `kv_free_blocks() >= paged_step_demand(..)`,
    /// which makes the step itself infallible on memory. Always 0 for
    /// dense sessions.
    ///
    /// Re-encode accounting is per *block*, not per row: a block with
    /// `n` references held by `k` re-encoding rows costs `k` fresh
    /// blocks while an outside holder keeps it alive, but only `k - 1`
    /// when the re-encoders are its only holders — the last one
    /// overwrites in place. Counting per row instead would double-bill
    /// co-evicting sharers and trigger spurious preemptions.
    pub fn paged_step_demand(&self, active: &[bool]) -> usize {
        let KvBacking::Paged { pool, tables } = &self.store else {
            return 0;
        };
        let bt = pool.block_tokens();
        let mut need = 0;
        // shared block -> number of re-encoding rows referencing it
        let mut evicting_refs: std::collections::HashMap<u32, usize> =
            std::collections::HashMap::new();
        for (r, &a) in active.iter().enumerate().take(self.b) {
            if !a {
                continue;
            }
            let len = self.len[r];
            if len == self.ctx {
                for &blk in &tables[r] {
                    if pool.is_shared(blk) {
                        *evicting_refs.entry(blk).or_insert(0) += 1;
                    }
                }
            } else if len == tables[r].len() * bt {
                need += 1;
            } else if pool.is_shared(tables[r][len / bt]) {
                // defensive: a mid-block write target is never shared
                // today (only *full* blocks enter the prefix registry,
                // and a row's partial tail block is its own), but the
                // engine's CoW resolve for that case must stay budgeted
                // so the step remains infallible if that ever changes
                need += 1;
            }
        }
        for (blk, k) in evicting_refs {
            need += k.min(pool.refcount(blk) as usize - 1);
        }
        need
    }

    /// Worst-case fresh blocks appending `extra` tokens to row `r`
    /// needs (the multi-token twin of [`DecodeSession::paged_step_demand`]):
    /// one per block boundary the append crosses, plus a CoW
    /// privatization if the current tail block is shared. Used by the
    /// scheduler to budget chunked-prefill advances and speculative
    /// verify extensions before running them, so the extension itself
    /// stays infallible on memory. Always 0 for dense sessions.
    pub fn paged_extend_demand(&self, r: usize, extra: usize) -> usize {
        let KvBacking::Paged { pool, tables } = &self.store else {
            return 0;
        };
        let len = self.len[r];
        let have = tables[r].len();
        let target = (len + extra).min(self.ctx).max(1);
        let mut need = pool.blocks_for(target).saturating_sub(have);
        // a mid-block first write into a still-shared tail block costs
        // one CoW copy (defensive: partial tails are private today, but
        // the resolve stays budgeted — see paged_step_demand)
        if extra > 0 && len < have * pool.block_tokens() {
            let bt = pool.block_tokens();
            if pool.is_shared(tables[r][len / bt]) {
                need += 1;
            }
        }
        need
    }

    /// Roll row `r` back to `new_len` cached positions, discarding the
    /// most recent `len - new_len` tokens from the cache and history
    /// ring — the KV rollback contract of speculative decoding: a
    /// verify extension appends K+1 draft positions, then the scheduler
    /// rolls back past the accepted prefix. Paged rows release the
    /// blocks past the new boundary (extension blocks are never
    /// registered for prefix sharing, so no registry entries go stale).
    ///
    /// Only valid while the row has not window-re-encoded since the
    /// tokens being discarded were appended (`history.len() == len`,
    /// which holds whenever `len < ctx` throughout the append) — the
    /// scheduler guarantees this by never speculating within K+1 tokens
    /// of the context edge.
    pub fn rollback_row(&mut self, r: usize, new_len: usize) {
        let cur = self.len[r];
        assert!(
            new_len >= 1 && new_len <= cur,
            "rollback_row: new_len {new_len} outside 1..={cur}"
        );
        assert_eq!(
            self.history[r].len(),
            cur,
            "rollback_row after a window re-encode is not representable"
        );
        for _ in new_len..cur {
            self.history[r].pop_back();
        }
        self.len[r] = new_len;
        if let KvBacking::Paged { pool, tables } = &mut self.store {
            let keep = pool.blocks_for(new_len);
            while tables[r].len() > keep {
                let blk = tables[r].pop().expect("table shrinks past keep");
                pool.release(blk);
            }
        }
    }

    /// Clear one row back to the empty state (length zero, empty
    /// history) without touching any other row — the slot-lifecycle
    /// seam of the continuous-batching scheduler: a finished request
    /// frees its slot (and, when paged, returns its block references to
    /// the pool) in O(blocks), and the next
    /// [`NativeModel::prefill_rows`] overwrites the row in place.
    ///
    /// [`NativeModel::prefill_rows`]: super::NativeModel::prefill_rows
    pub fn reset_row(&mut self, r: usize) {
        self.len[r] = 0;
        self.history[r].clear();
        if let KvBacking::Paged { pool, tables } = &mut self.store {
            for blk in tables[r].drain(..) {
                pool.release(blk);
            }
        }
    }

    /// Split borrows for the paged engine phases (None for dense).
    pub(crate) fn paged_parts(&mut self) -> Option<PagedParts<'_>> {
        match &mut self.store {
            KvBacking::Paged { pool, tables } => Some(PagedParts {
                pool,
                tables,
                len: &mut self.len,
                history: &mut self.history,
                scratch: &mut self.scratch,
            }),
            KvBacking::Dense { .. } => None,
        }
    }

    /// Split the session into disjoint per-row mutable views — the unit
    /// of parallelism for **dense** batched prefill and decode. Paged
    /// sessions never take this path (their rows share the pool).
    pub(crate) fn rows_mut(&mut self) -> Vec<RowMut<'_>> {
        let per = self.n_layer * self.n_head * self.ctx * self.head_dim;
        let (ctx, n_head, head_dim) = (self.ctx, self.n_head, self.head_dim);
        let KvBacking::Dense { k, v } = &mut self.store else {
            unreachable!("rows_mut on a paged session");
        };
        let mut rows = Vec::with_capacity(self.b);
        for ((((k, v), len), history), scratch) in k
            .chunks_mut(per)
            .zip(v.chunks_mut(per))
            .zip(self.len.iter_mut())
            .zip(self.history.iter_mut())
            .zip(self.scratch.iter_mut())
        {
            rows.push(RowMut {
                ctx,
                n_head,
                head_dim,
                k,
                v,
                len,
                history,
                scratch,
            });
        }
        rows
    }

    #[cfg(test)]
    fn dense_kv(&self) -> (&[f32], &[f32]) {
        match &self.store {
            KvBacking::Dense { k, v } => (k, v),
            KvBacking::Paged { .. } => panic!("dense_kv on a paged session"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KvDtype;

    #[test]
    fn fresh_session_geometry() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let s = DecodeSession::new(&cfg, 3);
        assert_eq!(s.batch(), 3);
        assert!(!s.is_paged());
        let (k, v) = s.dense_kv();
        assert_eq!(
            k.len(),
            3 * cfg.n_layer * cfg.n_head * cfg.ctx * cfg.head_dim()
        );
        assert_eq!(k.len(), v.len());
        for r in 0..3 {
            assert_eq!(s.len_of(r), 0);
        }
        // scratch arenas pre-sized for the zero-alloc decode step
        for sc in &s.scratch {
            assert_eq!(sc.x.len(), cfg.n_embd);
            assert_eq!(sc.qkv.len(), 3 * cfg.n_embd);
            assert_eq!(sc.srow.len(), cfg.ctx);
            assert_eq!(sc.hid.len(), 4 * cfg.n_embd);
            // dense rows carry no paged buffers
            assert!(sc.staged_k.is_empty() && sc.kgath.is_empty());
        }
    }

    #[test]
    fn fresh_paged_session_geometry() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let kv = KvCacheConfig { block_tokens: 16, ..KvCacheConfig::default() };
        let s = DecodeSession::new_paged(&cfg, 3, &kv).unwrap();
        assert!(s.is_paged());
        let st = s.kv_stats().unwrap();
        // budgetless pool: 3 rows * (64 / 16) blocks, all free
        assert_eq!(st.total_blocks, 12);
        assert_eq!(st.free_blocks, 12);
        assert_eq!(st.shared_blocks, 0);
        assert_eq!(st.dtype, KvDtype::F32);
        assert_eq!(s.kv_blocks_for(17), Some(2));
        for sc in &s.scratch {
            assert_eq!(
                sc.staged_k.len(),
                cfg.n_layer * cfg.n_head * cfg.head_dim()
            );
            assert_eq!(sc.kgath.len(), cfg.ctx * cfg.head_dim());
        }
        // no rows cached yet: a step over an all-empty active mask...
        assert_eq!(s.paged_step_demand(&[false, false, false]), 0);
    }

    #[test]
    fn row_views_are_contiguous_and_dense() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let mut s = DecodeSession::new(&cfg, 2);
        let hd = cfg.head_dim();
        let per = cfg.n_layer * cfg.n_head * cfg.ctx * hd;
        let rows = s.rows_mut();
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert_eq!(row.k.len(), per);
            assert_eq!(row.v.len(), per);
            // kv_start covers the row's block densely and disjointly
            let mut seen = std::collections::BTreeSet::new();
            for l in 0..cfg.n_layer {
                for h in 0..cfg.n_head {
                    for slot in 0..cfg.ctx {
                        let start = row.kv_start(l, h, slot);
                        assert!(start + hd <= per);
                        assert!(seen.insert(start), "overlap at {start}");
                    }
                }
            }
            assert_eq!(seen.len() * hd, per);
        }
    }

    #[test]
    fn row_writes_land_in_their_own_block() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let mut s = DecodeSession::new(&cfg, 2);
        {
            let mut rows = s.rows_mut();
            rows[0].k[0] = 1.0;
            let last = rows[1].k.len() - 1;
            rows[1].k[last] = 2.0;
            *rows[1].len = 5;
        }
        let (k, _) = s.dense_kv();
        assert_eq!(k[0], 1.0);
        assert_eq!(*k.last().unwrap(), 2.0);
        assert_eq!(s.len_of(0), 0);
        assert_eq!(s.len_of(1), 5);
    }

    #[test]
    fn reset_row_clears_only_that_row() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let mut s = DecodeSession::new(&cfg, 2);
        {
            let mut rows = s.rows_mut();
            rows[0].reset(&[1, 2, 3]);
            *rows[0].len = 3;
            rows[1].reset(&[7, 8]);
            *rows[1].len = 2;
        }
        s.reset_row(0);
        assert_eq!(s.len_of(0), 0);
        assert!(s.history[0].is_empty());
        // the neighboring in-flight row is untouched
        assert_eq!(s.len_of(1), 2);
        assert_eq!(s.history[1].iter().copied().collect::<Vec<_>>(), vec![7, 8]);
    }

    #[test]
    fn paged_reset_row_releases_blocks() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let kv = KvCacheConfig::default();
        let mut s = DecodeSession::new_paged(&cfg, 2, &kv).unwrap();
        {
            let parts = s.paged_parts().unwrap();
            let blk = parts.pool.alloc().unwrap();
            parts.tables[0].push(blk);
            parts.len[0] = 3;
            parts.history[0].extend([1, 2, 3]);
        }
        assert_eq!(s.kv_stats().unwrap().used_blocks, 1);
        s.reset_row(0);
        assert_eq!(s.len_of(0), 0);
        assert_eq!(s.kv_stats().unwrap().used_blocks, 0);
        assert_eq!(
            s.kv_free_blocks().unwrap(),
            s.kv_stats().unwrap().total_blocks
        );
    }

    #[test]
    fn rollback_row_truncates_len_and_history() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let mut s = DecodeSession::new(&cfg, 2);
        {
            let mut rows = s.rows_mut();
            rows[0].reset(&[1, 2, 3, 4, 5]);
            *rows[0].len = 5;
            rows[1].reset(&[9]);
            *rows[1].len = 1;
        }
        s.rollback_row(0, 2);
        assert_eq!(s.len_of(0), 2);
        assert_eq!(s.history[0].iter().copied().collect::<Vec<_>>(), vec![1, 2]);
        // neighbor untouched
        assert_eq!(s.len_of(1), 1);
        // no-op rollback
        s.rollback_row(0, 2);
        assert_eq!(s.len_of(0), 2);
    }

    #[test]
    fn paged_rollback_releases_trailing_blocks() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let kv = KvCacheConfig { block_tokens: 4, ..KvCacheConfig::default() };
        let mut s = DecodeSession::new_paged(&cfg, 1, &kv).unwrap();
        {
            let parts = s.paged_parts().unwrap();
            for _ in 0..3 {
                let blk = parts.pool.alloc().unwrap();
                parts.tables[0].push(blk);
            }
            parts.len[0] = 10; // 3 blocks of 4 tokens, tail partial
            parts.history[0].extend(0..10);
        }
        assert_eq!(s.kv_stats().unwrap().used_blocks, 3);
        // roll back within the middle block: trailing block released
        s.rollback_row(0, 6);
        assert_eq!(s.len_of(0), 6);
        assert_eq!(s.kv_stats().unwrap().used_blocks, 2);
        assert_eq!(s.history[0].len(), 6);
        // roll back to a block boundary keeps exactly those blocks
        s.rollback_row(0, 4);
        assert_eq!(s.kv_stats().unwrap().used_blocks, 1);
    }

    #[test]
    fn paged_extend_demand_counts_boundary_allocs() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let kv = KvCacheConfig { block_tokens: 4, ..KvCacheConfig::default() };
        let mut s = DecodeSession::new_paged(&cfg, 1, &kv).unwrap();
        // empty row: first chunk of 9 tokens needs 3 blocks
        assert_eq!(s.paged_extend_demand(0, 9), 3);
        {
            let parts = s.paged_parts().unwrap();
            let blk = parts.pool.alloc().unwrap();
            parts.tables[0].push(blk);
            parts.len[0] = 3;
            parts.history[0].extend(0..3);
        }
        // 1 token fits the tail block; 2 cross one boundary; 6 cross two
        assert_eq!(s.paged_extend_demand(0, 1), 0);
        assert_eq!(s.paged_extend_demand(0, 2), 1);
        assert_eq!(s.paged_extend_demand(0, 6), 2);
        // dense sessions never demand blocks
        let dense = DecodeSession::new(&cfg, 1);
        assert_eq!(dense.paged_extend_demand(0, 64), 0);
    }

    #[test]
    fn history_ring_evicts_oldest() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let mut s = DecodeSession::new(&cfg, 1);
        let mut rows = s.rows_mut();
        rows[0].reset(&[1, 2, 3]);
        for t in 4..=(cfg.ctx as i32 + 3) {
            rows[0].push_history(t);
        }
        let h = rows[0].history_vec();
        assert_eq!(h.len(), cfg.ctx);
        assert_eq!(h[0], 4); // 1, 2, 3 evicted
        assert_eq!(*h.last().unwrap(), cfg.ctx as i32 + 3);
    }
}
