//! The pluggable execution backend seam.
//!
//! A [`Backend`] executes *named ops* over [`HostTensor`]s. Op names and
//! I/O contracts follow the AOT artifact entries so the two backends are
//! drop-in interchangeable (DESIGN.md §4):
//!
//! | op               | inputs                         | outputs          |
//! |------------------|--------------------------------|------------------|
//! | `op_consmax`     | scores f32, C f32 (same shape) | probs f32        |
//! | `op_softmax`     | scores f32                     | probs f32        |
//! | `op_softermax`   | scores f32                     | probs f32        |
//! | `op_lut_consmax` | codes i8, C f32 (same shape)   | probs f16        |
//! | `op_consmax_pv`  | scores f32 (q,k), C f32, V f32 | context f32 (q,d)|
//!
//! Normalizers reduce (or, for ConSmax, *don't* reduce — the paper's
//! point) over the last axis.
//!
//! [`NativeBackend`] is always available; the PJRT [`Engine`] joins in
//! under `--features pjrt` and is selected through [`create_backend`].
//!
//! Above the op level, [`NativeModel`] is the native GPT forward, and
//! [`DecodeSession`] + `NativeModel::{prefill, decode_step}` form the
//! KV-cached decode engine that serving runs on (DESIGN.md §Decode
//! seam); `NativeModel::next_logits` stays as the recompute oracle.
//! Score normalization itself is behind the [`Normalizer`] seam
//! (DESIGN.md §Normalizer seam) — one enum resolved at model load that
//! owns the forward kernels, parameter schema, and backward rule of
//! every zoo member — and the `train` module builds the native
//! differentiable training stack on top (DESIGN.md §Training seam).
//!
//! [`Engine`]: crate::runtime::Engine

pub mod decode;
pub mod kvcache;
pub mod model;
pub mod native;
pub mod normalizer;
pub mod simd;
pub mod train;

use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::HostTensor;

pub use decode::DecodeSession;
pub use kvcache::{validate_budget as validate_kv_budget, KvGeometry, KvPool, KvStats};
pub use model::{ExtendLogits, ExtendReq, NativeModel};
pub use native::NativeBackend;
pub use normalizer::{HeadNorm, Normalizer};
pub use train::TrainTape;

/// An execution backend: runs named ops over host tensors.
pub trait Backend {
    /// Short identifier ("native" / "pjrt").
    fn name(&self) -> &'static str;

    /// Human-readable platform description.
    fn platform(&self) -> String;

    /// Whether `op` is available on this backend.
    fn supports(&self, op: &str) -> bool;

    /// All ops this backend can execute.
    fn ops(&self) -> Vec<String>;

    /// Execute one op; returns its outputs.
    fn execute(&self, op: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;
}

/// CLI-facing backend selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    /// Pure-Rust kernels; always available.
    Native,
    /// PJRT over AOT artifacts; needs `--features pjrt` + `make artifacts`.
    Pjrt,
    /// Pjrt when compiled in *and* artifacts exist, otherwise native.
    Auto,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Result<BackendChoice> {
        Ok(match s {
            "native" => BackendChoice::Native,
            "pjrt" => BackendChoice::Pjrt,
            "auto" => BackendChoice::Auto,
            other => bail!("unknown backend {other:?} (native|pjrt|auto)"),
        })
    }
}

/// Instantiate the selected backend.
///
/// `artifacts_dir` is only consulted for the PJRT engine; the native
/// backend needs no on-disk state at all.
pub fn create_backend(
    choice: BackendChoice,
    artifacts_dir: &Path,
) -> Result<Box<dyn Backend>> {
    match choice {
        BackendChoice::Native => Ok(Box::new(NativeBackend::new())),
        BackendChoice::Pjrt => pjrt_backend(artifacts_dir),
        BackendChoice::Auto => {
            if pjrt_available(artifacts_dir) {
                pjrt_backend(artifacts_dir)
            } else {
                Ok(Box::new(NativeBackend::new()))
            }
        }
    }
}

/// Whether the PJRT engine is compiled in AND its artifacts exist.
pub fn pjrt_available(artifacts_dir: &Path) -> bool {
    cfg!(feature = "pjrt") && artifacts_dir.join("manifest.json").exists()
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    Ok(Box::new(crate::runtime::Engine::new(artifacts_dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    bail!(
        "this binary was built without the `pjrt` feature; rebuild with \
         `cargo build --features pjrt` (and run `make artifacts`) or use \
         --backend native"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parses() {
        assert_eq!(BackendChoice::parse("native").unwrap(), BackendChoice::Native);
        assert_eq!(BackendChoice::parse("pjrt").unwrap(), BackendChoice::Pjrt);
        assert_eq!(BackendChoice::parse("auto").unwrap(), BackendChoice::Auto);
        assert!(BackendChoice::parse("tpu").is_err());
    }

    #[test]
    fn auto_without_artifacts_is_native() {
        let b = create_backend(
            BackendChoice::Auto,
            Path::new("/nonexistent/artifacts"),
        )
        .unwrap();
        assert_eq!(b.name(), "native");
    }

    #[test]
    fn native_always_available() {
        let b = create_backend(BackendChoice::Native, Path::new("unused")).unwrap();
        assert!(b.supports("op_consmax"));
        assert!(!b.supports("op_unknown"));
        assert!(b.ops().contains(&"op_softmax".to_string()));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_choice_errors_without_feature() {
        let err = create_backend(BackendChoice::Pjrt, Path::new("artifacts"))
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
