//! Pure-Rust f32 kernels for the paper's score normalizers and the
//! bitwidth-split LUT datapath — the Rust twin of
//! `python/compile/kernels/` (consmax.py / ref.py / lut.py).
//!
//! ConSmax is the only normalizer here with **no reduction over the score
//! axis** — `out[i] = C[i] * exp(s[i])` touches one element at a time —
//! which is exactly why it exists as a streaming kernel on hardware
//! (Fig 4b) and why the native implementation is a single elementwise
//! loop. The softmax/softermax baselines need the whole row (max + sum)
//! before any output; their native forms reduce per row, mirroring the
//! whole-row `BlockSpec` of the Pallas baselines.
//!
//! The LUT op reuses [`BitSplitLut`], so the native backend and the
//! bit-exact hardware model can be cross-validated by construction
//! (`rust/tests/native_backend.rs`).
//!
//! Matrix kernels come in two tiers: [`matmul`] is the naive
//! triple-loop **oracle** (single-threaded, unblocked, kept for tests
//! and the op-level `op_consmax_pv`), while [`matmul_bt`] /
//! [`matmul_bt_into`] are the production kernel — B pre-transposed so
//! both operands stream with unit stride, an 8-lane [`dot`] inner loop
//! from the SIMD microkernel seam (`runtime::backend::simd` — AVX2
//! intrinsics where detected, portable unrolled loops everywhere
//! else, bit-identical by construction), cache blocking over column
//! tiles, and work fanned out over `runtime::parallel`. Thread-count
//! and SIMD level never change results: each output element is one
//! serial [`dot`] with a fixed accumulation order.
//!
//! Every exponential below goes through the seam's dispatched
//! [`simd::exp`] / [`simd::exp2`] (polynomial when SIMD is on, libm
//! when `--simd off`) — except [`consmax`] / [`consmax_train`], which
//! stay on libm as the op-level scalar oracle the approximation is
//! tested against.
//!
//! The `--quant int8` serving path adds two twins (DESIGN.md
//! §Quantization seam): [`matmul_bt_i8_into`] runs the same tiling
//! over per-channel int8 weights with f32 accumulation, and
//! [`attend_consmax_lut`] replaces the attention tail's `C·exp` with a
//! bit-split-LUT table lookup whose probabilities are bit-identical to
//! [`BitSplitLut`] / the RTL simulator.
//!
//! The native training stack (DESIGN.md §Training seam) adds the
//! backward tier: [`matmul_at_b_acc`] (the `dW = x^T @ dy` transpose),
//! [`layer_norm_backward`], [`gelu_grad`], and the shared forward
//! helpers [`layer_norm`] / [`gelu`] the model and the tape-building
//! `forward_train` both call. Each normalizer's own backward rule lives
//! with its enum in `runtime::backend::normalizer`.

use anyhow::{bail, ensure, Result};

use crate::quant::{BitSplitLut, Int8Quantizer, QuantizedMatrix};
use crate::runtime::backend::simd::{self, ExpBase};
use crate::runtime::backend::Backend;
use crate::runtime::{DType, HostTensor};
use crate::util::fp16::F16;

/// The always-available pure-Rust backend.
#[derive(Debug, Default, Clone)]
pub struct NativeBackend;

const OPS: &[&str] = &[
    "op_consmax",
    "op_softmax",
    "op_softermax",
    "op_lut_consmax",
    "op_consmax_pv",
];

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        "native (pure-Rust f32 kernels)".to_string()
    }

    fn supports(&self, op: &str) -> bool {
        OPS.contains(&op)
    }

    fn ops(&self) -> Vec<String> {
        OPS.iter().map(|s| s.to_string()).collect()
    }

    fn execute(&self, op: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        match op {
            "op_consmax" => {
                let [s, c] = two(op, inputs)?;
                ensure!(s.shape == c.shape, "{op}: score/C shape mismatch");
                let out = consmax(&s.as_f32()?, &c.as_f32()?);
                Ok(vec![HostTensor::from_f32(&out, &s.shape)])
            }
            "op_softmax" => {
                let s = one(op, inputs)?;
                let out = softmax_rows(&s.as_f32()?, last_axis(s)?);
                Ok(vec![HostTensor::from_f32(&out, &s.shape)])
            }
            "op_softermax" => {
                let s = one(op, inputs)?;
                let out = softermax_rows(&s.as_f32()?, last_axis(s)?);
                Ok(vec![HostTensor::from_f32(&out, &s.shape)])
            }
            "op_lut_consmax" => {
                let [q, c] = two(op, inputs)?;
                ensure!(q.dtype == DType::I8, "{op}: codes must be int8");
                ensure!(q.shape == c.shape, "{op}: code/C shape mismatch");
                let codes: Vec<i8> =
                    q.data.iter().map(|&b| b as i8).collect();
                let bits = lut_consmax_bits(&codes, &c.as_f32()?);
                Ok(vec![HostTensor::from_f16_bits(&bits, &q.shape)])
            }
            "op_consmax_pv" => {
                let [s, c, v] = three(op, inputs)?;
                ensure!(s.shape == c.shape, "{op}: score/C shape mismatch");
                ensure!(
                    s.shape.len() == 2 && v.shape.len() == 2,
                    "{op}: expects 2-D scores and values"
                );
                let (tq, tk) = (s.shape[0], s.shape[1]);
                ensure!(
                    v.shape[0] == tk,
                    "{op}: V rows {} != score cols {tk}",
                    v.shape[0]
                );
                let d = v.shape[1];
                let probs = consmax(&s.as_f32()?, &c.as_f32()?);
                let out = matmul(&probs, &v.as_f32()?, tq, tk, d);
                Ok(vec![HostTensor::from_f32(&out, &[tq, d])])
            }
            other => bail!("native backend has no op {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// kernels (free functions so `NativeModel` and tests reuse them directly)
// ---------------------------------------------------------------------------

/// ConSmax inference form (paper Eq. 3): `out[i] = C[i] * exp(s[i])`.
/// No max, no sum, no second pass — each element is independent.
pub fn consmax(s: &[f32], c: &[f32]) -> Vec<f32> {
    debug_assert_eq!(s.len(), c.len());
    s.iter().zip(c).map(|(&x, &cc)| cc * x.exp()).collect()
}

/// ConSmax training form (paper Eq. 2): `exp(s - beta) / gamma` with
/// scalar per-call β/γ (per attention head in the model).
pub fn consmax_train(s: &[f32], beta: f32, gamma: f32) -> Vec<f32> {
    s.iter().map(|&x| (x - beta).exp() / gamma).collect()
}

/// Numerically-stable softmax over rows of length `row`.
pub fn softmax_rows(s: &[f32], row: usize) -> Vec<f32> {
    reduce_rows(s, row, ExpBase::E)
}

/// Softermax (base-2 softmax) over rows of length `row`.
pub fn softermax_rows(s: &[f32], row: usize) -> Vec<f32> {
    reduce_rows(s, row, ExpBase::Two)
}

/// In-place numerically-stable softmax over one score row.
pub fn softmax_inplace(row: &mut [f32]) {
    normalize_inplace(row, ExpBase::E);
}

/// In-place softermax (base-2 softmax) over one score row.
pub fn softermax_inplace(row: &mut [f32]) {
    normalize_inplace(row, ExpBase::Two);
}

/// The shared two-pass row reduction: max, then `e(x - m)`, then the
/// sum, then divide — every reduction through the seam's lane helpers
/// ([`simd::max`] / [`simd::sum`]) so there is exactly one reduction
/// implementation to audit, and the exponential through the seam's
/// dispatched [`ExpBase::map`]. Writes probabilities over the scores —
/// no temporary buffer, and a fixed reduction order (a pure function
/// of the row length) so results never depend on how callers
/// partition rows across threads.
fn normalize_inplace(row: &mut [f32], base: ExpBase) {
    let m = simd::max(row);
    if m == f32::NEG_INFINITY {
        // fully-masked row: every score is -inf, so `x - m` would be
        // NaN. The masked-attention convention is an all-zero row
        // (no key receives any weight), matching ConSmax where
        // exp(-inf) = 0 element-wise.
        row.fill(0.0);
        return;
    }
    for x in row.iter_mut() {
        *x -= m;
    }
    base.map(row);
    let sum = simd::sum(row);
    for x in row.iter_mut() {
        *x /= sum;
    }
}

fn reduce_rows(s: &[f32], row: usize, base: ExpBase) -> Vec<f32> {
    assert!(row > 0 && s.len() % row == 0, "bad row length {row}");
    // one output allocation; each row normalized in place within it
    let mut out = s.to_vec();
    for chunk in out.chunks_exact_mut(row) {
        normalize_inplace(chunk, base);
    }
    out
}

/// The INT8 hardware datapath: bitwidth-split LUT exponential × C, all in
/// fp16 (bit pattern output), at the paper's operating point (scale 1/16).
pub fn lut_consmax_bits(q: &[i8], c: &[f32]) -> Vec<u16> {
    debug_assert_eq!(q.len(), c.len());
    let lut = BitSplitLut::paper();
    q.iter()
        .zip(c)
        .map(|(&code, &cc)| lut.consmax(code, F16::from_f32(cc)).to_bits())
        .collect()
}

/// Naive row-major matmul: `a (m,k) @ b (k,n) -> (m,n)`.
///
/// Kept single-threaded and unblocked as the test oracle for
/// [`matmul_bt`]. The inner loop is branch-free: the old
/// `if av == 0.0 { continue; }` skip only paid off on the probs@V call
/// (causal zeros), which the fused streaming PV path in the model now
/// supersedes — and the branch defeated autovectorization everywhere
/// else.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Dot product through the SIMD microkernel seam ([`simd::dot`]):
/// 8 independent lanes (AVX2 registers or portable accumulators), a
/// fixed pairwise horizontal reduce, a serial remainder. The
/// accumulation order is a pure function of the input length — every
/// caller (batched forward, prefill capture, incremental decode, the
/// LM head) sums the same values in the same order at every SIMD
/// level, which is what makes KV-decode logits bitwise identical to
/// the recompute oracle's.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    simd::dot(a, b)
}

/// The one fused ConSmax attention tail, generic over the exponent
/// base — [`attend_consmax`] (base e) and [`attend_consmax2`] (base 2,
/// a shifter in hardware) are thin wrappers over this body. Over a
/// contiguous `[n, head_dim]` K/V region, keys are processed in
/// [`simd::LANES`]-wide blocks: score each key ([`simd::dot`] ×
/// `scale` − β), exponentiate the whole block through the seam's
/// dispatched [`ExpBase::map`] (one vectorizable polynomial stream —
/// bit-equal to exponentiating per key), then PV-accumulate each key
/// into `y` in ascending order. No row max, no denominator sum, no
/// materialized probability row (the paper's reduction-freeness), and
/// the per-key accumulation order is fixed — so both the dense decode
/// path and the paged path (after its per-block gather/dequant) stay
/// bitwise identical to each other and to the streaming forward pass.
#[allow(clippy::too_many_arguments)]
pub fn attend_stream(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    head_dim: usize,
    scale: f32,
    beta: f32,
    gamma: f32,
    base: ExpBase,
    y: &mut [f32],
) {
    debug_assert_eq!(k.len(), v.len());
    debug_assert_eq!(k.len() % head_dim, 0);
    let n = k.len() / head_dim;
    let mut block = [0.0f32; simd::LANES];
    let mut j0 = 0;
    while j0 < n {
        let bn = simd::LANES.min(n - j0);
        for (jj, b) in block[..bn].iter_mut().enumerate() {
            let j = j0 + jj;
            let krow = &k[j * head_dim..(j + 1) * head_dim];
            *b = simd::dot(q, krow) * scale - beta;
        }
        base.map(&mut block[..bn]);
        for (jj, &pe) in block[..bn].iter().enumerate() {
            let pj = pe / gamma;
            let vrow = &v[(j0 + jj) * head_dim..(j0 + jj + 1) * head_dim];
            for (o, &vv) in y.iter_mut().zip(vrow) {
                *o += pj * vv;
            }
        }
        j0 += bn;
    }
}

/// Fused base-e ConSmax attention tail: `p = exp(s − β)/γ` per key.
/// See [`attend_stream`] for the streaming contract.
#[allow(clippy::too_many_arguments)]
pub fn attend_consmax(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    head_dim: usize,
    scale: f32,
    beta: f32,
    gamma: f32,
    y: &mut [f32],
) {
    attend_stream(q, k, v, head_dim, scale, beta, gamma, ExpBase::E, y);
}

/// Int8/LUT ConSmax attention tail (DESIGN.md §Quantization seam):
/// the same fused loop as [`attend_consmax`], but the `C·exp` step
/// runs through the bit-split LUT response `table` — one fp16
/// probability per int8 score code, indexed `code as u8` exactly like
/// `BitSplitLut::response_table` builds it — after quantizing each
/// score onto `quant`'s grid (the paper's 1/16 operating point). Every
/// probability is therefore bit-identical to
/// `BitSplitLut::consmax(code, c)`, the same bits the RTL simulator
/// streams out, before the f32 PV accumulation.
#[allow(clippy::too_many_arguments)]
pub fn attend_consmax_lut(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    head_dim: usize,
    scale: f32,
    quant: &Int8Quantizer,
    table: &[F16; 256],
    y: &mut [f32],
) {
    debug_assert_eq!(k.len(), v.len());
    debug_assert_eq!(k.len() % head_dim, 0);
    let n = k.len() / head_dim;
    for j in 0..n {
        let krow = &k[j * head_dim..(j + 1) * head_dim];
        let code = quant.quantize(dot(q, krow) * scale);
        let pj = table[code as u8 as usize].to_f32();
        let vrow = &v[j * head_dim..(j + 1) * head_dim];
        for (o, &vv) in y.iter_mut().zip(vrow) {
            *o += pj * vv;
        }
    }
}

/// Score pass for the reducing normalizers: `srow[j] = (q · k_j) *
/// scale` over a contiguous `[n, head_dim]` K region (`n ==
/// srow.len()`). The caller normalizes (`softmax_inplace` /
/// `softermax_inplace`) before [`attend_pv`].
pub fn attend_scores(q: &[f32], k: &[f32], head_dim: usize, scale: f32, srow: &mut [f32]) {
    debug_assert_eq!(k.len(), srow.len() * head_dim);
    for (j, o) in srow.iter_mut().enumerate() {
        *o = dot(q, &k[j * head_dim..(j + 1) * head_dim]) * scale;
    }
}

/// PV accumulation: `y += Σ_j probs[j] · v_j` over a contiguous
/// `[n, head_dim]` V region.
pub fn attend_pv(probs: &[f32], v: &[f32], head_dim: usize, y: &mut [f32]) {
    debug_assert_eq!(v.len(), probs.len() * head_dim);
    for (j, &pj) in probs.iter().enumerate() {
        let vrow = &v[j * head_dim..(j + 1) * head_dim];
        for (o, &vv) in y.iter_mut().zip(vrow) {
            *o += pj * vv;
        }
    }
}

/// Fused ConSmax-v2 attention tail: the base-2 twin of
/// [`attend_consmax`] — `p = 2^(s − β)/γ` per key (a shifter instead
/// of `exp` in hardware), sharing the one generic [`attend_stream`]
/// body, so the v2 decode engine inherits the dense/paged bitwise
/// contract unchanged.
#[allow(clippy::too_many_arguments)]
pub fn attend_consmax2(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    head_dim: usize,
    scale: f32,
    beta: f32,
    gamma: f32,
    y: &mut [f32],
) {
    attend_stream(q, k, v, head_dim, scale, beta, gamma, ExpBase::Two, y);
}

/// Tanh-approximate GELU, matching `jax.nn.gelu` (approximate=True).
pub fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)).tanh())
}

/// `d gelu/dx` of the tanh approximation:
/// `0.5(1 + tanh u) + 0.5 x (1 − tanh²u) · u'` with
/// `u = √(2/π)(x + 0.044715 x³)`.
pub fn gelu_grad(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    let u = SQRT_2_OVER_PI * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let du = SQRT_2_OVER_PI * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// Row-wise LayerNorm (population variance, eps 1e-5) matching the JAX
/// model; allocates the output.
pub fn layer_norm(x: &[f32], g: &[f32], b: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    layer_norm_into(x, g, b, d, &mut out);
    out
}

/// [`layer_norm`] into a caller-owned buffer (the zero-allocation
/// decode hot path).
pub fn layer_norm_into(x: &[f32], g: &[f32], b: &[f32], d: usize, out: &mut [f32]) {
    for (row_in, row_out) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let mu = row_in.iter().sum::<f32>() / d as f32;
        let var =
            row_in.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for ((o, &v), (&gg, &bb)) in
            row_out.iter_mut().zip(row_in).zip(g.iter().zip(b))
        {
            *o = (v - mu) * inv * gg + bb;
        }
    }
}

/// Backward through [`layer_norm_into`]: recomputes each row's μ/inv
/// from the saved *input* `x` (cheaper than taping them), writes
/// `∂L/∂x` into `dx` and **accumulates** the gain/bias grads into
/// `dg`/`db` (so stacked rows — and stacked layers — sum into one
/// buffer). With `x̂ = (x − μ)·inv` and `dyg = dy ⊙ g`:
/// `dx = inv · (dyg − mean(dyg) − x̂ · mean(dyg ⊙ x̂))`,
/// `dg += Σ_rows dy ⊙ x̂`, `db += Σ_rows dy`.
pub fn layer_norm_backward(
    x: &[f32],
    g: &[f32],
    dy: &[f32],
    d: usize,
    dx: &mut [f32],
    dg: &mut [f32],
    db: &mut [f32],
) {
    debug_assert_eq!(x.len(), dy.len());
    debug_assert_eq!(x.len(), dx.len());
    debug_assert_eq!(g.len(), d);
    for ((row_x, row_dy), row_dx) in x
        .chunks_exact(d)
        .zip(dy.chunks_exact(d))
        .zip(dx.chunks_exact_mut(d))
    {
        let mu = row_x.iter().sum::<f32>() / d as f32;
        let var =
            row_x.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let mut m1 = 0.0f32; // mean(dy ⊙ g)
        let mut m2 = 0.0f32; // mean(dy ⊙ g ⊙ x̂)
        for ((&xv, &dyv), &gv) in row_x.iter().zip(row_dy).zip(g.iter()) {
            let xh = (xv - mu) * inv;
            let dyg = dyv * gv;
            m1 += dyg;
            m2 += dyg * xh;
        }
        m1 /= d as f32;
        m2 /= d as f32;
        for ((((o, &xv), &dyv), &gv), (dgv, dbv)) in row_dx
            .iter_mut()
            .zip(row_x)
            .zip(row_dy)
            .zip(g.iter())
            .zip(dg.iter_mut().zip(db.iter_mut()))
        {
            let xh = (xv - mu) * inv;
            *o = inv * (dyv * gv - m1 - xh * m2);
            *dgv += dyv * xh;
            *dbv += dyv;
        }
    }
}

/// `out += a^T @ b` with `a (k, m)` and `b (k, n)` row-major — the
/// weight-gradient kernel (`dW = x^T @ dy`). The `kk`-outer loop order
/// streams both operands and the output row with unit stride, and the
/// accumulation lets stacked layers (and micro-batches) sum into one
/// gradient buffer.
pub fn matmul_at_b_acc(
    a: &[f32],
    b: &[f32],
    k: usize,
    m: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// Transpose a row-major `(rows, cols)` matrix into `(cols, rows)` —
/// how `NativeModel` pre-packs its weight matrices once at load so
/// every matmul runs over unit-stride rows of both operands.
pub fn transpose(m: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(m.len(), rows * cols);
    let mut out = vec![0.0f32; m.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = m[r * cols + c];
        }
    }
    out
}

/// `a (m,k) @ bt^T -> (m,n)` where `bt` is B **pre-transposed** to
/// `(n,k)` row-major: the cache-blocked, multi-accumulator production
/// kernel. See [`matmul_bt_into`].
pub fn matmul_bt(a: &[f32], bt: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_bt_into(a, bt, m, k, n, &mut out);
    out
}

/// Multiply-accumulate count below which forking workers costs more
/// than it saves. Scoped spawn+join runs tens of microseconds, so the
/// bar is high enough that single-row decode-time matmuls at small
/// model sizes stay serial while prefill/eval-sized calls fan out.
const PAR_MIN_MACS: usize = 1 << 18;

/// Output-column tile width: one tile of `bt` rows stays hot in cache
/// while a block of `a` rows streams over it.
const COL_TILE: usize = 32;

/// [`matmul_bt`] into a caller-owned buffer (the zero-allocation decode
/// hot path). Both operands are read with unit stride ([`dot`]), the
/// output is cache-blocked over column tiles, and the work is
/// partitioned across the worker pool — by output rows when there are
/// several, by output columns for single-row (decode-time) calls. Every
/// output element is one serial [`dot`], so results are bit-identical
/// for every thread count.
pub fn matmul_bt_into(
    a: &[f32],
    bt: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(bt.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    if out.is_empty() {
        return;
    }
    let threads = crate::runtime::parallel::current_threads();
    if threads <= 1 || m * k * n < PAR_MIN_MACS {
        matmul_bt_block(a, bt, k, n, out);
        return;
    }
    if m == 1 {
        // one output row: partition its columns (the LM-head shape)
        crate::runtime::parallel::par_row_blocks(out, 1, |j0, cols| {
            for (jj, o) in cols.iter_mut().enumerate() {
                let j = j0 + jj;
                *o = dot(a, &bt[j * k..(j + 1) * k]);
            }
        });
    } else {
        crate::runtime::parallel::par_row_blocks(out, n, |i0, rows| {
            let m_block = rows.len() / n;
            matmul_bt_block(&a[i0 * k..(i0 + m_block) * k], bt, k, n, rows);
        });
    }
}

/// Serial cache-blocked core: out rows × column tiles of `bt`.
fn matmul_bt_block(a: &[f32], bt: &[f32], k: usize, n: usize, out: &mut [f32]) {
    let m = out.len() / n;
    let mut jb = 0;
    while jb < n {
        let je = (jb + COL_TILE).min(n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n + jb..i * n + je];
            for (o, j) in orow.iter_mut().zip(jb..je) {
                *o = dot(arow, &bt[j * k..(j + 1) * k]);
            }
        }
        jb = je;
    }
}

/// [`dot`] against int8 codes through the seam ([`simd::dot_i8`]):
/// each code is widened to f32 in the multiply; the per-channel scale
/// is applied once by the caller, after the reduction. Same 8-lane
/// layout and accumulation order as [`dot`] at every SIMD level, so
/// int8 matmul results are thread-count invariant too.
#[inline]
pub fn dot_i8(a: &[f32], q: &[i8]) -> f32 {
    simd::dot_i8(a, q)
}

/// [`matmul_bt_into`] against per-channel int8 weights:
/// `a (m,k) @ qm^T -> (m,n)` where `qm` holds B pre-transposed to
/// `(n,k)` row-major i8 codes with one power-of-two scale per output
/// channel, so `out[i,j] = scales[j] * Σ_p a[i,p] · q[j,p]` with the
/// reduction in f32 ([`dot_i8`]). Same cache blocking, parallel
/// partitioning, and serial per-element order as the f32 production
/// kernel — results are bit-identical at every thread count.
pub fn matmul_bt_i8_into(
    a: &[f32],
    qm: &QuantizedMatrix,
    m: usize,
    out: &mut [f32],
) {
    let (k, n) = (qm.din, qm.dout);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m * n);
    if out.is_empty() {
        return;
    }
    let threads = crate::runtime::parallel::current_threads();
    if threads <= 1 || m * k * n < PAR_MIN_MACS {
        matmul_bt_i8_block(a, qm, k, n, out);
        return;
    }
    if m == 1 {
        // one output row: partition its columns (the LM-head shape)
        crate::runtime::parallel::par_row_blocks(out, 1, |j0, cols| {
            for (jj, o) in cols.iter_mut().enumerate() {
                let j = j0 + jj;
                *o = qm.scales[j] * dot_i8(a, qm.row(j));
            }
        });
    } else {
        crate::runtime::parallel::par_row_blocks(out, n, |i0, rows| {
            let m_block = rows.len() / n;
            matmul_bt_i8_block(&a[i0 * k..(i0 + m_block) * k], qm, k, n, rows);
        });
    }
}

/// Serial cache-blocked core of [`matmul_bt_i8_into`].
fn matmul_bt_i8_block(
    a: &[f32],
    qm: &QuantizedMatrix,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    let m = out.len() / n;
    let mut jb = 0;
    while jb < n {
        let je = (jb + COL_TILE).min(n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n + jb..i * n + je];
            for (o, j) in orow.iter_mut().zip(jb..je) {
                *o = qm.scales[j] * dot_i8(arow, qm.row(j));
            }
        }
        jb = je;
    }
}

fn one<'a>(op: &str, inputs: &'a [HostTensor]) -> Result<&'a HostTensor> {
    ensure!(inputs.len() == 1, "{op}: expected 1 inputs, got {}", inputs.len());
    Ok(&inputs[0])
}

fn two<'a>(op: &str, inputs: &'a [HostTensor]) -> Result<[&'a HostTensor; 2]> {
    ensure!(inputs.len() == 2, "{op}: expected 2 inputs, got {}", inputs.len());
    Ok([&inputs[0], &inputs[1]])
}

fn three<'a>(op: &str, inputs: &'a [HostTensor]) -> Result<[&'a HostTensor; 3]> {
    ensure!(inputs.len() == 3, "{op}: expected 3 inputs, got {}", inputs.len());
    Ok([&inputs[0], &inputs[1], &inputs[2]])
}

fn last_axis(t: &HostTensor) -> Result<usize> {
    match t.shape.last() {
        Some(&n) if n > 0 => Ok(n),
        _ => bail!("normalizer needs a non-empty last axis, got {:?}", t.shape),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::merge_beta_gamma;

    #[test]
    fn consmax_is_elementwise() {
        // permuting inputs permutes outputs identically — no cross-element
        // coupling (the paper's synchronization-freeness, testable!)
        let s = vec![0.5f32, -1.0, 2.0, 0.0];
        let c = vec![0.01f32; 4];
        let a = consmax(&s, &c);
        let s_rev: Vec<f32> = s.iter().rev().cloned().collect();
        let b = consmax(&s_rev, &c);
        let b_rev: Vec<f32> = b.iter().rev().cloned().collect();
        assert_eq!(a, b_rev);
    }

    #[test]
    fn consmax_forms_agree() {
        // Eq. 2 == Eq. 3 with C = exp(-beta)/gamma (in f32)
        let (beta, gamma) = (1.5f32, 100.0f32);
        let c = (-beta).exp() / gamma;
        let s = vec![-2.0f32, 0.0, 1.0, 3.5];
        let train = consmax_train(&s, beta, gamma);
        let infer = consmax(&s, &vec![c; s.len()]);
        for (a, b) in train.iter().zip(&infer) {
            assert!((a - b).abs() <= 1e-6 * a.abs().max(1e-3), "{a} vs {b}");
        }
    }

    #[test]
    fn softmax_rows_normalize() {
        let s = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        let p = softmax_rows(&s, 3);
        for row in p.chunks(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6, "{sum}");
            assert!(row.windows(2).all(|w| w[0] < w[1])); // monotone inputs
        }
    }

    #[test]
    fn softermax_is_base2() {
        let s = vec![0.0f32, 1.0]; // 2^0=1, 2^1=2 -> 1/3, 2/3
        let p = softermax_rows(&s, 2);
        assert!((p[0] - 1.0 / 3.0).abs() < 1e-6);
        assert!((p[1] - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn fully_masked_rows_are_zero_not_nan() {
        // all -inf scores used to produce NaN (x - m = -inf - -inf);
        // a fully-masked row must come back all-zero instead
        let ninf = f32::NEG_INFINITY;
        let s = vec![ninf, ninf, ninf, 0.0, 1.0, ninf];
        for (name, p) in [
            ("softmax", softmax_rows(&s, 3)),
            ("softermax", softermax_rows(&s, 3)),
        ] {
            assert!(p.iter().all(|x| x.is_finite()), "{name}: {p:?}");
            assert_eq!(&p[..3], &[0.0, 0.0, 0.0], "{name}");
            // the live row still normalizes, with the masked tail at 0
            let live: f32 = p[3..].iter().sum();
            assert!((live - 1.0).abs() < 1e-6, "{name}: {live}");
            assert_eq!(p[5], 0.0, "{name}");
        }
    }

    #[test]
    fn masked_neg_inf_scores_vanish_under_consmax() {
        let s = vec![f32::NEG_INFINITY, 0.0];
        let p = consmax(&s, &[0.01, 0.01]);
        assert_eq!(p[0], 0.0);
        assert!(p[1] > 0.0);
    }

    #[test]
    fn lut_op_matches_bit_exact_model() {
        let lut = BitSplitLut::paper();
        let c = merge_beta_gamma(1.5, 100.0);
        let codes: Vec<i8> = (-128i16..=127).map(|q| q as i8).collect();
        let cs = vec![c.to_f32(); codes.len()];
        let bits = lut_consmax_bits(&codes, &cs);
        for (q, b) in codes.iter().zip(&bits) {
            assert_eq!(*b, lut.consmax(*q, c).to_bits(), "q={q}");
        }
    }

    #[test]
    fn backend_execute_roundtrip() {
        let be = NativeBackend::new();
        let s = HostTensor::from_f32(&[0.0, 1.0, -1.0, 0.5], &[2, 2]);
        let c = HostTensor::from_f32(&[0.01; 4], &[2, 2]);
        let out = be.execute("op_consmax", &[s.clone(), c]).unwrap();
        assert_eq!(out[0].shape, vec![2, 2]);
        let vals = out[0].as_f32().unwrap();
        assert!((vals[0] - 0.01).abs() < 1e-7);

        let sm = be.execute("op_softmax", &[s]).unwrap();
        let rows = sm[0].as_f32().unwrap();
        assert!((rows[0] + rows[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn backend_rejects_bad_arity_and_shapes() {
        let be = NativeBackend::new();
        let s = HostTensor::from_f32(&[0.0; 4], &[2, 2]);
        assert!(be.execute("op_consmax", std::slice::from_ref(&s)).is_err());
        let c = HostTensor::from_f32(&[0.0; 2], &[2]);
        assert!(be.execute("op_consmax", &[s, c]).is_err());
    }

    #[test]
    fn pv_fusion_matches_two_step() {
        let be = NativeBackend::new();
        let (tq, tk, d) = (3usize, 4usize, 2usize);
        let s: Vec<f32> = (0..tq * tk).map(|i| (i as f32) * 0.1 - 0.5).collect();
        let c = vec![0.02f32; tq * tk];
        let v: Vec<f32> = (0..tk * d).map(|i| i as f32 * 0.25).collect();
        let fused = be
            .execute(
                "op_consmax_pv",
                &[
                    HostTensor::from_f32(&s, &[tq, tk]),
                    HostTensor::from_f32(&c, &[tq, tk]),
                    HostTensor::from_f32(&v, &[tk, d]),
                ],
            )
            .unwrap();
        let probs = consmax(&s, &c);
        let want = matmul(&probs, &v, tq, tk, d);
        let got = fused[0].as_f32().unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_identity() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0]; // 2x2
        let id = vec![1.0f32, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &id, 2, 2, 2), a);
        assert_eq!(matmul_bt(&a, &id, 2, 2, 2), a); // id^T == id
    }

    #[test]
    fn dot_matches_serial_sum_closely() {
        // lengths around the 8-lane boundary, incl. the remainder path
        for len in [0usize, 1, 7, 8, 9, 31, 64, 100] {
            let a: Vec<f32> = (0..len).map(|i| (i as f32) * 0.25 - 3.0).collect();
            let b: Vec<f32> = (0..len).map(|i| 1.5 - (i as f32) * 0.125).collect();
            let want: f64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| (x as f64) * (y as f64))
                .sum();
            let got = dot(&a, &b) as f64;
            assert!(
                (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                "len {len}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn transpose_roundtrips() {
        let m: Vec<f32> = (0..6).map(|i| i as f32).collect(); // 2x3
        let t = transpose(&m, 2, 3); // 3x2
        assert_eq!(t, vec![0.0, 3.0, 1.0, 4.0, 2.0, 5.0]);
        assert_eq!(transpose(&t, 3, 2), m);
    }

    #[test]
    fn tiled_matmul_matches_naive_oracle() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(3);
        // odd sizes exercise column-tile and unroll remainders
        for (m, k, n) in [(1usize, 64usize, 256usize), (5, 33, 70), (8, 64, 64)] {
            let a = rng.normal_vec_f32(m * k, 0.0, 1.0);
            let b = rng.normal_vec_f32(k * n, 0.0, 1.0);
            let bt = transpose(&b, k, n);
            let want = matmul(&a, &b, m, k, n);
            let got = matmul_bt(&a, &bt, m, k, n);
            assert_eq!(got.len(), want.len());
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                let denom = g.abs().max(w.abs()).max(1.0);
                assert!(
                    (g - w).abs() / denom <= 1e-5,
                    "({m},{k},{n})[{i}]: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn int8_matmul_matches_dequantized_oracle() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(9);
        // the same shape sweep as the f32 tiled kernel, against a
        // float64 oracle over the dequantized codes
        for (m, k, n) in [(1usize, 64usize, 256usize), (5, 33, 70), (8, 64, 64)] {
            let a = rng.normal_vec_f32(m * k, 0.0, 1.0);
            let w = rng.normal_vec_f32(n * k, 0.0, 0.05);
            let qm = QuantizedMatrix::from_rows(&w, n, k);
            let dq = qm.dequantize();
            let mut got = vec![0.0f32; m * n];
            matmul_bt_i8_into(&a, &qm, m, &mut got);
            for i in 0..m {
                for j in 0..n {
                    let want: f64 = (0..k)
                        .map(|p| a[i * k + p] as f64 * dq[j * k + p] as f64)
                        .sum();
                    let g = got[i * n + j] as f64;
                    let denom = g.abs().max(want.abs()).max(1.0);
                    assert!(
                        (g - want).abs() / denom <= 1e-5,
                        "({m},{k},{n})[{i},{j}]: {g} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn dot_i8_matches_widened_dot() {
        // widening each code to f32 and running the f32 dot must agree
        // bit-for-bit (same lane layout, same order)
        for len in [0usize, 1, 7, 8, 9, 31, 64, 100] {
            let a: Vec<f32> = (0..len).map(|i| (i as f32) * 0.25 - 3.0).collect();
            let q: Vec<i8> = (0..len).map(|i| ((i * 37) % 255) as i8).collect();
            let qf: Vec<f32> = q.iter().map(|&c| c as f32).collect();
            assert_eq!(dot_i8(&a, &q).to_bits(), dot(&a, &qf).to_bits(), "len {len}");
        }
    }

    #[test]
    fn attend_consmax_lut_probs_are_lut_bits() {
        // the LUT tail must accumulate exactly the fp16 probabilities
        // BitSplitLut::consmax emits for the quantized scores
        let (n, hd) = (6usize, 4usize);
        let q: Vec<f32> = (0..hd).map(|i| 0.4 - 0.15 * i as f32).collect();
        let k: Vec<f32> = (0..n * hd).map(|i| (i as f32) * 0.09 - 0.5).collect();
        let v: Vec<f32> = (0..n * hd).map(|i| 1.0 - (i as f32) * 0.03).collect();
        let scale = 0.5f32;
        let quant = Int8Quantizer::paper();
        let lut = BitSplitLut::paper();
        let c = merge_beta_gamma(1.5, 100.0);
        let table = lut.response_table(c);

        let mut got = vec![0.0f32; hd];
        attend_consmax_lut(&q, &k, &v, hd, scale, &quant, &table, &mut got);

        let mut want = vec![0.0f32; hd];
        for j in 0..n {
            let code = quant.quantize(dot(&q, &k[j * hd..(j + 1) * hd]) * scale);
            let pj = lut.consmax(code, c).to_f32();
            for (o, &vv) in want.iter_mut().zip(&v[j * hd..(j + 1) * hd]) {
                *o += pj * vv;
            }
        }
        assert_eq!(got, want); // bit-identical, not just close
    }

    #[test]
    fn attend_helpers_match_reference_loops() {
        let (n, hd) = (5usize, 4usize);
        let q: Vec<f32> = (0..hd).map(|i| 0.3 - 0.1 * i as f32).collect();
        let k: Vec<f32> = (0..n * hd).map(|i| (i as f32) * 0.07 - 0.4).collect();
        let v: Vec<f32> = (0..n * hd).map(|i| 1.0 - (i as f32) * 0.05).collect();
        let (scale, beta, gamma) = (0.5f32, 1.5f32, 100.0f32);

        // consmax: fused loop == scores -> C*exp -> PV, bit for bit
        // (the reference loop uses the same dispatched simd::exp the
        // fused tail runs on, so the assert stays bitwise at any level)
        let mut srow = vec![0.0f32; n];
        attend_scores(&q, &k, hd, scale, &mut srow);
        let mut want = vec![0.0f32; hd];
        for j in 0..n {
            let pj = simd::exp(srow[j] - beta) / gamma;
            for (o, &vv) in want.iter_mut().zip(&v[j * hd..(j + 1) * hd]) {
                *o += pj * vv;
            }
        }
        let mut got = vec![0.0f32; hd];
        attend_consmax(&q, &k, &v, hd, scale, beta, gamma, &mut got);
        assert_eq!(got, want);

        // softmax: scores -> normalize -> PV matches the manual loop
        let mut probs = srow.clone();
        softmax_inplace(&mut probs);
        let mut pv = vec![0.0f32; hd];
        attend_pv(&probs, &v, hd, &mut pv);
        let mut pv_want = vec![0.0f32; hd];
        for (j, &pj) in probs.iter().enumerate() {
            for (o, &vv) in pv_want.iter_mut().zip(&v[j * hd..(j + 1) * hd]) {
                *o += pj * vv;
            }
        }
        assert_eq!(pv, pv_want);
        // accumulation: y starts non-zero and is added into
        let mut acc = vec![1.0f32; hd];
        attend_pv(&probs, &v, hd, &mut acc);
        for (a, w) in acc.iter().zip(&pv_want) {
            assert_eq!(*a, 1.0 + w);
        }
    }

    #[test]
    fn attend_consmax2_is_base2_twin() {
        let (n, hd) = (5usize, 4usize);
        let q: Vec<f32> = (0..hd).map(|i| 0.3 - 0.1 * i as f32).collect();
        let k: Vec<f32> = (0..n * hd).map(|i| (i as f32) * 0.07 - 0.4).collect();
        let v: Vec<f32> = (0..n * hd).map(|i| 1.0 - (i as f32) * 0.05).collect();
        let (scale, beta, gamma) = (0.5f32, 1.5f32, 2.0f32);
        let mut srow = vec![0.0f32; n];
        attend_scores(&q, &k, hd, scale, &mut srow);
        let mut want = vec![0.0f32; hd];
        for j in 0..n {
            let pj = simd::exp2(srow[j] - beta) / gamma;
            for (o, &vv) in want.iter_mut().zip(&v[j * hd..(j + 1) * hd]) {
                *o += pj * vv;
            }
        }
        let mut got = vec![0.0f32; hd];
        attend_consmax2(&q, &k, &v, hd, scale, beta, gamma, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn gelu_grad_matches_finite_differences() {
        let h = 1e-3f32;
        for i in -40..=40 {
            let x = i as f32 * 0.1;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            let an = gelu_grad(x);
            assert!((fd - an).abs() <= 1e-3, "x {x}: fd {fd} vs an {an}");
        }
    }

    #[test]
    fn layer_norm_backward_matches_finite_differences() {
        use crate::util::rng::Pcg32;
        let (rows, d) = (3usize, 8usize);
        let mut rng = Pcg32::seeded(5);
        let x = rng.normal_vec_f32(rows * d, 0.0, 1.0);
        let g = rng.normal_vec_f32(d, 1.0, 0.1);
        let b = rng.normal_vec_f32(d, 0.0, 0.1);
        let w = rng.normal_vec_f32(rows * d, 0.0, 1.0); // dL/dy weights
        let loss = |x: &[f32], g: &[f32], b: &[f32]| -> f32 {
            layer_norm(x, g, b, d).iter().zip(&w).map(|(&y, &wv)| y * wv).sum()
        };
        let mut dx = vec![0.0f32; rows * d];
        let mut dg = vec![0.0f32; d];
        let mut db = vec![0.0f32; d];
        layer_norm_backward(&x, &g, &w, d, &mut dx, &mut dg, &mut db);
        let h = 1e-2f32;
        let check = |an: f32, fd: f32, what: &str| {
            assert!(
                (fd - an).abs() <= 1e-3 * fd.abs().max(1.0),
                "{what}: fd {fd} vs an {an}"
            );
        };
        for i in 0..rows * d {
            let mut up = x.clone();
            up[i] += h;
            let mut dn = x.clone();
            dn[i] -= h;
            check(dx[i], (loss(&up, &g, &b) - loss(&dn, &g, &b)) / (2.0 * h), "dx");
        }
        for i in 0..d {
            let mut up = g.clone();
            up[i] += h;
            let mut dn = g.clone();
            dn[i] -= h;
            check(dg[i], (loss(&x, &up, &b) - loss(&x, &dn, &b)) / (2.0 * h), "dg");
            let mut bu = b.clone();
            bu[i] += h;
            let mut bd = b.clone();
            bd[i] -= h;
            check(db[i], (loss(&x, &g, &bu) - loss(&x, &g, &bd)) / (2.0 * h), "db");
        }
    }

    #[test]
    fn matmul_at_b_acc_matches_transposed_oracle() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(13);
        for (k, m, n) in [(7usize, 3usize, 5usize), (16, 8, 8), (1, 4, 2)] {
            let a = rng.normal_vec_f32(k * m, 0.0, 1.0);
            let b = rng.normal_vec_f32(k * n, 0.0, 1.0);
            let at = transpose(&a, k, m); // (m, k)
            let want = matmul(&at, &b, m, k, n);
            let mut got = vec![0.5f32; m * n]; // accumulation base
            matmul_at_b_acc(&a, &b, k, m, n, &mut got);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - 0.5 - w).abs() <= 1e-4 * w.abs().max(1.0),
                    "({k},{m},{n})[{i}]: {} vs {w}",
                    g - 0.5
                );
            }
        }
    }

    #[test]
    fn inplace_normalizers_match_row_variants() {
        let s = vec![0.3f32, -1.0, 2.5, 0.0, 4.0, -2.0];
        for (rows, inplace) in [
            (softmax_rows(&s, 3), softmax_inplace as fn(&mut [f32])),
            (softermax_rows(&s, 3), softermax_inplace as fn(&mut [f32])),
        ] {
            let mut chunks = s.clone();
            for chunk in chunks.chunks_exact_mut(3) {
                inplace(chunk);
            }
            assert_eq!(rows, chunks); // bit-identical, not just close
        }
    }
}
