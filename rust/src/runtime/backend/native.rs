//! Pure-Rust f32 kernels for the paper's score normalizers and the
//! bitwidth-split LUT datapath — the Rust twin of
//! `python/compile/kernels/` (consmax.py / ref.py / lut.py).
//!
//! ConSmax is the only normalizer here with **no reduction over the score
//! axis** — `out[i] = C[i] * exp(s[i])` touches one element at a time —
//! which is exactly why it exists as a streaming kernel on hardware
//! (Fig 4b) and why the native implementation is a single elementwise
//! loop. The softmax/softermax baselines need the whole row (max + sum)
//! before any output; their native forms reduce per row, mirroring the
//! whole-row `BlockSpec` of the Pallas baselines.
//!
//! The LUT op reuses [`BitSplitLut`], so the native backend and the
//! bit-exact hardware model can be cross-validated by construction
//! (`rust/tests/native_backend.rs`).

use anyhow::{bail, ensure, Result};

use crate::quant::BitSplitLut;
use crate::runtime::backend::Backend;
use crate::runtime::{DType, HostTensor};
use crate::util::fp16::F16;

/// The always-available pure-Rust backend.
#[derive(Debug, Default, Clone)]
pub struct NativeBackend;

const OPS: &[&str] = &[
    "op_consmax",
    "op_softmax",
    "op_softermax",
    "op_lut_consmax",
    "op_consmax_pv",
];

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        "native (pure-Rust f32 kernels)".to_string()
    }

    fn supports(&self, op: &str) -> bool {
        OPS.contains(&op)
    }

    fn ops(&self) -> Vec<String> {
        OPS.iter().map(|s| s.to_string()).collect()
    }

    fn execute(&self, op: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        match op {
            "op_consmax" => {
                let [s, c] = two(op, inputs)?;
                ensure!(s.shape == c.shape, "{op}: score/C shape mismatch");
                let out = consmax(&s.as_f32()?, &c.as_f32()?);
                Ok(vec![HostTensor::from_f32(&out, &s.shape)])
            }
            "op_softmax" => {
                let s = one(op, inputs)?;
                let out = softmax_rows(&s.as_f32()?, last_axis(s)?);
                Ok(vec![HostTensor::from_f32(&out, &s.shape)])
            }
            "op_softermax" => {
                let s = one(op, inputs)?;
                let out = softermax_rows(&s.as_f32()?, last_axis(s)?);
                Ok(vec![HostTensor::from_f32(&out, &s.shape)])
            }
            "op_lut_consmax" => {
                let [q, c] = two(op, inputs)?;
                ensure!(q.dtype == DType::I8, "{op}: codes must be int8");
                ensure!(q.shape == c.shape, "{op}: code/C shape mismatch");
                let codes: Vec<i8> =
                    q.data.iter().map(|&b| b as i8).collect();
                let bits = lut_consmax_bits(&codes, &c.as_f32()?);
                Ok(vec![HostTensor::from_f16_bits(&bits, &q.shape)])
            }
            "op_consmax_pv" => {
                let [s, c, v] = three(op, inputs)?;
                ensure!(s.shape == c.shape, "{op}: score/C shape mismatch");
                ensure!(
                    s.shape.len() == 2 && v.shape.len() == 2,
                    "{op}: expects 2-D scores and values"
                );
                let (tq, tk) = (s.shape[0], s.shape[1]);
                ensure!(
                    v.shape[0] == tk,
                    "{op}: V rows {} != score cols {tk}",
                    v.shape[0]
                );
                let d = v.shape[1];
                let probs = consmax(&s.as_f32()?, &c.as_f32()?);
                let out = matmul(&probs, &v.as_f32()?, tq, tk, d);
                Ok(vec![HostTensor::from_f32(&out, &[tq, d])])
            }
            other => bail!("native backend has no op {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// kernels (free functions so `NativeModel` and tests reuse them directly)
// ---------------------------------------------------------------------------

/// ConSmax inference form (paper Eq. 3): `out[i] = C[i] * exp(s[i])`.
/// No max, no sum, no second pass — each element is independent.
pub fn consmax(s: &[f32], c: &[f32]) -> Vec<f32> {
    debug_assert_eq!(s.len(), c.len());
    s.iter().zip(c).map(|(&x, &cc)| cc * x.exp()).collect()
}

/// ConSmax training form (paper Eq. 2): `exp(s - beta) / gamma` with
/// scalar per-call β/γ (per attention head in the model).
pub fn consmax_train(s: &[f32], beta: f32, gamma: f32) -> Vec<f32> {
    s.iter().map(|&x| (x - beta).exp() / gamma).collect()
}

/// Numerically-stable softmax over rows of length `row`.
pub fn softmax_rows(s: &[f32], row: usize) -> Vec<f32> {
    reduce_rows(s, row, f32::exp)
}

/// Softermax (base-2 softmax) over rows of length `row`.
pub fn softermax_rows(s: &[f32], row: usize) -> Vec<f32> {
    reduce_rows(s, row, f32::exp2)
}

fn reduce_rows(s: &[f32], row: usize, e: fn(f32) -> f32) -> Vec<f32> {
    assert!(row > 0 && s.len() % row == 0, "bad row length {row}");
    let mut out = Vec::with_capacity(s.len());
    for chunk in s.chunks_exact(row) {
        let m = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        if m == f32::NEG_INFINITY {
            // fully-masked row: every score is -inf, so `x - m` would be
            // NaN. The masked-attention convention is an all-zero row
            // (no key receives any weight), matching ConSmax where
            // exp(-inf) = 0 element-wise.
            out.resize(out.len() + row, 0.0);
            continue;
        }
        let exps: Vec<f32> = chunk.iter().map(|&x| e(x - m)).collect();
        let sum: f32 = exps.iter().sum();
        out.extend(exps.iter().map(|&x| x / sum));
    }
    out
}

/// The INT8 hardware datapath: bitwidth-split LUT exponential × C, all in
/// fp16 (bit pattern output), at the paper's operating point (scale 1/16).
pub fn lut_consmax_bits(q: &[i8], c: &[f32]) -> Vec<u16> {
    debug_assert_eq!(q.len(), c.len());
    let lut = BitSplitLut::paper();
    q.iter()
        .zip(c)
        .map(|(&code, &cc)| lut.consmax(code, F16::from_f32(cc)).to_bits())
        .collect()
}

/// Naive row-major matmul: `a (m,k) @ b (k,n) -> (m,n)`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

fn one<'a>(op: &str, inputs: &'a [HostTensor]) -> Result<&'a HostTensor> {
    ensure!(inputs.len() == 1, "{op}: expected 1 inputs, got {}", inputs.len());
    Ok(&inputs[0])
}

fn two<'a>(op: &str, inputs: &'a [HostTensor]) -> Result<[&'a HostTensor; 2]> {
    ensure!(inputs.len() == 2, "{op}: expected 2 inputs, got {}", inputs.len());
    Ok([&inputs[0], &inputs[1]])
}

fn three<'a>(op: &str, inputs: &'a [HostTensor]) -> Result<[&'a HostTensor; 3]> {
    ensure!(inputs.len() == 3, "{op}: expected 3 inputs, got {}", inputs.len());
    Ok([&inputs[0], &inputs[1], &inputs[2]])
}

fn last_axis(t: &HostTensor) -> Result<usize> {
    match t.shape.last() {
        Some(&n) if n > 0 => Ok(n),
        _ => bail!("normalizer needs a non-empty last axis, got {:?}", t.shape),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::merge_beta_gamma;

    #[test]
    fn consmax_is_elementwise() {
        // permuting inputs permutes outputs identically — no cross-element
        // coupling (the paper's synchronization-freeness, testable!)
        let s = vec![0.5f32, -1.0, 2.0, 0.0];
        let c = vec![0.01f32; 4];
        let a = consmax(&s, &c);
        let s_rev: Vec<f32> = s.iter().rev().cloned().collect();
        let b = consmax(&s_rev, &c);
        let b_rev: Vec<f32> = b.iter().rev().cloned().collect();
        assert_eq!(a, b_rev);
    }

    #[test]
    fn consmax_forms_agree() {
        // Eq. 2 == Eq. 3 with C = exp(-beta)/gamma (in f32)
        let (beta, gamma) = (1.5f32, 100.0f32);
        let c = (-beta).exp() / gamma;
        let s = vec![-2.0f32, 0.0, 1.0, 3.5];
        let train = consmax_train(&s, beta, gamma);
        let infer = consmax(&s, &vec![c; s.len()]);
        for (a, b) in train.iter().zip(&infer) {
            assert!((a - b).abs() <= 1e-6 * a.abs().max(1e-3), "{a} vs {b}");
        }
    }

    #[test]
    fn softmax_rows_normalize() {
        let s = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        let p = softmax_rows(&s, 3);
        for row in p.chunks(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6, "{sum}");
            assert!(row.windows(2).all(|w| w[0] < w[1])); // monotone inputs
        }
    }

    #[test]
    fn softermax_is_base2() {
        let s = vec![0.0f32, 1.0]; // 2^0=1, 2^1=2 -> 1/3, 2/3
        let p = softermax_rows(&s, 2);
        assert!((p[0] - 1.0 / 3.0).abs() < 1e-6);
        assert!((p[1] - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn fully_masked_rows_are_zero_not_nan() {
        // all -inf scores used to produce NaN (x - m = -inf - -inf);
        // a fully-masked row must come back all-zero instead
        let ninf = f32::NEG_INFINITY;
        let s = vec![ninf, ninf, ninf, 0.0, 1.0, ninf];
        for (name, p) in [
            ("softmax", softmax_rows(&s, 3)),
            ("softermax", softermax_rows(&s, 3)),
        ] {
            assert!(p.iter().all(|x| x.is_finite()), "{name}: {p:?}");
            assert_eq!(&p[..3], &[0.0, 0.0, 0.0], "{name}");
            // the live row still normalizes, with the masked tail at 0
            let live: f32 = p[3..].iter().sum();
            assert!((live - 1.0).abs() < 1e-6, "{name}: {live}");
            assert_eq!(p[5], 0.0, "{name}");
        }
    }

    #[test]
    fn masked_neg_inf_scores_vanish_under_consmax() {
        let s = vec![f32::NEG_INFINITY, 0.0];
        let p = consmax(&s, &[0.01, 0.01]);
        assert_eq!(p[0], 0.0);
        assert!(p[1] > 0.0);
    }

    #[test]
    fn lut_op_matches_bit_exact_model() {
        let lut = BitSplitLut::paper();
        let c = merge_beta_gamma(1.5, 100.0);
        let codes: Vec<i8> = (-128i16..=127).map(|q| q as i8).collect();
        let cs = vec![c.to_f32(); codes.len()];
        let bits = lut_consmax_bits(&codes, &cs);
        for (q, b) in codes.iter().zip(&bits) {
            assert_eq!(*b, lut.consmax(*q, c).to_bits(), "q={q}");
        }
    }

    #[test]
    fn backend_execute_roundtrip() {
        let be = NativeBackend::new();
        let s = HostTensor::from_f32(&[0.0, 1.0, -1.0, 0.5], &[2, 2]);
        let c = HostTensor::from_f32(&[0.01; 4], &[2, 2]);
        let out = be.execute("op_consmax", &[s.clone(), c]).unwrap();
        assert_eq!(out[0].shape, vec![2, 2]);
        let vals = out[0].as_f32().unwrap();
        assert!((vals[0] - 0.01).abs() < 1e-7);

        let sm = be.execute("op_softmax", &[s]).unwrap();
        let rows = sm[0].as_f32().unwrap();
        assert!((rows[0] + rows[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn backend_rejects_bad_arity_and_shapes() {
        let be = NativeBackend::new();
        let s = HostTensor::from_f32(&[0.0; 4], &[2, 2]);
        assert!(be.execute("op_consmax", std::slice::from_ref(&s)).is_err());
        let c = HostTensor::from_f32(&[0.0; 2], &[2]);
        assert!(be.execute("op_consmax", &[s, c]).is_err());
    }

    #[test]
    fn pv_fusion_matches_two_step() {
        let be = NativeBackend::new();
        let (tq, tk, d) = (3usize, 4usize, 2usize);
        let s: Vec<f32> = (0..tq * tk).map(|i| (i as f32) * 0.1 - 0.5).collect();
        let c = vec![0.02f32; tq * tk];
        let v: Vec<f32> = (0..tk * d).map(|i| i as f32 * 0.25).collect();
        let fused = be
            .execute(
                "op_consmax_pv",
                &[
                    HostTensor::from_f32(&s, &[tq, tk]),
                    HostTensor::from_f32(&c, &[tq, tk]),
                    HostTensor::from_f32(&v, &[tk, d]),
                ],
            )
            .unwrap();
        let probs = consmax(&s, &c);
        let want = matmul(&probs, &v, tq, tk, d);
        let got = fused[0].as_f32().unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_identity() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0]; // 2x2
        let id = vec![1.0f32, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &id, 2, 2, 2), a);
    }
}
