//! Native differentiable training for [`NativeModel`] (DESIGN.md
//! §Training seam): an explicit activation tape ([`TrainTape`]) built by
//! [`NativeModel::forward_train`], and a hand-derived reverse pass
//! ([`NativeModel::backward`]) producing gradients for **every**
//! parameter — weights, embeddings, LayerNorm gains/biases, and each
//! normalizer's own learnables (per-(layer, head) β/γ for the ConSmax
//! family, the SSMax scale) through
//! [`HeadNorm::backward_row`](crate::runtime::backend::normalizer::HeadNorm).
//!
//! The autodiff here is deliberately small and legible: five kernel
//! transposes (matmul, LayerNorm, GELU, embedding gather,
//! softmax-cross-entropy) plus one normalizer rule per zoo member.
//! ConSmax's is the paper's training claim in one line — `∂p/∂s = p`,
//! a diagonal Jacobian with no cross-key coupling — which is why the
//! attention backward below has no per-row reduction on the ConSmax
//! path either.
//!
//! Orientation note: the model stores its four projection matrices
//! **pre-transposed** (`params_t`, `[l, dout, din]`), so the activation
//! gradient `dx = dy @ W^T` is a *plain* row-major [`native::matmul`]
//! against the stored tile — no transpose is ever materialized in the
//! backward pass. Weight gradients come out in canonical `(din, dout)`
//! orientation via [`native::matmul_at_b_acc`] (`dW = x^T @ dy`),
//! matching the `ParamStore`/checkpoint layout the optimizer updates.
//!
//! Everything is f32 with fixed serial reduction orders, and every
//! dot/exp runs through the same SIMD microkernel seam as inference
//! ([`native::dot`] and the normalizers' dispatched `simd::exp` —
//! DESIGN.md §SIMD-kernel seam), so `forward_train` logits match the
//! eval forward bitwise at any SIMD level. The pass is pinned by
//! central-finite-difference gradcheck over every normalizer
//! (`rust/tests/gradcheck.rs`) and the loss-decrease integration suite
//! (`rust/tests/train_native.rs`).

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::runtime::backend::model::NativeModel;
use crate::runtime::backend::native;
use crate::runtime::backend::normalizer::Normalizer;

/// Per-layer saved activations (all row-major; `rows = b * t`).
struct LayerTape {
    /// Residual stream entering the layer (`rows, d`).
    x_in: Vec<f32>,
    /// ln1 output (`rows, d`).
    xn1: Vec<f32>,
    /// Fused QKV projection output (`rows, 3d`).
    qkv: Vec<f32>,
    /// Attention probabilities, `(b·h, t, t)` causal row-major — entry
    /// `(r·h+hh)·t² + i·t + j` holds `p_ij` for `j ≤ i`, zero above the
    /// diagonal. For the ConSmax family these are the *unnormalized*
    /// streaming probabilities (no row sum exists — the paper's point).
    probs: Vec<f32>,
    /// Raw pre-scale attention scores, same layout as `probs` — taped
    /// only for `ssmax`, whose backward needs them (empty otherwise).
    raw: Vec<f32>,
    /// Head-gathered attention output (`rows, d`).
    att: Vec<f32>,
    /// Residual stream after the attention projection (`rows, d`) —
    /// the ln2 input.
    x_mid: Vec<f32>,
    /// ln2 output (`rows, d`).
    xn2: Vec<f32>,
    /// MLP fc output before GELU (`rows, 4d`).
    hid_pre: Vec<f32>,
    /// MLP fc output after GELU (`rows, 4d`).
    hid_post: Vec<f32>,
}

/// The activation tape of one training forward: everything
/// [`NativeModel::backward`] needs, and nothing it can cheaply
/// recompute (LayerNorm μ/σ are re-derived from the saved inputs).
pub struct TrainTape {
    b: usize,
    t: usize,
    layers: Vec<LayerTape>,
    /// Final residual stream (`rows, d`) — the lnf input.
    xf_in: Vec<f32>,
    /// lnf output feeding the tied LM head (`rows, d`).
    xf: Vec<f32>,
    /// LM-head logits (`rows, vocab`).
    logits: Vec<f32>,
    /// Mean next-token cross-entropy over all `(b, t)` positions.
    pub loss: f64,
}

impl NativeModel {
    /// Training forward over a flat `(b, t)` batch: same math as
    /// [`NativeModel::forward`] (identical kernels and accumulation
    /// order, so the taped loss is bit-equal to [`NativeModel::loss`]),
    /// but every intermediate the reverse pass needs is saved on the
    /// returned [`TrainTape`], including per-(row, head) attention
    /// probability rows — materialized uniformly for all five
    /// normalizers via `HeadNorm::normalize_row`.
    pub fn forward_train(
        &self,
        x: &[i32],
        y: &[i32],
        b: usize,
        t: usize,
    ) -> Result<TrainTape> {
        let cfg = &self.cfg;
        let (d, h, hd, v) = (cfg.n_embd, cfg.n_head, cfg.head_dim(), cfg.vocab);
        ensure!(
            !self.quant_mode().is_int8(),
            "native training runs on the f32 kernels (--quant off)"
        );
        ensure!(x.len() == b * t, "token buffer is not (b={b}, t={t})");
        ensure!(y.len() == x.len(), "x/y length mismatch");
        ensure!(t >= 1 && t <= cfg.ctx, "sequence length {t} vs ctx {}", cfg.ctx);
        for &tok in x.iter().chain(y) {
            ensure!(
                (0..v as i32).contains(&tok),
                "token id {tok} outside vocab {v}"
            );
        }

        let wte = self.p("wte");
        let wpe = self.p("wpe");
        let rows = b * t;
        let mut xs = vec![0.0f32; rows * d];
        for r in 0..b {
            for i in 0..t {
                let tok = x[r * t + i] as usize;
                let out = &mut xs[(r * t + i) * d..(r * t + i + 1) * d];
                let te = &wte[tok * d..(tok + 1) * d];
                let pe = &wpe[i * d..(i + 1) * d];
                for ((o, &a), &p) in out.iter_mut().zip(te).zip(pe) {
                    *o = a + p;
                }
            }
        }

        let taped_raw = self.norm == Normalizer::Ssmax;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut layers = Vec::with_capacity(cfg.n_layer);
        for l in 0..cfg.n_layer {
            let x_in = xs.clone();
            let xn1 = native::layer_norm(
                &xs,
                self.layer("ln1_g", l, d),
                self.layer("ln1_b", l, d),
                d,
            );
            let mut qkv = vec![0.0f32; rows * 3 * d];
            self.affine_layer(
                &xn1,
                "attn_qkv_w",
                "attn_qkv_b",
                l,
                rows,
                d,
                3 * d,
                &mut qkv,
            );

            // causal attention with the probability rows taped; per-key
            // accumulation order matches the serving forward exactly
            let mut probs = vec![0.0f32; b * h * t * t];
            let mut raw =
                if taped_raw { vec![0.0f32; b * h * t * t] } else { Vec::new() };
            let mut att = vec![0.0f32; rows * d];
            for r in 0..b {
                for hh in 0..h {
                    let hn = self.head_norm(l, hh);
                    let tile = (r * h + hh) * t * t;
                    for i in 0..t {
                        let qoff = (r * t + i) * 3 * d + hh * hd;
                        let q = &qkv[qoff..qoff + hd];
                        let prow = &mut probs[tile + i * t..tile + i * t + i + 1];
                        for (j, o) in prow.iter_mut().enumerate() {
                            let koff = (r * t + j) * 3 * d + d + hh * hd;
                            *o = native::dot(q, &qkv[koff..koff + hd]) * scale;
                        }
                        if taped_raw {
                            raw[tile + i * t..tile + i * t + i + 1]
                                .copy_from_slice(prow);
                        }
                        hn.normalize_row(prow);
                        for j in 0..=i {
                            let pj = probs[tile + i * t + j];
                            let voff = (r * t + j) * 3 * d + 2 * d + hh * hd;
                            let yrow = &mut att
                                [(r * t + i) * d + hh * hd..(r * t + i) * d + (hh + 1) * hd];
                            let vrow = &qkv[voff..voff + hd];
                            for (o, &vv) in yrow.iter_mut().zip(vrow) {
                                *o += pj * vv;
                            }
                        }
                    }
                }
            }

            let mut proj = vec![0.0f32; rows * d];
            self.affine_layer(
                &att,
                "attn_proj_w",
                "attn_proj_b",
                l,
                rows,
                d,
                d,
                &mut proj,
            );
            for (xv, pv) in xs.iter_mut().zip(&proj) {
                *xv += pv;
            }
            let x_mid = xs.clone();

            let xn2 = native::layer_norm(
                &xs,
                self.layer("ln2_g", l, d),
                self.layer("ln2_b", l, d),
                d,
            );
            let mut hid_pre = vec![0.0f32; rows * 4 * d];
            self.affine_layer(
                &xn2,
                "mlp_fc_w",
                "mlp_fc_b",
                l,
                rows,
                d,
                4 * d,
                &mut hid_pre,
            );
            let hid_post: Vec<f32> =
                hid_pre.iter().map(|&hv| native::gelu(hv)).collect();
            let mut mo = vec![0.0f32; rows * d];
            self.affine_layer(
                &hid_post,
                "mlp_proj_w",
                "mlp_proj_b",
                l,
                rows,
                4 * d,
                d,
                &mut mo,
            );
            for (xv, mv) in xs.iter_mut().zip(&mo) {
                *xv += mv;
            }

            layers.push(LayerTape {
                x_in,
                xn1,
                qkv,
                probs,
                raw,
                att,
                x_mid,
                xn2,
                hid_pre,
                hid_post,
            });
        }

        let xf_in = xs.clone();
        let xf = native::layer_norm(&xs, self.p("lnf_g"), self.p("lnf_b"), d);
        let mut logits = vec![0.0f32; rows * v];
        self.lm_head_into(&xf, rows, &mut logits);

        let mut total = 0.0f64;
        for (pos, &target) in y.iter().enumerate() {
            let row = &logits[pos * v..(pos + 1) * v];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = m + row.iter().map(|&lg| (lg - m).exp()).sum::<f32>().ln();
            total += (lse - row[target as usize]) as f64;
        }
        let loss = total / y.len() as f64;

        Ok(TrainTape { b, t, layers, xf_in, xf, logits, loss })
    }

    /// Reverse pass over a [`TrainTape`]: gradients of the mean
    /// cross-entropy w.r.t. every parameter, keyed by canonical name in
    /// canonical (untransposed, layer-stacked) orientation — exactly
    /// the `ParamStore` layout the AdamW step updates. β/γ grads are
    /// always present (zero when the normalizer doesn't own them), so
    /// the optimizer loop never special-cases the zoo.
    pub fn backward(
        &self,
        tape: &TrainTape,
        x: &[i32],
        y: &[i32],
    ) -> Result<BTreeMap<String, Vec<f32>>> {
        let cfg = &self.cfg;
        let (d, h, hd, v) = (cfg.n_embd, cfg.n_head, cfg.head_dim(), cfg.vocab);
        let (b, t) = (tape.b, tape.t);
        let rows = b * t;
        ensure!(x.len() == rows && y.len() == rows, "tape/batch mismatch");
        ensure!(tape.layers.len() == cfg.n_layer, "tape depth mismatch");

        let mut grads: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        for name in &cfg.param_order {
            let n: usize = cfg.shape_of(name)?.iter().product();
            grads.insert(name.clone(), vec![0.0f32; n]);
        }

        // -- cross-entropy + LM head ---------------------------------
        // dlogits = (softmax(logits) − onehot(y)) / N over all positions
        let n_inv = 1.0f32 / rows as f32;
        let mut dlogits = vec![0.0f32; rows * v];
        for pos in 0..rows {
            let row = &tape.logits[pos * v..(pos + 1) * v];
            let drow = &mut dlogits[pos * v..(pos + 1) * v];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for (o, &lg) in drow.iter_mut().zip(row) {
                *o = (lg - m).exp();
                sum += *o;
            }
            for o in drow.iter_mut() {
                *o = *o / sum * n_inv;
            }
            drow[y[pos] as usize] -= n_inv;
        }

        // tied head: logits = xf @ wte^T, so dxf = dlogits @ wte and
        // the head's wte contribution is dlogits^T @ xf
        let wte = self.p("wte");
        let mut dx = native::matmul(&dlogits, wte, rows, v, d);
        {
            let dwte = grads.get_mut("wte").expect("schema");
            native::matmul_at_b_acc(&dlogits, &tape.xf, rows, v, d, dwte);
        }

        // -- final LayerNorm -----------------------------------------
        let mut dxf_in = vec![0.0f32; rows * d];
        {
            let [dg, db] = two_grads(&mut grads, "lnf_g", "lnf_b");
            native::layer_norm_backward(
                &tape.xf_in,
                self.p("lnf_g"),
                &dx,
                d,
                &mut dxf_in,
                dg,
                db,
            );
        }
        dx = dxf_in;

        // -- transformer blocks, reversed ----------------------------
        let scale = 1.0 / (hd as f32).sqrt();
        for l in (0..cfg.n_layer).rev() {
            let tp = &tape.layers[l];

            // MLP proj: x_out = x_mid + hid_post @ W + b. The stored
            // tile is W^T, so dy @ W^T is a plain matmul against it.
            let dmo = &dx; // (rows, d)
            let dhid_post = native::matmul(
                dmo,
                self.layer_t("mlp_proj_w", l, 4 * d * d),
                rows,
                d,
                4 * d,
            );
            accumulate_affine_grads(
                &mut grads,
                "mlp_proj_w",
                "mlp_proj_b",
                l,
                &tp.hid_post,
                dmo,
                rows,
                4 * d,
                d,
            );

            // GELU
            let dhid_pre: Vec<f32> = dhid_post
                .iter()
                .zip(&tp.hid_pre)
                .map(|(&dv, &pre)| dv * native::gelu_grad(pre))
                .collect();

            // MLP fc
            let dxn2 = native::matmul(
                &dhid_pre,
                self.layer_t("mlp_fc_w", l, d * 4 * d),
                rows,
                4 * d,
                d,
            );
            accumulate_affine_grads(
                &mut grads,
                "mlp_fc_w",
                "mlp_fc_b",
                l,
                &tp.xn2,
                &dhid_pre,
                rows,
                d,
                4 * d,
            );

            // ln2 (+ the residual stream around the MLP)
            let mut dx_mid = vec![0.0f32; rows * d];
            {
                let [dg, db] = two_grads(&mut grads, "ln2_g", "ln2_b");
                native::layer_norm_backward(
                    &tp.x_mid,
                    self.layer("ln2_g", l, d),
                    &dxn2,
                    d,
                    &mut dx_mid,
                    &mut dg[l * d..(l + 1) * d],
                    &mut db[l * d..(l + 1) * d],
                );
            }
            for (o, &r) in dx_mid.iter_mut().zip(dx.iter()) {
                *o += r;
            }

            // attention projection
            let datt = native::matmul(
                &dx_mid,
                self.layer_t("attn_proj_w", l, d * d),
                rows,
                d,
                d,
            );
            accumulate_affine_grads(
                &mut grads,
                "attn_proj_w",
                "attn_proj_b",
                l,
                &tp.att,
                &dx_mid,
                rows,
                d,
                d,
            );

            // attention core: probs/raw from the tape, normalizer rule
            // from the seam, q/k/v grads written straight into dqkv
            let mut dqkv = vec![0.0f32; rows * 3 * d];
            let mut dprow = vec![0.0f32; t];
            let mut dsrow = vec![0.0f32; t];
            for r in 0..b {
                for hh in 0..h {
                    let hn = self.head_norm(l, hh);
                    let tile = (r * h + hh) * t * t;
                    for i in 0..t {
                        let dy =
                            &datt[(r * t + i) * d + hh * hd..(r * t + i) * d + (hh + 1) * hd];
                        let prow = &tp.probs[tile + i * t..tile + i * t + i + 1];
                        // dp_j = dy·v_j ; dv_j += p_ij · dy
                        for (j, dp) in dprow[..=i].iter_mut().enumerate() {
                            let voff = (r * t + j) * 3 * d + 2 * d + hh * hd;
                            *dp = native::dot(dy, &tp.qkv[voff..voff + hd]);
                            let dvrow = &mut dqkv[voff..voff + hd];
                            let pj = prow[j];
                            for (o, &dyv) in dvrow.iter_mut().zip(dy) {
                                *o += pj * dyv;
                            }
                        }
                        let rrow = if tp.raw.is_empty() {
                            &[]
                        } else {
                            &tp.raw[tile + i * t..tile + i * t + i + 1]
                        };
                        let ng = hn.backward_row(
                            prow,
                            &dprow[..=i],
                            rrow,
                            &mut dsrow[..=i],
                        );
                        if hn.kind.uses_beta_gamma() {
                            let gb = grads.get_mut("beta").expect("schema");
                            gb[l * h + hh] += ng.dbeta;
                            let gg = grads.get_mut("gamma").expect("schema");
                            gg[l * h + hh] += ng.dgamma;
                        }
                        if hn.kind.uses_ssmax_scale() {
                            let gs = grads.get_mut("ssmax_s").expect("schema");
                            gs[l * h + hh] += ng.dsscale;
                        }
                        // dq_i += ds_j·scale·k_j ; dk_j += ds_j·scale·q_i
                        let qoff = (r * t + i) * 3 * d + hh * hd;
                        let q: Vec<f32> = tp.qkv[qoff..qoff + hd].to_vec();
                        for (j, &ds) in dsrow[..=i].iter().enumerate() {
                            let koff = (r * t + j) * 3 * d + d + hh * hd;
                            let dsc = ds * scale;
                            {
                                let dqrow = &mut dqkv[qoff..qoff + hd];
                                let krow = &tp.qkv[koff..koff + hd];
                                for (o, &kv) in dqrow.iter_mut().zip(krow) {
                                    *o += dsc * kv;
                                }
                            }
                            let dkrow = &mut dqkv[koff..koff + hd];
                            for (o, &qv) in dkrow.iter_mut().zip(&q) {
                                *o += dsc * qv;
                            }
                        }
                    }
                }
            }

            // fused QKV projection
            let dxn1 = native::matmul(
                &dqkv,
                self.layer_t("attn_qkv_w", l, d * 3 * d),
                rows,
                3 * d,
                d,
            );
            accumulate_affine_grads(
                &mut grads,
                "attn_qkv_w",
                "attn_qkv_b",
                l,
                &tp.xn1,
                &dqkv,
                rows,
                d,
                3 * d,
            );

            // ln1 (+ the residual stream around attention)
            let mut dx_in = vec![0.0f32; rows * d];
            {
                let [dg, db] = two_grads(&mut grads, "ln1_g", "ln1_b");
                native::layer_norm_backward(
                    &tp.x_in,
                    self.layer("ln1_g", l, d),
                    &dxn1,
                    d,
                    &mut dx_in,
                    &mut dg[l * d..(l + 1) * d],
                    &mut db[l * d..(l + 1) * d],
                );
            }
            for (o, &r) in dx_in.iter_mut().zip(&dx_mid) {
                *o += r;
            }
            dx = dx_in;
        }

        // -- embeddings ----------------------------------------------
        {
            let dwte = grads.get_mut("wte").expect("schema");
            for (pos, &tok) in x.iter().enumerate() {
                let src = &dx[pos * d..(pos + 1) * d];
                let dst = &mut dwte[tok as usize * d..(tok as usize + 1) * d];
                for (o, &s) in dst.iter_mut().zip(src) {
                    *o += s;
                }
            }
        }
        {
            let dwpe = grads.get_mut("wpe").expect("schema");
            for pos in 0..rows {
                let i = pos % t;
                let src = &dx[pos * d..(pos + 1) * d];
                let dst = &mut dwpe[i * d..(i + 1) * d];
                for (o, &s) in dst.iter_mut().zip(src) {
                    *o += s;
                }
            }
        }
        Ok(grads)
    }
}

/// Accumulate one layer's affine gradients in canonical orientation:
/// `dW[l] += x^T @ dy` (`(din, dout)`) and `db[l] += Σ_rows dy`.
#[allow(clippy::too_many_arguments)]
fn accumulate_affine_grads(
    grads: &mut BTreeMap<String, Vec<f32>>,
    w_name: &str,
    b_name: &str,
    l: usize,
    x: &[f32],
    dy: &[f32],
    rows: usize,
    din: usize,
    dout: usize,
) {
    {
        let dw = grads.get_mut(w_name).expect("schema");
        let per = din * dout;
        native::matmul_at_b_acc(
            x,
            dy,
            rows,
            din,
            dout,
            &mut dw[l * per..(l + 1) * per],
        );
    }
    let db = grads.get_mut(b_name).expect("schema");
    let brow = &mut db[l * dout..(l + 1) * dout];
    for drow in dy.chunks_exact(dout) {
        for (o, &dv) in brow.iter_mut().zip(drow) {
            *o += dv;
        }
    }
}

/// Disjoint mutable grad buffers for a gain/bias pair (the map holds
/// each under its own key, so two `get_mut`s need a split borrow).
fn two_grads<'a>(
    grads: &'a mut BTreeMap<String, Vec<f32>>,
    a: &str,
    b: &str,
) -> [&'a mut Vec<f32>; 2] {
    debug_assert_ne!(a, b);
    let mut ga: Option<&mut Vec<f32>> = None;
    let mut gb: Option<&mut Vec<f32>> = None;
    for (k, val) in grads.iter_mut() {
        if k == a {
            ga = Some(val);
        } else if k == b {
            gb = Some(val);
        }
    }
    [ga.expect("schema"), gb.expect("schema")]
}

#[cfg(test)]
mod tests {
    use crate::config::ModelConfig;
    use crate::runtime::backend::NativeModel;
    use crate::runtime::HostTensor;
    use crate::util::rng::Pcg32;

    fn tiny_model(normalizer: &str) -> NativeModel {
        let cfg = ModelConfig::builtin("tiny", normalizer).unwrap();
        let mut rng = Pcg32::seeded(7);
        let mut tensors = Vec::new();
        for name in cfg.param_order.clone() {
            let shape = cfg.shape_of(&name).unwrap().to_vec();
            let n: usize = shape.iter().product();
            let vals: Vec<f32> = match name.as_str() {
                "ln1_g" | "ln2_g" | "lnf_g" => vec![1.0; n],
                "beta" => vec![1.5; n],
                "gamma" => vec![100.0; n],
                "ssmax_s" => vec![0.43; n],
                _ if name.ends_with("_b") => vec![0.0; n],
                _ => rng.normal_vec_f32(n, 0.0, 0.02),
            };
            tensors.push(HostTensor::from_f32(&vals, &shape));
        }
        NativeModel::from_params(&cfg, &cfg.param_order, &tensors).unwrap()
    }

    #[test]
    fn forward_train_loss_matches_eval_loss() {
        // the tape-building forward runs the same kernels in the same
        // order as the serving forward — losses agree to f32 roundoff
        for norm in ["consmax", "softmax", "softermax", "consmax-v2", "ssmax"] {
            let m = tiny_model(norm);
            let x: Vec<i32> = (0..2 * 16).map(|i| (i * 7) % 256).collect();
            let y: Vec<i32> = (0..2 * 16).map(|i| (i * 7 + 1) % 256).collect();
            let tape = m.forward_train(&x, &y, 2, 16).unwrap();
            let eval = m.loss(&x, &y, 2, 16).unwrap();
            assert!(
                (tape.loss - eval).abs() < 1e-6,
                "{norm}: {} vs {eval}",
                tape.loss
            );
        }
    }

    #[test]
    fn backward_produces_full_schema_and_finite_grads() {
        for norm in ["consmax", "ssmax"] {
            let m = tiny_model(norm);
            let x: Vec<i32> = (0..2 * 8).map(|i| (i * 11) % 256).collect();
            let y: Vec<i32> = (0..2 * 8).map(|i| (i * 11 + 1) % 256).collect();
            let tape = m.forward_train(&x, &y, 2, 8).unwrap();
            let grads = m.backward(&tape, &x, &y).unwrap();
            assert_eq!(grads.len(), m.cfg.param_order.len(), "{norm}");
            for (name, g) in &grads {
                let want: usize =
                    m.cfg.shape_of(name).unwrap().iter().product();
                assert_eq!(g.len(), want, "{norm}/{name}");
                assert!(
                    g.iter().all(|v| v.is_finite()),
                    "{norm}/{name}: non-finite grad"
                );
            }
            // the learnable-normalizer grads actually flow
            let key = if norm == "ssmax" { "ssmax_s" } else { "beta" };
            assert!(
                grads[key].iter().any(|&v| v != 0.0),
                "{norm}: no gradient reached {key}"
            );
        }
    }
}
