//! The **SIMD microkernel seam** (DESIGN.md §SIMD-kernel seam): one
//! 8-wide lane layer every hot kernel routes through, plus the
//! polynomial `exp` that turns the ConSmax tail into a single fused
//! multiply-exp-accumulate stream.
//!
//! Three resolved levels, selected once per process:
//!
//! * **avx2** — x86_64 with runtime-detected AVX2: hand-written
//!   256-bit intrinsic inner loops for [`dot`] / [`dot_i8`]. Separate
//!   multiply + add (never FMA — fused rounding would change bits),
//!   the same lane-to-element mapping and the same pairwise horizontal
//!   reduce as the portable path, so the result is **bit-identical by
//!   construction** to every other level.
//! * **portable** — the 8-accumulator unrolled loops that compile on
//!   every target and autovectorize under `-O`; also the fallback when
//!   AVX2 is absent.
//! * **off** — the scalar reference: the same portable loops (they
//!   *are* the bit-exactness oracle for the reductions) but with every
//!   exponential dispatched to libm instead of the polynomial.
//!
//! Selection order: `--simd auto|off` ([`set_mode`]) beats the
//! `CONSMAX_SIMD` environment variable (`0`/`off` disables) beats the
//! default `auto`; `consmax info` reports the resolved level.
//!
//! **The oracle/tolerance contract.** The reductions ([`dot`],
//! [`dot_i8`], [`sum`], [`max`]) are pinned bit-identical across all
//! levels — accumulation order is a pure function of input length, so
//! matmuls, int8 matmuls and row normalizer reductions never drift
//! when the level changes. Only the exponential differs: [`exp`] /
//! [`exp2`] dispatch to [`exp_approx`] / [`exp2_approx`] (a Cephes
//! f32 polynomial, ~2e-7 max relative error, saturating to `inf`
//! above [`EXP_HI`] and flushing to `0.0` — never NaN — below
//! [`EXP_LO`]) when SIMD is on, and to libm when off. Every consumer
//! of an exponential in the model (streaming tails, `stream_p`, row
//! softmax/softermax) goes through this one dispatch, so forward,
//! KV decode, paged decode and the training tape stay bitwise
//! self-consistent *within* each mode; across modes the outputs agree
//! within the tolerance pinned by `rust/tests/simd_kernels.rs`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use anyhow::{bail, Result};

/// Lane width of the microkernel layer (f32 elements per block).
pub const LANES: usize = 8;

// ---------------------------------------------------------------------------
// mode selection
// ---------------------------------------------------------------------------

/// CLI/env-facing SIMD mode (`--simd auto|off`, `CONSMAX_SIMD`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Use the best level the host supports (the default).
    Auto,
    /// Scalar reference path: libm exponentials, portable reductions.
    Off,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Mode> {
        Ok(match s {
            "auto" => Mode::Auto,
            "off" => Mode::Off,
            other => bail!("unknown --simd {other:?} (auto|off)"),
        })
    }
}

/// The resolved microkernel level actually running.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Scalar reference: portable reductions + libm exponentials.
    Off,
    /// Portable 8-lane loops + polynomial exp (compiles everywhere).
    Portable,
    /// Runtime-detected AVX2 intrinsics + polynomial exp.
    Avx2,
}

impl Level {
    /// Short name for `consmax info` / bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Portable => "portable",
            Level::Avx2 => "avx2",
        }
    }
}

const MODE_UNSET: u8 = 0;
const MODE_AUTO: u8 = 1;
const MODE_OFF: u8 = 2;
const LVL_UNRESOLVED: u8 = 0;
const LVL_OFF: u8 = 1;
const LVL_PORTABLE: u8 = 2;
const LVL_AVX2: u8 = 3;

/// Runtime override installed by `--simd` (MODE_UNSET = not given).
static OVERRIDE: AtomicU8 = AtomicU8::new(MODE_UNSET);
/// Process-wide default, resolved once from `CONSMAX_SIMD`.
static DEFAULT: OnceLock<Mode> = OnceLock::new();
/// Cached resolved level (so the hot-path dispatch is one relaxed load).
static LEVEL: AtomicU8 = AtomicU8::new(LVL_UNRESOLVED);

fn default_mode() -> Mode {
    *DEFAULT.get_or_init(|| match std::env::var("CONSMAX_SIMD").as_deref() {
        Ok("0") | Ok("off") => Mode::Off,
        _ => Mode::Auto,
    })
}

#[cfg(target_arch = "x86_64")]
fn detect() -> Level {
    if is_x86_feature_detected!("avx2") {
        Level::Avx2
    } else {
        Level::Portable
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect() -> Level {
    Level::Portable
}

fn resolve() -> Level {
    let mode = match OVERRIDE.load(Ordering::Relaxed) {
        MODE_AUTO => Mode::Auto,
        MODE_OFF => Mode::Off,
        _ => default_mode(),
    };
    match mode {
        Mode::Off => Level::Off,
        Mode::Auto => detect(),
    }
}

fn level_code(l: Level) -> u8 {
    match l {
        Level::Off => LVL_OFF,
        Level::Portable => LVL_PORTABLE,
        Level::Avx2 => LVL_AVX2,
    }
}

/// Install the CLI mode (beats `CONSMAX_SIMD`). Callable any time;
/// tests that flip modes serialize themselves (the kernels read the
/// level per call, so a flip between calls is always coherent).
pub fn set_mode(m: Mode) {
    OVERRIDE.store(
        match m {
            Mode::Auto => MODE_AUTO,
            Mode::Off => MODE_OFF,
        },
        Ordering::Relaxed,
    );
    LEVEL.store(level_code(resolve()), Ordering::Relaxed);
}

/// The resolved level (cached; one relaxed atomic load on hot paths).
#[inline]
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        LVL_OFF => Level::Off,
        LVL_PORTABLE => Level::Portable,
        LVL_AVX2 => Level::Avx2,
        _ => {
            let l = resolve();
            LEVEL.store(level_code(l), Ordering::Relaxed);
            l
        }
    }
}

// ---------------------------------------------------------------------------
// lane reductions: dot / dot_i8 / sum / max
// ---------------------------------------------------------------------------

/// 8-lane dot product — the one reduction every matmul and attention
/// score in the stack runs through. Lane `j` of the accumulator only
/// ever sees elements `8k + j`, and the horizontal reduce is the fixed
/// pairwise tree `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7))` with a serial
/// remainder, at **every** level — so the result is a pure function of
/// the input values and length: bit-identical across thread counts,
/// SIMD levels, and the KV-decode/recompute split.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        // SAFETY: Level::Avx2 is only resolved after
        // `is_x86_feature_detected!("avx2")` succeeded on this host.
        return unsafe { avx2::dot(a, b) };
    }
    dot_portable(a, b)
}

/// Portable 8-accumulator [`dot`] core (also the `off`-level oracle).
#[inline]
pub fn dot_portable(a: &[f32], b: &[f32]) -> f32 {
    let a_whole = a.chunks_exact(LANES);
    let b_whole = b.chunks_exact(LANES);
    let a_rest = a_whole.remainder();
    let b_rest = b_whole.remainder();
    let mut acc = [0.0f32; LANES];
    for (ca, cb) in a_whole.zip(b_whole) {
        for (lane, (&x, &y)) in acc.iter_mut().zip(ca.iter().zip(cb)) {
            *lane += x * y;
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (&x, &y) in a_rest.iter().zip(b_rest) {
        s += x * y;
    }
    s
}

/// [`dot`] against int8 codes, widening each code to f32 in the
/// multiply. Same lane layout and reduce as [`dot`]: bit-identical to
/// widening the whole vector and running the f32 dot, at every level.
#[inline]
pub fn dot_i8(a: &[f32], q: &[i8]) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        // SAFETY: Level::Avx2 implies runtime-detected AVX2.
        return unsafe { avx2::dot_i8(a, q) };
    }
    dot_i8_portable(a, q)
}

/// Portable 8-accumulator [`dot_i8`] core.
#[inline]
pub fn dot_i8_portable(a: &[f32], q: &[i8]) -> f32 {
    let a_whole = a.chunks_exact(LANES);
    let q_whole = q.chunks_exact(LANES);
    let a_rest = a_whole.remainder();
    let q_rest = q_whole.remainder();
    let mut acc = [0.0f32; LANES];
    for (ca, cq) in a_whole.zip(q_whole) {
        for (lane, (&x, &code)) in acc.iter_mut().zip(ca.iter().zip(cq)) {
            *lane += x * code as f32;
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (&x, &code) in a_rest.iter().zip(q_rest) {
        s += x * code as f32;
    }
    s
}

/// 8-lane sum with the same fixed pairwise reduce as [`dot`] — the one
/// denominator reduction of `softmax_inplace` / `reduce_rows`. Level-
/// independent and thread-count-independent by the same argument.
#[inline]
pub fn sum(xs: &[f32]) -> f32 {
    let whole = xs.chunks_exact(LANES);
    let rest = whole.remainder();
    let mut acc = [0.0f32; LANES];
    for c in whole {
        for (lane, &x) in acc.iter_mut().zip(c) {
            *lane += x;
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for &x in rest {
        s += x;
    }
    s
}

/// 8-lane running max (`f32::max` semantics: NaN inputs are dropped,
/// exactly like the serial `fold(NEG_INFINITY, f32::max)` it replaces
/// — max is order-independent, so lane-splitting cannot change the
/// result). Returns `-inf` for an empty slice.
#[inline]
pub fn max(xs: &[f32]) -> f32 {
    let whole = xs.chunks_exact(LANES);
    let rest = whole.remainder();
    let mut acc = [f32::NEG_INFINITY; LANES];
    for c in whole {
        for (lane, &x) in acc.iter_mut().zip(c) {
            *lane = lane.max(x);
        }
    }
    let mut m = (acc[0].max(acc[1])).max(acc[2].max(acc[3]));
    m = m.max((acc[4].max(acc[5])).max(acc[6].max(acc[7])));
    for &x in rest {
        m = m.max(x);
    }
    m
}

// ---------------------------------------------------------------------------
// polynomial exponentials
// ---------------------------------------------------------------------------

/// Above this input [`exp_approx`] saturates to `+inf`. Chosen so the
/// scale exponent `n` never exceeds 127 (`exp(88.37) ≈ 2.4e38` is
/// still finite f32; true `expf` stays finite up to ~88.72 — the gap
/// is the documented saturation region).
pub const EXP_HI: f32 = 88.37;
/// Below this input [`exp_approx`] flushes to `0.0` (never NaN, no
/// subnormal outputs): the smallest-normal edge, `ln(2^-126)`.
pub const EXP_LO: f32 = -87.336_54;
/// [`exp2_approx`] saturates to `+inf` above this input.
pub const EXP2_HI: f32 = 127.0;
/// [`exp2_approx`] flushes to `0.0` below this input.
pub const EXP2_LO: f32 = -126.0;

// Cody–Waite split of ln(2): C1 + C2 == ln(2) to ~2e-11, with C1
// exactly representable so `x - n*C1` is exact for |n| <= 127.
const C1: f32 = 0.693_359_375;
#[allow(clippy::excessive_precision)]
const C2: f32 = -2.121_944_4e-4;

// Degree-5 minimax polynomial for exp(r) on |r| <= ln(2)/2 (the
// classic Cephes `expf` coefficients; ~2e-7 max relative error).
#[allow(clippy::excessive_precision)]
const P: [f32; 6] = [
    1.987_569_15e-4,
    1.398_199_95e-3,
    8.333_451_9e-3,
    4.166_579_6e-2,
    1.666_666_55e-1,
    5.000_000_1e-1,
];

/// `exp(r)` for reduced `|r| <= ~0.347`, times `2^n` via exponent-bit
/// construction. `n` must be in `[-126, 127]`.
#[inline]
fn exp_poly_scale(r: f32, n: i32) -> f32 {
    let r2 = r * r;
    let mut p = P[0];
    p = p * r + P[1];
    p = p * r + P[2];
    p = p * r + P[3];
    p = p * r + P[4];
    p = p * r + P[5];
    let y = p * r2 + r + 1.0;
    let scale = f32::from_bits(((n + 127) as u32) << 23);
    y * scale
}

/// Round-half-up floor of `t + 0.5` without a libm call: truncating
/// saturating cast plus a negative-direction correction — this is what
/// lets the whole function autovectorize on baseline targets (a
/// `f32::floor` call would block the vectorizer without SSE4.1).
#[inline]
fn round_i32(t: f32) -> i32 {
    let zf = t + 0.5;
    let mut n = zf as i32;
    n -= ((n as f32) > zf) as i32;
    n
}

/// Branchless polynomial `exp(x)`: Cody–Waite range reduction, the
/// degree-5 Cephes polynomial, exponent-bit scaling. ~2e-7 max
/// relative error over `[EXP_LO, EXP_HI]`; `+inf` above, exact `0.0`
/// below (never NaN — pinned in `rust/tests/simd_kernels.rs`); NaN
/// propagates (`f32::clamp` keeps NaN). Every select compiles to a
/// branch-free `select`, so a loop over a slice vectorizes.
#[inline]
pub fn exp_approx(x: f32) -> f32 {
    let xc = x.clamp(EXP_LO, EXP_HI);
    let n = round_i32(xc * std::f32::consts::LOG2_E);
    let nf = n as f32;
    let r = (xc - nf * C1) - nf * C2;
    let out = exp_poly_scale(r, n);
    let out = if x > EXP_HI { f32::INFINITY } else { out };
    if x < EXP_LO {
        0.0
    } else {
        out
    }
}

/// Branchless polynomial `exp2(x)` (the ConSmax-v2 / softermax base):
/// the integer part scales by exponent bits exactly, the fractional
/// part `r ∈ [-0.5, 0.5]` goes through the same polynomial as
/// `exp(r·ln2)`. Same saturation/flush/NaN contract as [`exp_approx`].
#[inline]
pub fn exp2_approx(x: f32) -> f32 {
    let xc = x.clamp(EXP2_LO, EXP2_HI);
    let n = round_i32(xc);
    let r = (xc - n as f32) * std::f32::consts::LN_2;
    let out = exp_poly_scale(r, n);
    let out = if x > EXP2_HI { f32::INFINITY } else { out };
    if x < EXP2_LO {
        0.0
    } else {
        out
    }
}

/// The one `exp` dispatch every model exponential goes through:
/// libm when the level is `off`, the polynomial otherwise. Used by
/// `HeadNorm::stream_p`, the fused attention tails, and the row
/// normalizers alike, so each mode is bitwise self-consistent across
/// forward / decode / paged / training paths.
#[inline]
pub fn exp(x: f32) -> f32 {
    if level() == Level::Off {
        x.exp()
    } else {
        exp_approx(x)
    }
}

/// Base-2 twin of [`exp`].
#[inline]
pub fn exp2(x: f32) -> f32 {
    if level() == Level::Off {
        x.exp2()
    } else {
        exp2_approx(x)
    }
}

/// Exponentiate a slice in place — the block form the fused tails and
/// row normalizers use. The level is read once, so the inner loop is
/// pure straight-line polynomial math that the compiler vectorizes.
/// Element-for-element identical to mapping [`exp`].
#[inline]
pub fn exp_map(xs: &mut [f32]) {
    if level() == Level::Off {
        for x in xs.iter_mut() {
            *x = x.exp();
        }
    } else {
        for x in xs.iter_mut() {
            *x = exp_approx(*x);
        }
    }
}

/// Base-2 twin of [`exp_map`].
#[inline]
pub fn exp2_map(xs: &mut [f32]) {
    if level() == Level::Off {
        for x in xs.iter_mut() {
            *x = x.exp2();
        }
    } else {
        for x in xs.iter_mut() {
            *x = exp2_approx(*x);
        }
    }
}

/// Which exponent base a normalizer kernel runs on — the parameter
/// that dedupes the base-e/base-2 twin kernels (`attend_consmax` /
/// `attend_consmax2`, softmax/softermax) into one generic body each.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpBase {
    /// Natural base (`softmax`, `consmax`).
    E,
    /// Base 2 (`softermax`, `consmax-v2` — a shifter in hardware).
    Two,
}

impl ExpBase {
    /// Scalar dispatched exponential in this base.
    #[inline]
    pub fn eval(self, x: f32) -> f32 {
        match self {
            ExpBase::E => exp(x),
            ExpBase::Two => exp2(x),
        }
    }

    /// In-place slice exponential in this base ([`exp_map`] /
    /// [`exp2_map`]); bit-equal to mapping [`ExpBase::eval`].
    #[inline]
    pub fn map(self, xs: &mut [f32]) {
        match self {
            ExpBase::E => exp_map(xs),
            ExpBase::Two => exp2_map(xs),
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 intrinsic cores (x86_64 only, runtime-gated by `level()`)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::{
        __m128i, __m256, _mm256_add_ps, _mm256_cvtepi8_epi32,
        _mm256_cvtepi32_ps, _mm256_loadu_ps, _mm256_mul_ps,
        _mm256_setzero_ps, _mm256_storeu_ps, _mm_loadl_epi64,
    };

    /// Pairwise reduce matching the portable order exactly.
    #[inline]
    unsafe fn reduce(acc: __m256) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
    }

    /// 256-bit [`super::dot`] core: unaligned loads, separate
    /// multiply+add (no FMA — fused rounding would break the
    /// bit-identity contract with the portable path).
    ///
    /// # Safety
    /// Caller must have runtime-verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n8 = a.len() / 8 * 8;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < n8 {
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
            i += 8;
        }
        let mut s = reduce(acc);
        for j in n8..a.len() {
            s += a[j] * b[j];
        }
        s
    }

    /// 256-bit [`super::dot_i8`] core: 8 codes widen i8→i32→f32 per
    /// step, then the same multiply+add lanes as [`dot`].
    ///
    /// # Safety
    /// Caller must have runtime-verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8(a: &[f32], q: &[i8]) -> f32 {
        let n8 = a.len() / 8 * 8;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < n8 {
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vq8 = _mm_loadl_epi64(q.as_ptr().add(i) as *const __m128i);
            let vq = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(vq8));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vq));
            i += 8;
        }
        let mut s = reduce(acc);
        for j in n8..a.len() {
            s += a[j] * q[j] as f32;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: no test here calls `set_mode` — the lib test binary runs
    // tests concurrently and other modules assert bitwise contracts
    // that must not see the level flip mid-test. Mode-flipping tests
    // live in `rust/tests/simd_kernels.rs` (own process, serialized).

    #[test]
    fn mode_parses() {
        assert_eq!(Mode::parse("auto").unwrap(), Mode::Auto);
        assert_eq!(Mode::parse("off").unwrap(), Mode::Off);
        assert!(Mode::parse("avx512").is_err());
    }

    #[test]
    fn level_is_resolved_and_named() {
        let l = level();
        assert!(matches!(l, Level::Off | Level::Portable | Level::Avx2));
        assert!(["off", "portable", "avx2"].contains(&l.name()));
    }

    #[test]
    fn exp_approx_is_accurate_near_zero() {
        for i in -64..=64 {
            let x = i as f32 / 8.0;
            let want = (x as f64).exp();
            let got = exp_approx(x) as f64;
            assert!(
                (got - want).abs() <= 1e-6 * want,
                "x={x}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn exp_approx_edge_cases() {
        assert_eq!(exp_approx(0.0), 1.0);
        assert_eq!(exp_approx(f32::NEG_INFINITY), 0.0);
        assert_eq!(exp_approx(-1e10), 0.0);
        assert_eq!(exp_approx(-88.0), 0.0);
        assert!(exp_approx(f32::INFINITY).is_infinite());
        assert!(exp_approx(1e10).is_infinite());
        assert!(exp_approx(f32::NAN).is_nan());
        // subnormal inputs round to exp(0) = 1
        assert_eq!(exp_approx(1.0e-40), 1.0);
        // top of the finite range stays finite
        assert!(exp_approx(EXP_HI).is_finite());
    }

    #[test]
    fn exp2_approx_edge_cases() {
        assert_eq!(exp2_approx(0.0), 1.0);
        assert_eq!(exp2_approx(10.0), 1024.0);
        assert_eq!(exp2_approx(-1.0), 0.5);
        assert_eq!(exp2_approx(f32::NEG_INFINITY), 0.0);
        assert_eq!(exp2_approx(-1e10), 0.0);
        assert!(exp2_approx(f32::INFINITY).is_infinite());
        assert!(exp2_approx(f32::NAN).is_nan());
        assert!(exp2_approx(EXP2_HI).is_finite());
        assert!(exp2_approx(128.0).is_infinite());
    }

    #[test]
    fn portable_dot_matches_f64_reference() {
        for len in [0usize, 1, 7, 8, 9, 31, 64, 100] {
            let a: Vec<f32> = (0..len).map(|i| (i as f32) * 0.25 - 3.0).collect();
            let b: Vec<f32> = (0..len).map(|i| 1.5 - (i as f32) * 0.125).collect();
            let want: f64 =
                a.iter().zip(&b).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
            let got = dot_portable(&a, &b) as f64;
            assert!(
                (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                "len {len}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn dispatched_dot_matches_portable_bitwise() {
        // whatever level the process resolved, the dispatched dot must
        // agree with the portable oracle bit-for-bit
        for len in [0usize, 1, 7, 8, 9, 16, 31, 64, 100, 257] {
            let a: Vec<f32> = (0..len).map(|i| (i as f32) * 0.21 - 5.0).collect();
            let b: Vec<f32> = (0..len).map(|i| 2.5 - (i as f32) * 0.11).collect();
            assert_eq!(
                dot(&a, &b).to_bits(),
                dot_portable(&a, &b).to_bits(),
                "len {len} at level {}",
                level().name()
            );
            let q: Vec<i8> = (0..len).map(|i| ((i * 37) % 255) as i8).collect();
            assert_eq!(
                dot_i8(&a, &q).to_bits(),
                dot_i8_portable(&a, &q).to_bits(),
                "i8 len {len} at level {}",
                level().name()
            );
        }
    }

    #[test]
    fn sum_and_max_match_serial_reference() {
        let xs: Vec<f32> = (0..103).map(|i| ((i * 31) % 17) as f32 - 8.0).collect();
        let serial_max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert_eq!(max(&xs).to_bits(), serial_max.to_bits());
        let want: f64 = xs.iter().map(|&x| x as f64).sum();
        assert!((sum(&xs) as f64 - want).abs() <= 1e-3 * want.abs().max(1.0));
        assert_eq!(max(&[]), f32::NEG_INFINITY);
        assert_eq!(sum(&[]), 0.0);
    }

    #[test]
    fn exp_maps_match_scalar_dispatch_bitwise() {
        let xs: Vec<f32> = (0..57).map(|i| (i as f32) * 0.3 - 8.0).collect();
        let mut m1 = xs.clone();
        exp_map(&mut m1);
        let m2: Vec<f32> = xs.iter().map(|&x| exp(x)).collect();
        assert_eq!(m1, m2);
        let mut b1 = xs.clone();
        exp2_map(&mut b1);
        let b2: Vec<f32> = xs.iter().map(|&x| exp2(x)).collect();
        assert_eq!(b1, b2);
    }
}
