//! Execution runtime: host tensors plus a pluggable [`Backend`] seam.
//!
//! Two backends implement the same op-level contract (DESIGN.md §4 has
//! the selection matrix):
//!
//! * [`backend::NativeBackend`] — pure-Rust f32 kernels for the ConSmax /
//!   Softmax / Softermax normalizers and the bitwidth-split LUT datapath,
//!   mirroring `python/compile/kernels/`. Always compiled; needs no
//!   Python, no PJRT and no `artifacts/` directory. This is what CI and
//!   the default build run.
//! * [`Engine`] (`--features pjrt`) — loads the AOT artifacts
//!   (`artifacts/*.hlo.txt`, produced by `make artifacts`, i.e.
//!   `python -m compile.aot` — see the repo `Makefile` and
//!   `rust/README.md`) and executes them on the CPU PJRT client. The
//!   interchange format is **HLO text**: jax ≥ 0.5 serializes protos with
//!   64-bit instruction ids that xla_extension 0.5.1 rejects, while the
//!   text parser reassigns ids (see `python/compile/aot.py` and
//!   DESIGN.md §3).
//!
//! The coordinator layers (trainer/server/CLI) talk to whichever backend
//! is selected; training, evaluation, generation and serving all run on
//! the native backend (`backend::train` supplies the activation tape +
//! backward pass — DESIGN.md §Training seam), while the `pjrt` feature
//! adds the fused AOT `train_step` and the Fig 8 init sweep.
//!
//! [`parallel`] is the native compute layer's std-only worker pool
//! (`--threads` / `CONSMAX_THREADS`); its determinism contract — thread
//! count never changes results — is documented there and in DESIGN.md
//! §Parallel-compute seam.
//!
//! [`serve_net`] is the hardened TCP/HTTP serving front end (bounded
//! admission, deadlines, cancellation, graceful drain) over the
//! [`serve_net::ServeEngine`] seam; the coordinator adapts `Server`
//! onto it (DESIGN.md §Serving-robustness seam).

pub mod backend;
pub mod parallel;
pub mod serve_net;
pub mod tensor;

#[cfg(feature = "pjrt")]
pub mod engine;

#[cfg(feature = "pjrt")]
pub use engine::Engine;

pub use backend::{create_backend, Backend, BackendChoice, NativeBackend};
pub use tensor::{DType, HostTensor};
