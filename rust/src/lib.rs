//! # ConSmax — full-stack reproduction
//!
//! *ConSmax: Hardware-Friendly Alternative Softmax with Learnable
//! Parameters* (Liu et al., 2024) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **Layer 1** (`python/compile/kernels/`): the ConSmax normalizer (and
//!   softmax / softermax baselines) as Pallas kernels, plus the bit-exact
//!   bitwidth-split LUT model of the paper's hardware unit.
//! * **Layer 2** (`python/compile/model.py`): the paper's GPT benchmark
//!   model (6L / 6H / 384-embd / 256-ctx) with a pluggable score
//!   normalizer, AOT-lowered to HLO text once at build time.
//! * **Layer 3** (this crate): the coordinator that owns everything at
//!   run time — training loop, evaluation, generation server, plus the
//!   simulated hardware substrates that regenerate the paper's evaluation
//!   (synthesis estimator for Table I / Figs 9–10, cycle-accurate
//!   attention-pipeline simulator for Fig 5).
//!
//! Execution is backend-pluggable ([`runtime::Backend`], DESIGN.md §4):
//!
//! * the **native** backend re-implements the L1 kernels (and a fully
//!   differentiable GPT — forward, activation tape, backward) in pure
//!   Rust, so training, evaluation, generation, serving, the hardware
//!   report and the pipeline simulation all run from a bare checkout —
//!   no Python, no PJRT, no artifacts (DESIGN.md §Training seam);
//! * the **pjrt** backend (`--features pjrt`) executes the AOT artifacts:
//!   `make artifacts` lowers the JAX entry points to
//!   `artifacts/*.hlo.txt`, and [`runtime::Engine`] loads and executes
//!   them through PJRT (`xla` crate) — the fused single-dispatch
//!   train/eval/decode steps, plus the Fig 8 init sweep.
//!
//! See `DESIGN.md` for the experiment index and backend-selection matrix,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod util;

pub use config::RunConfig;
