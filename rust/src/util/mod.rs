//! Infrastructure substrates built in-repo.
//!
//! The offline crate mirror only carries the `xla` dependency closure, so
//! the usual ecosystem crates (serde, clap, rand, criterion, proptest,
//! half) are replaced by the small, fully-tested implementations here.
//! Each module documents the subset of behaviour it guarantees.

pub mod atomicio;
pub mod bench;
pub mod cli;
pub mod fp16;
pub mod json;
pub mod proptest;
pub mod rng;
