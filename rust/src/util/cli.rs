//! Tiny declarative CLI parser (replaces `clap`): subcommands, `--flag`,
//! `--key value` / `--key=value`, typed accessors with defaults, and
//! generated `--help` text.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, named options and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    BadValue {
        key: String,
        value: String,
        expected: &'static str,
    },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => {
                write!(f, "option --{name} expects a value")
            }
            CliError::BadValue { key, value, expected } => {
                write!(f, "invalid value for --{key}: {value:?} ({expected})")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Option/flag specification used for validation and help output.
#[derive(Debug, Clone)]
pub struct Spec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

impl Spec {
    pub fn opt(name: &'static str, help: &'static str) -> Spec {
        Spec { name, help, takes_value: true, default: None }
    }
    pub fn opt_default(
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Spec {
        Spec { name, help, takes_value: true, default: Some(default) }
    }
    pub fn flag(name: &'static str, help: &'static str) -> Spec {
        Spec { name, help, takes_value: false, default: None }
    }
}

impl Args {
    /// Parse `argv[1..]` against a spec list. The first non-option token
    /// is the subcommand; later bare tokens are positionals.
    pub fn parse(
        argv: impl IntoIterator<Item = String>,
        specs: &[Spec],
    ) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError::UnknownOption(key.clone()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| CliError::MissingValue(key.clone()))?,
                    };
                    out.opts.insert(key, val);
                } else {
                    out.flags.push(key);
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        // fill defaults
        for s in specs {
            if let Some(d) = s.default {
                out.opts.entry(s.name.to_string()).or_insert_with(|| d.into());
            }
        }
        Ok(out)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_string(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: name.into(),
                value: v.into(),
                expected: "unsigned integer",
            }),
        }
    }

    /// Like [`Args::get_usize`] but with no default: `None` when the
    /// option was not given at all (e.g. `--threads`, where absence
    /// means "keep the environment's choice").
    pub fn get_opt_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| CliError::BadValue {
                key: name.into(),
                value: v.into(),
                expected: "unsigned integer",
            }),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: name.into(),
                value: v.into(),
                expected: "number",
            }),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: name.into(),
                value: v.into(),
                expected: "unsigned integer",
            }),
        }
    }
}

/// Render a help screen for a command with subcommands and options.
pub fn render_help(
    bin: &str,
    about: &str,
    subcommands: &[(&str, &str)],
    specs: &[Spec],
) -> String {
    let mut s = format!("{bin} — {about}\n\nUSAGE:\n  {bin} <command> [options]\n");
    if !subcommands.is_empty() {
        s.push_str("\nCOMMANDS:\n");
        for (name, help) in subcommands {
            s.push_str(&format!("  {name:<16} {help}\n"));
        }
    }
    if !specs.is_empty() {
        s.push_str("\nOPTIONS:\n");
        for spec in specs {
            let mut left = format!("--{}", spec.name);
            if spec.takes_value {
                left.push_str(" <v>");
            }
            let def = spec
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {left:<24} {}{def}\n", spec.help));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<Spec> {
        vec![
            Spec::opt("steps", "number of steps"),
            Spec::opt_default("config", "tiny", "model config"),
            Spec::flag("verbose", "noisy output"),
        ]
    }

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()), &specs()).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--steps", "100", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["train", "--steps=42"]);
        assert_eq!(a.get_usize("steps", 0).unwrap(), 42);
    }

    #[test]
    fn defaults_fill_in() {
        let a = parse(&["train"]);
        assert_eq!(a.get("config"), Some("tiny"));
        assert_eq!(a.get_usize("steps", 7).unwrap(), 7);
    }

    #[test]
    fn positionals() {
        let a = parse(&["run", "alpha", "beta"]);
        assert_eq!(a.positional, vec!["alpha", "beta"]);
    }

    #[test]
    fn unknown_option_rejected() {
        let e = Args::parse(
            ["--nope".to_string()].into_iter(),
            &specs(),
        );
        assert!(matches!(e, Err(CliError::UnknownOption(_))));
    }

    #[test]
    fn missing_value_rejected() {
        let e = Args::parse(["--steps".to_string()].into_iter(), &specs());
        assert!(matches!(e, Err(CliError::MissingValue(_))));
    }

    #[test]
    fn bad_value_typed() {
        let a = parse(&["train", "--steps", "xyz"]);
        assert!(matches!(
            a.get_usize("steps", 0),
            Err(CliError::BadValue { .. })
        ));
    }

    #[test]
    fn opt_usize_distinguishes_absent_from_bad() {
        let a = parse(&["train", "--steps", "4"]);
        assert_eq!(a.get_opt_usize("steps").unwrap(), Some(4));
        let b = parse(&["train"]);
        assert_eq!(b.get_opt_usize("steps").unwrap(), None);
        let c = parse(&["train", "--steps", "zz"]);
        assert!(matches!(
            c.get_opt_usize("steps"),
            Err(CliError::BadValue { .. })
        ));
    }

    #[test]
    fn help_renders() {
        let h = render_help("consmax", "repro", &[("train", "t")], &specs());
        assert!(h.contains("--config"));
        assert!(h.contains("[default: tiny]"));
        assert!(h.contains("train"));
    }
}
