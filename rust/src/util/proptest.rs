//! Mini property-testing framework (replaces `proptest` — unavailable
//! offline).
//!
//! Properties are closures over a [`Gen`] handle; the runner executes N
//! seeded cases and, on failure, retries with the same seed while halving
//! integer sizes to report a *smaller* witness (bounded greedy shrinking).
//!
//! ```ignore
//! proptest!(|g| {
//!     let v = g.vec_f64(0..100, -1.0, 1.0);
//!     prop_assert!(v.len() < 100);
//! });
//! ```

use crate::util::rng::Pcg32;

/// Value generator handed to properties; wraps a deterministic PRNG plus
/// a size budget the shrinker reduces.
pub struct Gen {
    rng: Pcg32,
    /// Size multiplier in (0, 1]: shrinking lowers it to prefer smaller
    /// structures while replaying the same seed.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Gen {
        Gen { rng: Pcg32::seeded(seed), size }
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        let span = ((hi - lo) as f64 * self.size).max(1.0) as u64;
        self.rng.range_u64(lo, lo + span.min(hi - lo).max(1))
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.u64(0, (hi - lo) as u64) as i64
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, lo + (hi - lo) * self.size)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.rng.normal() as f32
    }

    pub fn vec_f32(&mut self, len_lo: usize, len_hi: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize(len_lo, len_hi.max(len_lo + 1));
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn vec_u8(&mut self, len_lo: usize, len_hi: usize) -> Vec<u8> {
        let n = self.usize(len_lo, len_hi.max(len_lo + 1));
        (0..n).map(|_| self.rng.next_u32() as u8).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len())]
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` seeded property cases; panic with the failing seed and the
/// smallest failing size found.
pub fn run_property(
    name: &str,
    cases: u64,
    mut prop: impl FnMut(&mut Gen) -> CaseResult,
) {
    let base_seed = 0xC0DE_5EED ^ fxhash(name);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case);
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // greedy shrink: replay same seed with smaller size budgets
            let mut best = (1.0, msg);
            let mut size = 0.5;
            while size > 0.01 {
                let mut g2 = Gen::new(seed, size);
                if let Err(m2) = prop(&mut g2) {
                    best = (size, m2);
                    size *= 0.5;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, case={case}, \
                 shrunk size={:.3}): {}",
                best.0, best.1
            );
        }
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert inside a property, returning Err instead of panicking so the
/// shrinker can drive.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !$cond {
            return Err(format!(
                "assertion failed: {} ({}:{})",
                stringify!($cond),
                file!(),
                line!()
            ));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Assert approximate equality with relative tolerance.
#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $rtol:expr) => {{
        let (a, b) = ($a as f64, $b as f64);
        let denom = a.abs().max(b.abs()).max(1e-300);
        if !((a - b).abs() / denom <= $rtol || (a - b).abs() < 1e-12) {
            return Err(format!(
                "not close: {a} vs {b} (rtol {}) at {}:{}",
                $rtol,
                file!(),
                line!()
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        run_property("tautology", 50, |g| {
            let x = g.u64(0, 100);
            prop_assert!(x < 100);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'falsifiable' failed")]
    fn failing_property_panics_with_seed() {
        run_property("falsifiable", 50, |g| {
            let x = g.u64(0, 100);
            prop_assert!(x < 2, "x={x}");
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = || {
            let mut out = Vec::new();
            run_property("det", 5, |g| {
                out.push(g.u64(0, 1000));
                Ok(())
            });
            out
        };
        // note: closure capture means we rebuild; just check stability
        assert_eq!(collect(), collect());
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(42, 1.0);
        for _ in 0..1000 {
            let v = g.i64(-5, 5);
            assert!((-5..5).contains(&v));
            let f = g.f64(2.0, 3.0);
            assert!((2.0..3.0).contains(&f));
        }
    }

    #[test]
    fn shrink_reduces_size() {
        // property fails only for big values; the reported shrink size
        // must be < 1.0 (we can't capture panic message easily, so check
        // the Gen mechanics directly)
        let mut big = Gen::new(7, 1.0);
        let mut small = Gen::new(7, 0.05);
        let vb = big.usize(0, 1000);
        let vs = small.usize(0, 1000);
        assert!(vs <= vb.max(50), "shrunk {vs} vs {vb}");
    }

    #[test]
    fn close_macro_works() {
        fn check() -> CaseResult {
            prop_assert_close!(1.0, 1.0 + 1e-9, 1e-6);
            Ok(())
        }
        assert!(check().is_ok());
        fn check_fail() -> CaseResult {
            prop_assert_close!(1.0, 2.0, 1e-6);
            Ok(())
        }
        assert!(check_fail().is_err());
    }
}
