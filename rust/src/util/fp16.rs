//! IEEE 754 binary16 software float (replaces the `half` crate).
//!
//! The hardware model (`quant::lut`) must reproduce the paper's FP16
//! datapath bit-for-bit, so conversions implement round-to-nearest-even
//! exactly. Products of two binary16 values are exact in f32 (11-bit
//! significands -> 22-bit product < 24), so `mul` = convert → f32 multiply
//! → RNE convert is the correctly-rounded binary16 multiply, matching both
//! the hardware multiplier and numpy's float16 semantics.

/// IEEE 754 binary16 value, stored as its bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct F16(pub u16);

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const ONE: F16 = F16(0x3C00);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    pub const MAX: F16 = F16(0x7BFF); // 65504

    pub fn from_bits(bits: u16) -> F16 {
        F16(bits)
    }

    pub fn to_bits(self) -> u16 {
        self.0
    }

    /// Convert from f32 with round-to-nearest-even (the hardware rounding).
    pub fn from_f32(x: f32) -> F16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let man = bits & 0x007F_FFFF;

        if exp == 0xFF {
            // Inf / NaN
            return if man == 0 {
                F16(sign | 0x7C00)
            } else {
                F16(sign | 0x7E00) // quiet NaN
            };
        }

        // unbiased exponent
        let e = exp - 127;
        if e > 15 {
            // overflow -> infinity
            return F16(sign | 0x7C00);
        }
        if e >= -14 {
            // normal range: 10-bit mantissa, RNE on the dropped 13 bits
            let mant = man >> 13;
            let rest = man & 0x1FFF;
            let halfway = 0x1000;
            let mut h = ((e + 15) as u16) << 10 | mant as u16;
            if rest > halfway || (rest == halfway && (h & 1) == 1) {
                h += 1; // carries propagate into the exponent correctly
            }
            return F16(sign | h);
        }
        if e >= -25 {
            // subnormal: shift the implicit-1 mantissa right
            let full = 0x0080_0000 | man; // 24-bit significand
            let shift = (-14 - e) + 13;
            let mant = full >> shift;
            let rest = full & ((1u32 << shift) - 1);
            let halfway = 1u32 << (shift - 1);
            let mut h = mant as u16;
            if rest > halfway || (rest == halfway && (h & 1) == 1) {
                h += 1;
            }
            return F16(sign | h);
        }
        // underflow to signed zero
        F16(sign)
    }

    /// Exact widening conversion to f32.
    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 & 0x8000) as u32) << 16;
        let exp = ((self.0 >> 10) & 0x1F) as u32;
        let man = (self.0 & 0x3FF) as u32;
        let bits = match (exp, man) {
            (0, 0) => sign,
            (0, m) => {
                // subnormal: value = m * 2^-24 = 1.x * 2^(p-24), p = msb pos
                let p = 31 - m.leading_zeros(); // 0..9
                let e = p + 103; // (p - 24) + 127
                let mant = (m << (23 - p)) & 0x007F_FFFF;
                sign | (e << 23) | mant
            }
            (0x1F, 0) => sign | 0x7F80_0000,
            (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
            (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
        };
        f32::from_bits(bits)
    }

    /// Correctly-rounded binary16 multiply (see module docs).
    pub fn mul(self, rhs: F16) -> F16 {
        F16::from_f32(self.to_f32() * rhs.to_f32())
    }

    /// Correctly-rounded binary16 add (exact in f32, single rounding).
    pub fn add(self, rhs: F16) -> F16 {
        F16::from_f32(self.to_f32() + rhs.to_f32())
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x3FF) != 0
    }

    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }

    pub fn is_finite(self) -> bool {
        (self.0 & 0x7C00) != 0x7C00
    }
}

/// bfloat16 (truncated f32 with RNE), used by the mixed-precision tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub fn from_f32(x: f32) -> Bf16 {
        let bits = x.to_bits();
        if x.is_nan() {
            return Bf16(((bits >> 16) as u16) | 0x0040); // keep quiet
        }
        let round_bit = 0x8000u32;
        let lower = bits & 0xFFFF;
        let mut hi = (bits >> 16) as u16;
        if lower > round_bit || (lower == round_bit && (hi & 1) == 1) {
            hi = hi.wrapping_add(1);
        }
        Bf16(hi)
    }

    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    pub fn to_bits(self) -> u16 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_constants() {
        assert_eq!(F16::from_f32(1.0).to_bits(), 0x3C00);
        assert_eq!(F16::from_f32(-2.0).to_bits(), 0xC000);
        assert_eq!(F16::from_f32(0.5).to_bits(), 0x3800);
        assert_eq!(F16::from_f32(65504.0).to_bits(), 0x7BFF);
        assert_eq!(F16::from_f32(0.0).to_bits(), 0x0000);
        assert_eq!(F16::from_f32(-0.0).to_bits(), 0x8000);
    }

    #[test]
    fn overflow_to_inf() {
        assert_eq!(F16::from_f32(65520.0).to_bits(), 0x7C00);
        assert_eq!(F16::from_f32(1e30).to_bits(), 0x7C00);
        assert_eq!(F16::from_f32(-1e30).to_bits(), 0xFC00);
    }

    #[test]
    fn subnormals() {
        // smallest positive subnormal: 2^-24
        let tiny = 2.0_f32.powi(-24);
        assert_eq!(F16::from_f32(tiny).to_bits(), 0x0001);
        assert_eq!(F16(0x0001).to_f32(), tiny);
        // largest subnormal
        let big_sub = 1023.0 * 2.0_f32.powi(-24);
        assert_eq!(F16::from_f32(big_sub).to_bits(), 0x03FF);
        assert_eq!(F16(0x03FF).to_f32(), big_sub);
    }

    #[test]
    fn underflow_to_zero() {
        assert_eq!(F16::from_f32(1e-12).to_bits(), 0x0000);
        assert_eq!(F16::from_f32(-1e-12).to_bits(), 0x8000);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10 -> ties to
        // even mantissa (1.0)
        let x = 1.0 + 2.0_f32.powi(-11);
        assert_eq!(F16::from_f32(x).to_bits(), 0x3C00);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9 -> rounds to
        // even (1 + 2^-9 has even mantissa 0b10)
        let y = 1.0 + 3.0 * 2.0_f32.powi(-11);
        assert_eq!(F16::from_f32(y).to_bits(), 0x3C02);
    }

    #[test]
    fn rounding_carry_into_exponent() {
        // just below 2.0: 1.9999999 rounds up to 2.0
        assert_eq!(F16::from_f32(1.999_999_9).to_bits(), 0x4000);
    }

    #[test]
    fn roundtrip_all_finite_f16() {
        // EXHAUSTIVE: every finite f16 must roundtrip exactly through f32
        for bits in 0..=0xFFFFu16 {
            let h = F16(bits);
            if h.is_nan() {
                continue;
            }
            let rt = F16::from_f32(h.to_f32());
            assert_eq!(rt.to_bits(), bits, "bits {bits:#06x}");
        }
    }

    #[test]
    fn nan_propagates() {
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert!(F16(0x7E00).to_f32().is_nan());
    }

    #[test]
    fn inf_conversions() {
        assert_eq!(F16::from_f32(f32::INFINITY), F16::INFINITY);
        assert_eq!(F16::INFINITY.to_f32(), f32::INFINITY);
        assert_eq!(F16::NEG_INFINITY.to_f32(), f32::NEG_INFINITY);
    }

    #[test]
    fn mul_matches_exhaustive_sample() {
        // spot-check the exactness argument on a structured grid
        for a in (0..=0x7BFF_u16).step_by(97) {
            for b in (0..=0x7BFF_u16).step_by(1013) {
                let x = F16(a);
                let y = F16(b);
                if !x.is_finite() || !y.is_finite() {
                    continue;
                }
                let got = x.mul(y);
                // reference: f64 product rounded once to f16
                let want = F16::from_f32((x.to_f32() as f64 * y.to_f32() as f64) as f32);
                assert_eq!(got.to_bits(), want.to_bits(), "{a:#x} * {b:#x}");
            }
        }
    }

    #[test]
    fn bf16_roundtrip_and_rounding() {
        assert_eq!(Bf16::from_f32(1.0).to_bits(), 0x3F80);
        // RNE at the bf16 boundary
        let x = f32::from_bits(0x3F80_8000); // halfway
        assert_eq!(Bf16::from_f32(x).to_bits(), 0x3F80); // ties to even
        let y = f32::from_bits(0x3F81_8000);
        assert_eq!(Bf16::from_f32(y).to_bits(), 0x3F82);
    }

    // The KV cache stores keys/values through these codecs
    // (runtime/backend/kvcache.rs), so their corner cases are
    // load-bearing for serving: ties, subnormals, overflow.

    #[test]
    fn f16_subnormal_ties_to_even() {
        // 2^-25 is exactly halfway between 0 and the smallest subnormal
        // 2^-24: RNE picks the even mantissa (zero)
        assert_eq!(F16::from_f32(2.0_f32.powi(-25)).to_bits(), 0x0000);
        assert_eq!(F16::from_f32(-(2.0_f32.powi(-25))).to_bits(), 0x8000);
        // 3·2^-25 is halfway between subnormals 1 and 2: ties to 2 (even)
        assert_eq!(F16::from_f32(3.0 * 2.0_f32.powi(-25)).to_bits(), 0x0002);
        // just above the halfway point rounds up to mantissa 1
        let above = f32::from_bits((2.0_f32.powi(-25)).to_bits() + 1);
        assert_eq!(F16::from_f32(above).to_bits(), 0x0001);
        // tie between subnormals 2 and 3 (5·2^-25): even mantissa 2
        assert_eq!(F16::from_f32(5.0 * 2.0_f32.powi(-25)).to_bits(), 0x0002);
    }

    #[test]
    fn f16_ties_round_to_even_in_normal_range() {
        // f16 spacing at this scale is 2, so 2049 is the exact tie
        // point between 2048 (mantissa 0, even) and 2050 (mantissa 1)
        assert_eq!(F16::from_f32(2049.0).to_bits(), F16::from_f32(2048.0).to_bits());
        // 2051 ties between 2050 and 2052: even mantissa wins (2052)
        assert_eq!(F16::from_f32(2051.0).to_bits(), F16::from_f32(2052.0).to_bits());
    }

    #[test]
    fn f16_overflow_boundary_to_inf() {
        // 65504 is F16::MAX; the rounding boundary to inf is 65520
        assert_eq!(F16::from_f32(65504.0), F16::MAX);
        assert_eq!(F16::from_f32(65519.9), F16::MAX); // below the boundary
        assert_eq!(F16::from_f32(65520.0), F16::INFINITY); // at it
        assert_eq!(F16::from_f32(-65520.0), F16::NEG_INFINITY);
        assert_eq!(F16::from_f32(f32::MAX), F16::INFINITY);
        assert_eq!(F16::from_f32(f32::MIN), F16::NEG_INFINITY);
    }

    #[test]
    fn bf16_overflow_to_inf() {
        // bf16 shares f32's exponent range, so only *rounding* can
        // overflow: f32::MAX (0x7F7F_FFFF) rounds up to +inf (0x7F80)
        assert_eq!(Bf16::from_f32(f32::MAX).to_bits(), 0x7F80);
        assert!(Bf16::from_f32(f32::MAX).to_f32().is_infinite());
        assert_eq!(Bf16::from_f32(f32::MIN).to_bits(), 0xFF80);
        assert!(Bf16::from_f32(f32::MIN).to_f32().is_infinite());
        // infinities pass through exactly
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_bits(), 0x7F80);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_bits(), 0xFF80);
        // the largest f32 that does NOT round up stays finite
        let below = f32::from_bits(0x7F7F_7FFF);
        assert_eq!(Bf16::from_f32(below).to_bits(), 0x7F7F);
        assert!(Bf16(0x7F7F).to_f32().is_finite());
    }

    #[test]
    fn bf16_subnormal_roundtrips() {
        // smallest positive bf16 subnormal: 2^-133 (f32 bits 0x0001_0000)
        let tiny = f32::from_bits(0x0001_0000);
        assert_eq!(Bf16::from_f32(tiny).to_bits(), 0x0001);
        assert_eq!(Bf16(0x0001).to_f32().to_bits(), tiny.to_bits());
        // largest bf16 subnormal: mantissa 0x7F at exponent 0
        let big_sub = f32::from_bits(0x007F_0000);
        assert_eq!(Bf16::from_f32(big_sub).to_bits(), 0x007F);
        assert_eq!(Bf16(0x007F).to_f32().to_bits(), big_sub.to_bits());
        // below the smallest subnormal's halfway point: flushes to zero
        let sub_tiny = f32::from_bits(0x0000_7FFF);
        assert_eq!(Bf16::from_f32(sub_tiny).to_bits(), 0x0000);
    }

    #[test]
    fn bf16_roundtrip_all_finite_bit_patterns() {
        // EXHAUSTIVE: every non-NaN bf16 round-trips exactly through f32
        // (to_f32 is a shift; from_f32 of an exact value must not move)
        for bits in 0..=0xFFFFu16 {
            let b = Bf16(bits);
            if b.to_f32().is_nan() {
                continue;
            }
            assert_eq!(Bf16::from_f32(b.to_f32()).to_bits(), bits, "{bits:#06x}");
        }
    }
}
