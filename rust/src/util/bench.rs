//! Micro-benchmark harness (replaces `criterion` — unavailable offline).
//!
//! Used by the `harness = false` bench targets in `rust/benches/`.
//! Methodology: warmup, then timed batches sized to a target duration,
//! reporting median / mean / p95 with outlier-robust statistics. Results
//! can be emitted as text and machine-readable JSON lines for
//! EXPERIMENTS.md bookkeeping.

use std::time::{Duration, Instant};

use crate::util::json::Json;

/// One benchmark's collected statistics (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs([
            ("name".into(), Json::from(self.name.as_str())),
            ("iters".into(), Json::from(self.iters as f64)),
            ("median_ns".into(), Json::from(self.median_ns)),
            ("mean_ns".into(), Json::from(self.mean_ns)),
            ("p95_ns".into(), Json::from(self.p95_ns)),
            ("min_ns".into(), Json::from(self.min_ns)),
        ])
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner with criterion-like ergonomics.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(900),
            min_samples: 12,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile for expensive end-to-end benches.
    pub fn coarse() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(400),
            min_samples: 5,
            results: Vec::new(),
        }
    }

    /// Time `f`, preventing the result from being optimized away.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        // Warmup + estimate cost of one call.
        let wstart = Instant::now();
        let mut calls = 0u64;
        while wstart.elapsed() < self.warmup || calls == 0 {
            std::hint::black_box(f());
            calls += 1;
            if calls > 1_000_000 {
                break;
            }
        }
        let per_call = self.warmup.as_nanos() as f64 / calls as f64;

        // Choose batch size so one sample is ~ measure/min_samples.
        let target_sample_ns =
            (self.measure.as_nanos() as f64 / self.min_samples as f64).max(1.0);
        let batch = ((target_sample_ns / per_call.max(1.0)) as u64).max(1);

        let mut samples: Vec<f64> = Vec::new();
        let mstart = Instant::now();
        let mut total_iters = 0u64;
        while mstart.elapsed() < self.measure || samples.len() < self.min_samples
        {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(dt);
            total_iters += batch;
            if samples.len() > 10_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p95 = samples[((samples.len() as f64 * 0.95) as usize)
            .min(samples.len() - 1)];
        let min = samples[0];
        let stats = Stats {
            name: name.to_string(),
            iters: total_iters,
            median_ns: median,
            mean_ns: mean,
            p95_ns: p95,
            min_ns: min,
        };
        println!(
            "{name:<48} {:>12}/iter  (mean {}, p95 {}, {} iters)",
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p95_ns),
            total_iters,
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// JSON-lines dump for post-processing.
    pub fn dump_json(&self) -> String {
        self.results
            .iter()
            .map(|s| s.to_json().to_string())
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Persist the JSON-lines dump, one `Stats` object per line — the
    /// raw-timings companion a summarizing bench writes next to its
    /// digest (e.g. `decode_bench`'s `BENCH_decode_raw.jsonl` beside
    /// `BENCH_decode.json`).
    pub fn save_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.dump_json())
    }
}

/// Print a markdown-style table: used by the paper-table benches so the
/// bench output *is* the reproduced table.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}\n");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut line = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
        }
        line
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    println!("{}", fmt_row(&sep));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_samples: 3,
            results: vec![],
        };
        let s = b.bench("noop-ish", || std::hint::black_box(1 + 1)).clone();
        assert!(s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.p95_ns * 1.001);
        assert!(s.iters > 0);
    }

    #[test]
    fn slower_function_measures_slower() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            min_samples: 3,
            results: vec![],
        };
        let fast = b.bench("fast", || std::hint::black_box(0u64)).median_ns;
        let slow = b
            .bench("slow", || {
                let mut acc = 0u64;
                for i in 0..5_000u64 {
                    acc = acc.wrapping_add(std::hint::black_box(i * i));
                }
                acc
            })
            .median_ns;
        assert!(slow > fast * 5.0, "fast={fast} slow={slow}");
    }

    #[test]
    fn json_dump_parses() {
        let mut b = Bencher {
            warmup: Duration::from_millis(2),
            measure: Duration::from_millis(5),
            min_samples: 2,
            results: vec![],
        };
        b.bench("x", || 1);
        let line = b.dump_json();
        let v = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(v.get("name").as_str(), Some("x"));
    }

    #[test]
    fn save_json_roundtrips_through_disk() {
        let mut b = Bencher {
            warmup: Duration::from_millis(2),
            measure: Duration::from_millis(5),
            min_samples: 2,
            results: vec![],
        };
        b.bench("persisted", || 1);
        let path = std::env::temp_dir().join("consmax_bench_save_json.jsonl");
        b.save_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(v.get("name").as_str(), Some("persisted"));
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
