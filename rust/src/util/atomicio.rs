//! Crash-safe file writes: stage into a temp file, then rename.
//!
//! A `File::create` + `write_all` sequence that dies mid-write (SIGKILL,
//! OOM, power loss) leaves a truncated file at the final path — and a
//! truncated checkpoint is worse than none, because `consmax train
//! --resume` will try to load it. [`write_atomic`] stages the bytes into
//! a sibling temp file in the *same directory* (renames across
//! filesystems are not atomic) and `rename`s it over the target only
//! after every byte is flushed, so readers see either the old complete
//! file or the new complete file, never a prefix.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Temp-file sibling for `path`: same directory, hidden, pid-tagged so
/// concurrent writers from different processes never collide.
fn staging_path(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "file".into());
    path.with_file_name(format!(".{name}.tmp.{}", std::process::id()))
}

/// Write `path` atomically: `fill` streams into a temp file in the same
/// directory, which is flushed and renamed over `path` on success. On
/// any error the temp file is removed and the prior `path` contents (if
/// any) are left untouched.
pub fn write_atomic(path: &Path, fill: impl FnOnce(&mut File) -> Result<()>) -> Result<()> {
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = staging_path(path);
    let result = (|| -> Result<()> {
        let mut f = File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        fill(&mut f)?;
        f.flush()?;
        f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
        drop(f);
        std::fs::rename(&tmp, path).with_context(|| format!("renaming to {}", path.display()))?;
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// [`write_atomic`] for a single in-memory buffer.
pub fn write_bytes_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    write_atomic(path, |f| {
        f.write_all(bytes)?;
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::bail;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("consmax_atomicio_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_and_overwrites() {
        let dir = tmpdir("basic");
        let p = dir.join("out.bin");
        write_bytes_atomic(&p, b"first").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"first");
        write_bytes_atomic(&p, b"second, longer").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second, longer");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn failed_write_preserves_previous_contents() {
        let dir = tmpdir("preserve");
        let p = dir.join("ckpt.bin");
        write_bytes_atomic(&p, b"good checkpoint").unwrap();
        let err = write_atomic(&p, |f| {
            f.write_all(b"partial garbage")?;
            bail!("simulated crash mid-serialize")
        });
        assert!(err.is_err());
        // The original survives and no staging file is left behind.
        assert_eq!(std::fs::read(&p).unwrap(), b"good checkpoint");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name())
            .filter(|n| n.to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "staging leak: {leftovers:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn creates_missing_parent_dirs() {
        let dir = tmpdir("parents");
        let p = dir.join("a/b/c.txt");
        write_bytes_atomic(&p, b"deep").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"deep");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
