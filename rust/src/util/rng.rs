//! Deterministic PRNG substrate (replaces the `rand` crate).
//!
//! [`Pcg32`] — O'Neill's PCG-XSH-RR 64/32, the same generator family the
//! `rand` crate's `Pcg32` uses; statistically solid for simulation and
//! data-generation workloads, and fully reproducible from a seed.

/// PCG-XSH-RR 64/32.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create from a seed and stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Single-argument constructor with a fixed stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 random bits -> exactly representable dyadic in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// N(mu, sigma).
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Exponential with rate lambda (inter-arrival times for the serving
    /// workload generator).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.uniform().ln_1p_neg() / lambda
    }

    /// Sample an index from unnormalized weights (Zipfian corpus, request
    /// mixes). Panics on empty or all-zero weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must have positive mass");
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Vector of standard normals as f32 (parameter init, test data).
    pub fn normal_vec_f32(&mut self, n: usize, mu: f32, sigma: f32) -> Vec<f32> {
        (0..n)
            .map(|_| self.normal_with(mu as f64, sigma as f64) as f32)
            .collect()
    }
}

fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

trait LnOneMinusExt {
    /// ln(1 - x) computed as ln_1p(-x); keeps exponential() readable.
    fn ln_1p_neg(self) -> f64;
}
impl LnOneMinusExt for f64 {
    fn ln_1p_neg(self) -> f64 {
        (-self).ln_1p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Pcg32::seeded(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_is_unbiased_range() {
        let mut r = Pcg32::seeded(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg32::seeded(7);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn weighted_follows_weights() {
        let mut r = Pcg32::seeded(8);
        let w = [1.0, 3.0];
        let mut c = [0usize; 2];
        for _ in 0..40_000 {
            c[r.weighted(&w)] += 1;
        }
        let frac = c[1] as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "{frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_u64_bounds() {
        let mut r = Pcg32::seeded(10);
        for _ in 0..1000 {
            let x = r.range_u64(5, 9);
            assert!((5..9).contains(&x));
        }
    }
}
