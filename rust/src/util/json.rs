//! Minimal JSON: a value model, a recursive-descent parser and a
//! serializer. Replaces `serde_json` for the artifact manifest, golden
//! vectors, run configs, checkpoints and metric logs.
//!
//! Supported: the full JSON grammar (RFC 8259) minus `\u` surrogate-pair
//! edge cases beyond the BMP (accepted, replaced leniently). Numbers are
//! kept as `f64`, which is lossless for every integer the manifest emits
//! (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object keys are sorted (BTreeMap) so serialization is deterministic.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ----- constructors ---------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs<I: IntoIterator<Item = (String, Json)>>(it: I) -> Json {
        Json::Obj(it.into_iter().collect())
    }

    // ----- accessors ------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64()
            .and_then(|n| (n.fract() == 0.0).then_some(n as i64))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `value["key"]`-style access; returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Index into an array; `Json::Null` when out of bounds.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), val);
        }
    }

    /// Convenience: array of numbers -> Vec<f64>.
    pub fn to_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    pub fn to_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    // ----- parsing --------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let b = text.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- serialization ----------------------------------------------------

    /// Compact serialization (no whitespace).
    #[allow(clippy::inherent_to_string_shadow_display)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null like serde_json's lossy mode.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // shortest roundtrip representation
        let s = format!("{n}");
        out.push_str(&s);
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let start = self.pos + 1;
                            if start + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[start..start + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".into())
        );
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(v.get("nope").get("deeper"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"num":42,"s":"hi \"q\"","t":true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(42.5).to_string(), "42.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn large_ints_roundtrip_exactly() {
        let v = Json::parse("9007199254740991").unwrap(); // 2^53 - 1
        assert_eq!(v.as_i64(), Some(9007199254740991));
    }

    #[test]
    fn vec_helpers() {
        let v = Json::parse("[1,2,3]").unwrap();
        assert_eq!(v.to_usize_vec(), Some(vec![1, 2, 3]));
        assert_eq!(v.to_f64_vec(), Some(vec![1.0, 2.0, 3.0]));
        assert_eq!(Json::parse("[1,\"x\"]").unwrap().to_f64_vec(), None);
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
