//! Run metrics: scalar time series (loss, perplexity, beta/gamma traces,
//! latency percentiles) with JSON-lines persistence. This is what the
//! trainer and server log through, and what EXPERIMENTS.md numbers are
//! extracted from.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// A named series of (step, value) points.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub points: Vec<(u64, f64)>,
}

impl Series {
    pub fn push(&mut self, step: u64, value: f64) {
        self.points.push((step, value));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    pub fn min(&self) -> Option<f64> {
        self.points
            .iter()
            .map(|&(_, v)| v)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Mean over the last `n` points (smoothing for noisy loss curves).
    pub fn tail_mean(&self, n: usize) -> Option<f64> {
        if self.points.is_empty() {
            return None;
        }
        let tail = &self.points[self.points.len().saturating_sub(n)..];
        Some(tail.iter().map(|&(_, v)| v).sum::<f64>() / tail.len() as f64)
    }
}

/// Metric registry for one run.
#[derive(Debug, Default)]
pub struct Metrics {
    pub series: BTreeMap<String, Series>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn log(&mut self, name: &str, step: u64, value: f64) {
        self.series.entry(name.to_string()).or_default().push(step, value);
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// Serialize every series as JSON lines: {"series": "...", "step": s, "value": v}.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for (name, series) in &self.series {
            for &(step, value) in &series.points {
                let row = Json::from_pairs([
                    ("series".into(), Json::from(name.as_str())),
                    ("step".into(), Json::from(step as f64)),
                    ("value".into(), Json::from(value)),
                ]);
                out.push_str(&row.to_string());
                out.push('\n');
            }
        }
        out
    }

    /// Atomic (temp + rename): a run killed mid-save never leaves a
    /// truncated `runs/*_train.jsonl` behind.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        crate::util::atomicio::write_bytes_atomic(path.as_ref(), self.to_jsonl().as_bytes())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Metrics> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let mut m = Metrics::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let v = Json::parse(line)?;
            let name = v.get("series").as_str().context("series")?;
            let step = v.get("step").as_f64().context("step")? as u64;
            let value = v.get("value").as_f64().context("value")?;
            m.log(name, step, value);
        }
        Ok(m)
    }
}

/// Latency recorder with percentile queries (serving metrics).
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples_us: Vec<f64>,
    /// Lazily maintained ascending view of `samples_us`. A percentile
    /// query used to clone and sort the full sample vec on every call
    /// (O(n log n) per percentile, per report); now the sort runs at
    /// most once per batch of new records and repeat queries are O(1).
    sorted: std::cell::RefCell<Vec<f64>>,
}

impl LatencyRecorder {
    pub fn record_us(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn percentile(&self, p: f64) -> Option<f64> {
        if self.samples_us.is_empty() {
            return None;
        }
        let mut s = self.sorted.borrow_mut();
        if s.len() != self.samples_us.len() {
            // samples arrived since the last query: rebuild the view
            s.clear();
            s.extend_from_slice(&self.samples_us);
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        // nearest-rank method: idx = ceil(p/100 * N) - 1
        let rank = ((p / 100.0) * s.len() as f64).ceil() as isize - 1;
        let idx = rank.max(0) as usize;
        Some(s[idx.min(s.len() - 1)])
    }

    pub fn mean(&self) -> Option<f64> {
        if self.samples_us.is_empty() {
            return None;
        }
        Some(self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64)
    }
}

/// Perplexity from mean NLL (the paper's Fig 6 metric).
pub fn perplexity(mean_nll: f64) -> f64 {
    mean_nll.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_basics() {
        let mut m = Metrics::new();
        m.log("loss", 0, 5.5);
        m.log("loss", 10, 4.2);
        m.log("ppl", 10, 66.7);
        let loss = m.get("loss").unwrap();
        assert_eq!(loss.last(), Some(4.2));
        assert_eq!(loss.min(), Some(4.2));
        assert_eq!(loss.tail_mean(1), Some(4.2));
        assert_eq!(loss.tail_mean(10), Some((5.5 + 4.2) / 2.0));
    }

    #[test]
    fn jsonl_roundtrip() {
        let mut m = Metrics::new();
        m.log("a", 1, 2.0);
        m.log("b", 3, -0.5);
        let dir = std::env::temp_dir().join("consmax_metrics_test");
        let path = dir.join("metrics.jsonl");
        m.save(&path).unwrap();
        let m2 = Metrics::load(&path).unwrap();
        assert_eq!(m2.get("a").unwrap().points, vec![(1, 2.0)]);
        assert_eq!(m2.get("b").unwrap().points, vec![(3, -0.5)]);
    }

    #[test]
    fn latency_percentiles() {
        let mut l = LatencyRecorder::default();
        for i in 1..=100 {
            l.record_us(i as f64);
        }
        assert_eq!(l.percentile(50.0), Some(50.0));
        assert_eq!(l.percentile(99.0), Some(99.0));
        assert_eq!(l.percentile(0.0), Some(1.0));
        assert!((l.mean().unwrap() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn percentile_cache_tracks_new_samples() {
        // the sorted view is a cache: records landing after a query must
        // invalidate it, and query order must not affect results
        let mut l = LatencyRecorder::default();
        l.record_us(10.0);
        assert_eq!(l.percentile(50.0), Some(10.0));
        l.record_us(5.0);
        l.record_us(1.0);
        assert_eq!(l.percentile(0.0), Some(1.0));
        assert_eq!(l.percentile(100.0), Some(10.0));
        l.record_us(20.0);
        assert_eq!(l.percentile(100.0), Some(20.0));
        assert_eq!(l.percentile(50.0), Some(5.0));
        assert_eq!(l.len(), 4);
    }

    #[test]
    fn empty_latency() {
        let l = LatencyRecorder::default();
        assert_eq!(l.percentile(50.0), None);
        assert_eq!(l.mean(), None);
    }

    #[test]
    fn perplexity_of_uniform_byte_model() {
        // ln(256) nats -> ppl 256
        assert!((perplexity((256f64).ln()) - 256.0).abs() < 1e-9);
    }
}
