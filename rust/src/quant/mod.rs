//! Quantization + the bit-exact software model of the bitwidth-split
//! ConSmax hardware unit (paper §IV-A).
//!
//! This is the Rust twin of `python/compile/kernels/lut.py`/`ref.py`; the
//! two are pinned to identical output *bits* by the golden vectors
//! checked in at `rust/tests/golden/golden.json` (regenerated into
//! `artifacts/golden.json` by `make artifacts`; see
//! `rust/tests/quant_cross_validation.rs`).
//! The serving coordinator uses it to post-process INT8 score streams the
//! way the real accelerator would, and the hw substrate uses its table
//! sizes for area accounting.

pub mod lut;

pub use lut::{BitSplitLut, ReductionUnit};

use crate::util::fp16::F16;

/// Symmetric INT8 quantizer with a power-of-two scale (hardware-friendly:
/// dequantization is an exponent shift).
#[derive(Debug, Clone, Copy)]
pub struct Int8Quantizer {
    pub scale: f32,
}

impl Int8Quantizer {
    pub fn new(scale: f32) -> Int8Quantizer {
        assert!(scale > 0.0);
        Int8Quantizer { scale }
    }

    /// The paper's operating point: scores in [-8, 8) at 1/16 resolution.
    pub fn paper() -> Int8Quantizer {
        Int8Quantizer::new(1.0 / 16.0)
    }

    /// Round-to-nearest (ties away from zero, like `f32::round`), saturating.
    pub fn quantize(&self, x: f32) -> i8 {
        let q = (x / self.scale).round();
        q.clamp(-128.0, 127.0) as i8
    }

    pub fn dequantize(&self, q: i8) -> f32 {
        q as f32 * self.scale
    }

    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<i8> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Max absolute dequantization error for in-range inputs.
    pub fn max_error(&self) -> f32 {
        self.scale / 2.0
    }

    /// Pick the scale that covers `max_abs` with full code range,
    /// rounded to a power of two (hardware shift-dequant).
    pub fn fit(max_abs: f32) -> Int8Quantizer {
        let raw = max_abs / 127.0;
        let exp = raw.log2().ceil();
        Int8Quantizer::new(exp.exp2())
    }
}

/// The merged inference constant C = exp(-beta)/gamma (paper Eq. 3; see
/// `ref.py` for the sign-typo note), rounded to the fp16 the multiplier
/// consumes.
pub fn merge_beta_gamma(beta: f32, gamma: f32) -> F16 {
    F16::from_f32((-beta).exp() / gamma)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let q = Int8Quantizer::paper();
        for i in 0..1000 {
            let x = -7.9 + 15.8 * (i as f32 / 999.0);
            let err = (q.dequantize(q.quantize(x)) - x).abs();
            assert!(err <= q.max_error() + 1e-6, "x={x} err={err}");
        }
    }

    #[test]
    fn quantize_saturates() {
        let q = Int8Quantizer::paper();
        assert_eq!(q.quantize(1e9), 127);
        assert_eq!(q.quantize(-1e9), -128);
    }

    #[test]
    fn exact_codes_roundtrip() {
        let q = Int8Quantizer::paper();
        for code in -128i16..=127 {
            let code = code as i8;
            assert_eq!(q.quantize(q.dequantize(code)), code);
        }
    }

    #[test]
    fn fit_covers_range_with_pow2_scale() {
        let q = Int8Quantizer::fit(10.0);
        assert!(q.scale.log2().fract() == 0.0, "scale {}", q.scale);
        assert_eq!(q.quantize(10.0).unsigned_abs() as i32 as f32 * q.scale >= 9.0, true);
        assert!(q.quantize(10.0) < 127 || q.quantize(10.0) == 127);
    }

    #[test]
    fn merge_matches_f32_math() {
        let c = merge_beta_gamma(1.5, 100.0);
        let want = F16::from_f32((-1.5f32).exp() / 100.0);
        assert_eq!(c.to_bits(), want.to_bits());
    }
}
