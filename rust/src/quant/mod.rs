//! Quantization + the bit-exact software model of the bitwidth-split
//! ConSmax hardware unit (paper §IV-A).
//!
//! This is the Rust twin of `python/compile/kernels/lut.py`/`ref.py`; the
//! two are pinned to identical output *bits* by the golden vectors
//! checked in at `rust/tests/golden/golden.json` (regenerated into
//! `artifacts/golden.json` by `make artifacts`; see
//! `rust/tests/quant_cross_validation.rs`).
//! The serving coordinator uses it to post-process INT8 score streams the
//! way the real accelerator would, and the hw substrate uses its table
//! sizes for area accounting. Under `--quant int8` the native serving
//! path also quantizes here: [`QuantizedMatrix`] holds the per-channel
//! int8 projection weights and [`kv_vec_scale`]/[`quantize_i8`]/
//! [`dequantize_i8`] define the per-vector int8 KV storage transform
//! (DESIGN.md §Quantization seam).

pub mod lut;

pub use lut::{BitSplitLut, ReductionUnit};

use crate::util::fp16::F16;

/// Symmetric INT8 quantizer with a power-of-two scale (hardware-friendly:
/// dequantization is an exponent shift).
#[derive(Debug, Clone, Copy)]
pub struct Int8Quantizer {
    pub scale: f32,
}

impl Int8Quantizer {
    pub fn new(scale: f32) -> Int8Quantizer {
        assert!(scale > 0.0);
        Int8Quantizer { scale }
    }

    /// The paper's operating point: scores in [-8, 8) at 1/16 resolution.
    pub fn paper() -> Int8Quantizer {
        Int8Quantizer::new(1.0 / 16.0)
    }

    /// Round-to-nearest (ties away from zero, like `f32::round`), saturating.
    pub fn quantize(&self, x: f32) -> i8 {
        let q = (x / self.scale).round();
        q.clamp(-128.0, 127.0) as i8
    }

    pub fn dequantize(&self, q: i8) -> f32 {
        q as f32 * self.scale
    }

    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<i8> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Max absolute dequantization error for in-range inputs.
    pub fn max_error(&self) -> f32 {
        self.scale / 2.0
    }

    /// Pick the scale that covers `max_abs` with full code range,
    /// rounded to a power of two (hardware shift-dequant).
    pub fn fit(max_abs: f32) -> Int8Quantizer {
        let raw = max_abs / 127.0;
        let exp = raw.log2().ceil();
        Int8Quantizer::new(exp.exp2())
    }

    /// Total version of [`Int8Quantizer::fit`]: all-zero, non-finite,
    /// and underflowing-to-zero inputs (`max_abs / 127` below the f32
    /// subnormal range) fall back to a unit scale instead of panicking,
    /// so a fitted scale is never zero, NaN, or infinite. For any
    /// finite `max_abs` the fitted scale still satisfies
    /// `max_abs <= 127 * scale` (no saturation on in-range inputs).
    pub fn fit_safe(max_abs: f32) -> Int8Quantizer {
        if max_abs.is_finite() && max_abs > 0.0 {
            let scale = (max_abs / 127.0).log2().ceil().exp2();
            if scale.is_finite() && scale > 0.0 {
                return Int8Quantizer::new(scale);
            }
        }
        Int8Quantizer::new(1.0)
    }
}

/// Power-of-two scale for one stored KV `head_dim` vector: symmetric
/// int8, fitted to the vector's max-abs via [`Int8Quantizer::fit_safe`]
/// (all-zero vectors get a unit scale; NaN elements are ignored by the
/// max-abs scan so the scale itself is always finite and positive).
/// This is the single source of truth shared by `KvPool` block storage
/// and the paged decode staging path — both must agree bit-for-bit.
pub fn kv_vec_scale(v: &[f32]) -> f32 {
    let mut max_abs = 0.0f32;
    for &x in v {
        // f32::max drops NaN operands, keeping the scan total
        max_abs = max_abs.max(x.abs());
    }
    Int8Quantizer::fit_safe(max_abs).scale
}

/// Round-to-nearest saturating int8 encode at a fixed scale (the
/// free-function twin of [`Int8Quantizer::quantize`] for callers that
/// store raw scales, e.g. the paged KV pool).
pub fn quantize_i8(x: f32, scale: f32) -> i8 {
    (x / scale).round().clamp(-128.0, 127.0) as i8
}

/// Shift-dequantize one int8 code (exact in f32: `scale` is a power of
/// two and `|q| <= 128`).
pub fn dequantize_i8(q: i8, scale: f32) -> f32 {
    q as f32 * scale
}

/// One weight matrix quantized per output channel for the int8 serving
/// path (DESIGN.md §Quantization seam): `[dout, din]` row-major i8
/// codes in the same layout as the f32 source (so the int8 matmul
/// walks memory exactly like `native::matmul_bt_into`), plus one
/// power-of-two scale per output-channel row. Built once at model load
/// beside `params_t`; the f32 tensors are kept as the oracle.
#[derive(Debug, Clone)]
pub struct QuantizedMatrix {
    pub data: Vec<i8>,
    pub scales: Vec<f32>,
    pub dout: usize,
    pub din: usize,
}

impl QuantizedMatrix {
    /// Quantize a `[dout, din]` row-major f32 matrix, one symmetric
    /// power-of-two scale per output-channel row. All-zero rows get a
    /// unit scale (codes are all zero anyway), so no scale is ever
    /// zero, NaN, or infinite.
    pub fn from_rows(w: &[f32], dout: usize, din: usize) -> QuantizedMatrix {
        assert_eq!(w.len(), dout * din, "matrix shape mismatch");
        let mut data = vec![0i8; w.len()];
        let mut scales = vec![1.0f32; dout];
        for r in 0..dout {
            let row = &w[r * din..(r + 1) * din];
            let mut max_abs = 0.0f32;
            for &x in row {
                max_abs = max_abs.max(x.abs());
            }
            let q = Int8Quantizer::fit_safe(max_abs);
            scales[r] = q.scale;
            for (dst, &x) in data[r * din..(r + 1) * din].iter_mut().zip(row) {
                *dst = q.quantize(x);
            }
        }
        QuantizedMatrix { data, scales, dout, din }
    }

    /// The i8 codes of output channel `r`.
    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.din..(r + 1) * self.din]
    }

    /// Dequantize the whole matrix back to f32 (test/oracle helper).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.data.len()];
        for r in 0..self.dout {
            let s = self.scales[r];
            for c in 0..self.din {
                out[r * self.din + c] =
                    dequantize_i8(self.data[r * self.din + c], s);
            }
        }
        out
    }
}

/// The merged inference constant C = exp(-beta)/gamma (paper Eq. 3; see
/// `ref.py` for the sign-typo note), rounded to the fp16 the multiplier
/// consumes.
pub fn merge_beta_gamma(beta: f32, gamma: f32) -> F16 {
    F16::from_f32((-beta).exp() / gamma)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let q = Int8Quantizer::paper();
        for i in 0..1000 {
            let x = -7.9 + 15.8 * (i as f32 / 999.0);
            let err = (q.dequantize(q.quantize(x)) - x).abs();
            assert!(err <= q.max_error() + 1e-6, "x={x} err={err}");
        }
    }

    #[test]
    fn quantize_saturates() {
        let q = Int8Quantizer::paper();
        assert_eq!(q.quantize(1e9), 127);
        assert_eq!(q.quantize(-1e9), -128);
    }

    #[test]
    fn exact_codes_roundtrip() {
        let q = Int8Quantizer::paper();
        for code in -128i16..=127 {
            let code = code as i8;
            assert_eq!(q.quantize(q.dequantize(code)), code);
        }
    }

    #[test]
    fn fit_covers_range_with_pow2_scale() {
        let q = Int8Quantizer::fit(10.0);
        assert!(q.scale.log2().fract() == 0.0, "scale {}", q.scale);
        assert_eq!(q.quantize(10.0).unsigned_abs() as i32 as f32 * q.scale >= 9.0, true);
        assert!(q.quantize(10.0) < 127 || q.quantize(10.0) == 127);
    }

    #[test]
    fn merge_matches_f32_math() {
        let c = merge_beta_gamma(1.5, 100.0);
        let want = F16::from_f32((-1.5f32).exp() / 100.0);
        assert_eq!(c.to_bits(), want.to_bits());
    }

    #[test]
    fn fit_safe_never_yields_degenerate_scales() {
        let cases = [0.0f32, 1e-44, 1e-30, 1.0, 127.0, 1e9, f32::MAX, f32::INFINITY, f32::NAN];
        for max_abs in cases {
            let q = Int8Quantizer::fit_safe(max_abs);
            assert!(q.scale.is_finite() && q.scale > 0.0, "max_abs={max_abs}");
            if max_abs.is_finite() && max_abs > 0.0 && q.scale != 1.0 {
                assert!(max_abs <= 127.0 * q.scale, "max_abs={max_abs}");
            }
        }
    }

    #[test]
    fn kv_vec_scale_handles_adversarial_vectors() {
        // all-zero vector: unit scale, zero codes
        assert_eq!(kv_vec_scale(&[0.0; 8]), 1.0);
        // NaN elements are ignored by the max-abs scan
        let s = kv_vec_scale(&[1.0, f32::NAN, -2.0]);
        assert!(s.is_finite() && s > 0.0);
        assert_eq!(s, kv_vec_scale(&[1.0, -2.0]));
        // pow2 scale, error bound scale/2 on in-range values
        let v = [0.3f32, -0.7, 0.01, 0.69];
        let s = kv_vec_scale(&v);
        assert_eq!(s.log2().fract(), 0.0);
        for &x in &v {
            let rt = dequantize_i8(quantize_i8(x, s), s);
            assert!((rt - x).abs() <= s / 2.0, "{x} -> {rt} (scale {s})");
        }
    }

    #[test]
    fn quantized_matrix_per_channel_rows() {
        // two output channels with very different ranges get their own
        // scales; an all-zero channel gets the unit fallback
        let w = [
            10.0f32, -20.0, 5.0, //
            0.01, -0.02, 0.005, //
            0.0, 0.0, 0.0,
        ];
        let qm = QuantizedMatrix::from_rows(&w, 3, 3);
        assert!(qm.scales[0] > qm.scales[1]);
        assert_eq!(qm.scales[2], 1.0);
        assert_eq!(qm.row(2), &[0, 0, 0]);
        let dq = qm.dequantize();
        for (r, scale) in qm.scales.iter().enumerate() {
            for c in 0..3 {
                let (a, b) = (w[r * 3 + c], dq[r * 3 + c]);
                assert!((a - b).abs() <= scale / 2.0, "[{r},{c}] {a} vs {b}");
            }
        }
    }
}
