//! Bit-exact model of the bitwidth-split LUT datapath (paper Fig 4a,
//! Eq. 4).
//!
//! An INT8 score code `q` splits into a signed MSB nibble `m = q >> 4`
//! and an unsigned LSB nibble `l = q & 0xF`; two 16-entry fp16 tables
//! hold `exp(16·s·m)` and `exp(s·l)` and an fp16 multiplier merges them:
//!
//! ```text
//! exp(q·s) = MSB_LUT[m] × LSB_LUT[l]          (one fp16 rounding)
//! ConSmax(q) = (MSB_LUT[m] × LSB_LUT[l]) × C  (one more fp16 rounding)
//! ```
//!
//! Every arithmetic step is IEEE binary16 with round-to-nearest-even —
//! exactly what the synthesized datapath computes — so outputs are
//! bit-identical to the python oracle and (per the paper's claim) to the
//! RTL.

use crate::util::fp16::F16;

/// One bitwidth-split unit: the two 16-entry LUTs for a given scale.
#[derive(Debug, Clone)]
pub struct BitSplitLut {
    pub scale: f32,
    msb: [F16; 16],
    lsb: [F16; 16],
}

impl BitSplitLut {
    /// Build the tables for input codes dequantized as `x = q * scale`.
    pub fn new(scale: f32) -> BitSplitLut {
        let mut msb = [F16::ZERO; 16];
        let mut lsb = [F16::ZERO; 16];
        for (i, slot) in msb.iter_mut().enumerate() {
            let m = i as f32 - 8.0; // signed nibble -8..7 at index m+8
            *slot = F16::from_f32((16.0 * scale * m).exp());
        }
        for (i, slot) in lsb.iter_mut().enumerate() {
            *slot = F16::from_f32((scale * i as f32).exp());
        }
        BitSplitLut { scale, msb, lsb }
    }

    /// The paper's operating point (scale 1/16).
    pub fn paper() -> BitSplitLut {
        BitSplitLut::new(1.0 / 16.0)
    }

    /// Split a signed INT8 code into (MSB table index, LSB nibble).
    #[inline]
    pub fn split(q: i8) -> (usize, usize) {
        let m = (q as i32) >> 4; // arithmetic shift: -8..7
        let l = (q as i32) & 0xF;
        ((m + 8) as usize, l as usize)
    }

    /// The raw exponential `fp16(exp(q*scale))` through the LUT datapath.
    #[inline]
    pub fn exp(&self, q: i8) -> F16 {
        let (mi, li) = Self::split(q);
        self.msb[mi].mul(self.lsb[li])
    }

    /// Full ConSmax unit output: LUT-exp then ×C, both in fp16.
    #[inline]
    pub fn consmax(&self, q: i8, c: F16) -> F16 {
        self.exp(q).mul(c)
    }

    /// Vectorized form used by the serving post-processor.
    ///
    /// Perf: the unit's response is a pure function of the 256 input
    /// codes, so we materialize the full response table once (256 × two
    /// fp16 multiplies) and stream lookups after — bit-identical to the
    /// per-element path (asserted in tests) and ~20x faster on long
    /// streams (EXPERIMENTS.md §Perf).
    pub fn consmax_slice(&self, qs: &[i8], c: F16) -> Vec<F16> {
        let table = self.response_table(c);
        qs.iter().map(|&q| table[q as u8 as usize]).collect()
    }

    /// The full 256-entry response table for a fixed C (index = q as u8,
    /// i.e. two's-complement bit pattern).
    pub fn response_table(&self, c: F16) -> [F16; 256] {
        let mut t = [F16::ZERO; 256];
        for i in 0..256usize {
            t[i] = self.consmax(i as u8 as i8, c);
        }
        t
    }

    /// Table contents as bit patterns (hw ROM image / golden comparison).
    pub fn table_bits(&self) -> ([u16; 16], [u16; 16]) {
        let mut m = [0u16; 16];
        let mut l = [0u16; 16];
        for i in 0..16 {
            m[i] = self.msb[i].to_bits();
            l[i] = self.lsb[i].to_bits();
        }
        (m, l)
    }

    /// Total LUT capacity in bits (the §IV-A1 claim: 512, not 4096).
    pub const CAPACITY_BITS: usize = 2 * 16 * 16;
}

/// The Level-2 reduction unit (paper Fig 4a right, §IV-A2): chains
/// bitwidth-split units through an fp16 multiplier chain to support wider
/// input precision (mixed-precision computing).
#[derive(Debug, Clone)]
pub struct ReductionUnit {
    /// low-byte unit (unsigned byte: two unsigned nibbles)
    lo_msb: [F16; 16],
    lo_lsb: [F16; 16],
    /// high-byte factors, wider format internally (see ref.py note): the
    /// per-byte factor is produced in f32 and rounded once to fp16.
    scale: f32,
}

impl ReductionUnit {
    pub fn new(scale: f32) -> ReductionUnit {
        let mut lo_msb = [F16::ZERO; 16];
        let mut lo_lsb = [F16::ZERO; 16];
        for i in 0..16 {
            lo_msb[i] = F16::from_f32((16.0 * scale * i as f32).exp());
            lo_lsb[i] = F16::from_f32((scale * i as f32).exp());
        }
        ReductionUnit { lo_msb, lo_lsb, scale }
    }

    /// Split signed INT16 into (signed high byte, unsigned low byte).
    #[inline]
    pub fn split(q: i16) -> (i32, u32) {
        ((q as i32) >> 8, (q as i32 & 0xFF) as u32)
    }

    /// fp16(exp(q*scale)) for INT16 codes via the multiplier chain.
    pub fn exp16(&self, q: i16) -> F16 {
        let (hi, lo) = Self::split(q);
        // high byte: wider-format LUT pair, merged in f32, rounded once
        let hs = 256.0 * self.scale;
        let m = hi >> 4;
        let l = hi & 0xF;
        let e_hi = F16::from_f32(
            ((16.0 * hs * m as f32).exp()) * ((hs * l as f32).exp()),
        );
        // low byte: fp16 nibble tables exactly like the 8-bit unit
        let mi = (lo >> 4) as usize;
        let li = (lo & 0xF) as usize;
        let e_lo = self.lo_msb[mi].mul(self.lo_lsb[li]);
        e_hi.mul(e_lo)
    }

    pub fn consmax16(&self, q: i16, c: F16) -> F16 {
        self.exp16(q).mul(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_reassembles() {
        for q in i8::MIN..=i8::MAX {
            let (mi, li) = BitSplitLut::split(q);
            assert_eq!(16 * (mi as i32 - 8) + li as i32, q as i32);
            assert!(mi < 16 && li < 16);
        }
    }

    #[test]
    fn lossless_against_direct_fp16_exp() {
        // the paper's "lossless" claim: LUT path vs direct exp, within one
        // fp16 multiply rounding, over the EXHAUSTIVE input grid
        let lut = BitSplitLut::paper();
        for q in i8::MIN..=i8::MAX {
            let got = lut.exp(q).to_f32() as f64;
            let want = ((q as f64) / 16.0).exp();
            let rel = (got - want).abs() / want;
            assert!(rel < 2.0_f64.powi(-10), "q={q} rel={rel}");
        }
    }

    #[test]
    fn matches_scalar_reference_bitwise() {
        // independent recomputation: fp16(fp16(exp(16sm)) * fp16(exp(sl)))
        let lut = BitSplitLut::new(1.0 / 32.0);
        for q in i8::MIN..=i8::MAX {
            let m = ((q as i32) >> 4) as f32;
            let l = ((q as i32) & 0xF) as f32;
            let a = F16::from_f32((16.0 / 32.0 * m).exp());
            let b = F16::from_f32((l / 32.0).exp());
            assert_eq!(lut.exp(q).to_bits(), a.mul(b).to_bits(), "q={q}");
        }
    }

    #[test]
    fn consmax_applies_constant() {
        let lut = BitSplitLut::paper();
        let c = F16::from_f32(0.01);
        for q in [-128i8, -1, 0, 1, 127] {
            let want = lut.exp(q).mul(c);
            assert_eq!(lut.consmax(q, c).to_bits(), want.to_bits());
        }
    }

    #[test]
    fn capacity_is_512_bits() {
        assert_eq!(BitSplitLut::CAPACITY_BITS, 512);
    }

    #[test]
    fn monotone_on_the_grid() {
        // exp is monotone; the LUT path must preserve ordering despite
        // fp16 rounding (adjacent codes differ by e^(1/16) ≈ 6.4%, far
        // above fp16 resolution)
        let lut = BitSplitLut::paper();
        let mut prev = lut.exp(-128).to_f32();
        for q in -127i16..=127 {
            let cur = lut.exp(q as i8).to_f32();
            assert!(cur > prev, "q={q}");
            prev = cur;
        }
    }

    #[test]
    fn reduction_unit_splits_correctly() {
        for &q in &[-32768i16, -257, -256, -255, -1, 0, 1, 255, 256, 32767] {
            let (hi, lo) = ReductionUnit::split(q);
            assert_eq!(256 * hi + lo as i32, q as i32, "q={q}");
            assert!(lo < 256);
        }
    }

    #[test]
    fn reduction_unit_accuracy() {
        let ru = ReductionUnit::new(1.0 / 256.0);
        for q in (-2048i16..2048).step_by(7) {
            let got = ru.exp16(q).to_f32() as f64;
            let want = (q as f64 / 256.0).exp();
            let rel = (got - want).abs() / want;
            assert!(rel < 2e-3, "q={q} rel={rel}");
        }
    }

    #[test]
    fn table_bits_stable() {
        let (m1, l1) = BitSplitLut::paper().table_bits();
        let (m2, l2) = BitSplitLut::paper().table_bits();
        assert_eq!(m1, m2);
        assert_eq!(l1, l2);
        // known entry: index 8 is m=0 -> exp(0) = 1.0 = 0x3C00
        assert_eq!(m1[8], 0x3C00);
        assert_eq!(l1[0], 0x3C00);
    }
}
