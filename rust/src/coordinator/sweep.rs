//! β/γ initialization sweep (paper Fig 8, `--features pjrt`): train short
//! runs over a grid of initial values and report validation loss,
//! selecting the best combination — the paper's "hyperparameter tuning
//! during warm-up iterations" procedure (§III-A). Rides on [`Trainer`],
//! so it shares the trainer's PJRT requirement.

use anyhow::Result;

use crate::config::ModelConfig;
use crate::coordinator::params::ParamStore;
use crate::coordinator::trainer::{TrainOptions, Trainer};
use crate::data::BatchSampler;
use crate::runtime::{Engine, HostTensor};

/// One grid point's outcome.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub beta0: f64,
    pub gamma0: f64,
    pub final_train_loss: f64,
    pub val_loss: f64,
}

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    pub betas: Vec<f64>,
    pub gammas: Vec<f64>,
    pub warmup_steps: usize,
    pub seed: u64,
}

impl Default for SweepOptions {
    fn default() -> Self {
        // the paper explores beta in [0.5, 2.5] at gamma = 100, plus
        // gamma variations (Fig 8 shows a (beta, gamma) grid)
        SweepOptions {
            betas: vec![0.5, 1.0, 1.5, 2.0, 2.5],
            gammas: vec![10.0, 100.0, 300.0],
            warmup_steps: 30,
            seed: 0,
        }
    }
}

/// Set every (layer, head) β/γ to the given constants (overriding the
/// randomized init) so the sweep isolates the initialization effect.
pub fn pin_beta_gamma(store: &mut ParamStore, beta0: f32, gamma0: f32) {
    if let Some(i) = store.index_of("beta") {
        let shape = store.params[i].shape.clone();
        let n: usize = shape.iter().product();
        store.params[i] = HostTensor::from_f32(&vec![beta0; n], &shape);
    }
    if let Some(i) = store.index_of("gamma") {
        let shape = store.params[i].shape.clone();
        let n: usize = shape.iter().product();
        store.params[i] = HostTensor::from_f32(&vec![gamma0; n], &shape);
    }
}

/// Run the grid. Each point trains `warmup_steps` from an identical seed
/// (identical weights, identical data order) with only β₀/γ₀ varying.
pub fn sweep_init(
    engine: &Engine,
    cfg: &ModelConfig,
    tokens: &[i32],
    val_tokens: &[i32],
    opts: &SweepOptions,
) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::new();
    for &beta0 in &opts.betas {
        for &gamma0 in &opts.gammas {
            let mut store = ParamStore::init(cfg, opts.seed)?;
            pin_beta_gamma(&mut store, beta0 as f32, gamma0 as f32);
            let train =
                BatchSampler::new(tokens.to_vec(), cfg.train_batch, cfg.ctx, opts.seed);
            let val = BatchSampler::new(
                val_tokens.to_vec(),
                cfg.train_batch,
                cfg.ctx,
                opts.seed,
            );
            let mut tr = Trainer::new(engine, &cfg.key, store, train, Some(val))?;
            let report = tr.train(&TrainOptions {
                steps: opts.warmup_steps,
                log_every: opts.warmup_steps.max(1),
                eval_every: 0,
                eval_batches: 2,
                trace_params: false,
                checkpoint: None,
            })?;
            let val_loss = tr.evaluate(2)?;
            log::info!(
                "sweep beta0={beta0} gamma0={gamma0}: train {:.4} val {val_loss:.4}",
                report.final_loss
            );
            out.push(SweepPoint {
                beta0,
                gamma0,
                final_train_loss: report.final_loss,
                val_loss,
            });
        }
    }
    Ok(out)
}

/// The winning grid point (lowest validation loss), i.e. the combination
/// the paper "utilizes to train the model until convergence".
pub fn best_point(points: &[SweepPoint]) -> Option<&SweepPoint> {
    points
        .iter()
        .min_by(|a, b| a.val_loss.partial_cmp(&b.val_loss).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_point_picks_min_val() {
        let pts = vec![
            SweepPoint { beta0: 0.5, gamma0: 100.0, final_train_loss: 5.0, val_loss: 5.2 },
            SweepPoint { beta0: 1.0, gamma0: 100.0, final_train_loss: 5.1, val_loss: 5.0 },
            SweepPoint { beta0: 2.5, gamma0: 10.0, final_train_loss: 4.9, val_loss: 5.4 },
        ];
        let best = best_point(&pts).unwrap();
        assert_eq!(best.beta0, 1.0);
    }

    #[test]
    fn default_grid_matches_paper_ranges() {
        let o = SweepOptions::default();
        assert_eq!(*o.betas.first().unwrap(), 0.5);
        assert_eq!(*o.betas.last().unwrap(), 2.5);
        assert!(o.gammas.contains(&100.0));
    }
}
