//! Run reporting: render metric series from `runs/*.jsonl` as ASCII
//! charts and summary tables — the Fig 6/7 figures without leaving the
//! terminal. Used by `consmax report`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::metrics::Metrics;

/// An ASCII line chart of one or more series on a shared x (step) axis.
pub fn render_chart(
    title: &str,
    series: &[(&str, &[(u64, f64)])],
    width: usize,
    height: usize,
) -> String {
    let mut out = format!("\n{title}\n");
    let all: Vec<(u64, f64)> = series
        .iter()
        .flat_map(|(_, pts)| pts.iter().copied())
        .collect();
    if all.is_empty() {
        return out + "(no data)\n";
    }
    let x_min = all.iter().map(|p| p.0).min().unwrap() as f64;
    let x_max = all.iter().map(|p| p.0).max().unwrap() as f64;
    let y_min = all.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
    let y_max = all.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max);
    let y_span = (y_max - y_min).max(1e-12);
    let x_span = (x_max - x_min).max(1.0);

    let marks = ['*', 'o', '+', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for &(x, y) in pts.iter() {
            let col = (((x as f64 - x_min) / x_span) * (width - 1) as f64)
                .round() as usize;
            let row = (((y_max - y) / y_span) * (height - 1) as f64).round()
                as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = mark;
        }
    }
    for (r, line) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{y_max:9.3} |")
        } else if r == height - 1 {
            format!("{y_min:9.3} |")
        } else {
            "          |".to_string()
        };
        out.push_str(&label);
        out.extend(line.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "          +{}\n           step {:.0} .. {:.0}   ",
        "-".repeat(width),
        x_min,
        x_max
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("[{}] {}  ", marks[si % marks.len()], name));
    }
    out.push('\n');
    out
}

/// Load a metrics file and render train/val loss + β/γ summaries.
pub fn report_run(path: &Path) -> Result<String> {
    let m = Metrics::load(path)
        .with_context(|| format!("loading {}", path.display()))?;
    let mut out = format!("# run report: {}\n", path.display());

    let mut loss_series: Vec<(&str, &[(u64, f64)])> = Vec::new();
    if let Some(s) = m.get("train_loss") {
        loss_series.push(("train", &s.points));
    }
    if let Some(s) = m.get("val_loss") {
        loss_series.push(("val", &s.points));
    }
    if !loss_series.is_empty() {
        out.push_str(&render_chart("loss", &loss_series, 64, 14));
    }

    // β/γ trace summary (Fig 7)
    let mut beta_rows = Vec::new();
    for (name, s) in &m.series {
        if let Some(rest) = name.strip_prefix("beta_") {
            let first = s.points.first().unwrap().1;
            let last = s.points.last().unwrap().1;
            beta_rows.push(format!(
                "  beta[{rest}]: {first:.3} -> {last:.3} ({:+.1}%)",
                (last - first) / first * 100.0
            ));
        }
    }
    if !beta_rows.is_empty() {
        out.push_str("\nFig 7 β traces:\n");
        out.push_str(&beta_rows.join("\n"));
        out.push('\n');
        // γ summary: mean drift only ("low % change")
        let gammas: Vec<(f64, f64)> = m
            .series
            .iter()
            .filter(|(n, _)| n.starts_with("gamma_"))
            .map(|(_, s)| {
                (s.points.first().unwrap().1, s.points.last().unwrap().1)
            })
            .collect();
        if !gammas.is_empty() {
            let mean0: f64 =
                gammas.iter().map(|g| g.0).sum::<f64>() / gammas.len() as f64;
            let mean1: f64 =
                gammas.iter().map(|g| g.1).sum::<f64>() / gammas.len() as f64;
            out.push_str(&format!(
                "γ mean: {mean0:.2} -> {mean1:.2} ({:+.3}%) — the paper's \
                 'low % change'\n",
                (mean1 - mean0) / mean0 * 100.0
            ));
        }
    }

    if let Some(s) = m.get("train_loss") {
        out.push_str(&format!(
            "\nfinal train loss {:.4}; best {:.4}; tail-10 mean {:.4}\n",
            s.last().unwrap_or(f64::NAN),
            s.min().unwrap_or(f64::NAN),
            s.tail_mean(10).unwrap_or(f64::NAN),
        ));
    }
    Ok(out)
}

/// Side-by-side comparison of two runs' loss curves (Fig 6).
pub fn report_compare(a: &Path, b: &Path) -> Result<String> {
    let ma = Metrics::load(a)?;
    let mb = Metrics::load(b)?;
    let name_a = a.file_stem().unwrap().to_string_lossy().into_owned();
    let name_b = b.file_stem().unwrap().to_string_lossy().into_owned();
    let sa = ma.get("train_loss").context("train_loss in a")?;
    let sb = mb.get("train_loss").context("train_loss in b")?;
    let mut out = render_chart(
        "Fig 6: train loss",
        &[(&name_a, &sa.points), (&name_b, &sb.points)],
        64,
        16,
    );
    if let (Some(va), Some(vb)) = (ma.get("val_loss"), mb.get("val_loss")) {
        out.push_str(&render_chart(
            "Fig 6: val loss",
            &[(&name_a, &va.points), (&name_b, &vb.points)],
            64,
            12,
        ));
        if let (Some(la), Some(lb)) = (va.last(), vb.last()) {
            out.push_str(&format!(
                "\nfinal val: {name_a} {la:.4} vs {name_b} {lb:.4} \
                 ({:+.2}%)\n",
                (lb - la) / la * 100.0
            ));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chart_renders_extremes() {
        let pts: Vec<(u64, f64)> = (0..20).map(|i| (i, (i as f64).sin())).collect();
        let s = render_chart("t", &[("sin", &pts)], 40, 8);
        assert!(s.contains('*'));
        assert!(s.contains("step 0 .. 19"));
        assert!(s.lines().count() > 8);
    }

    #[test]
    fn chart_handles_empty() {
        let s = render_chart("t", &[("x", &[])], 40, 8);
        assert!(s.contains("no data"));
    }

    #[test]
    fn chart_two_series_distinct_marks() {
        let a: Vec<(u64, f64)> = vec![(0, 0.0), (10, 1.0)];
        let b: Vec<(u64, f64)> = vec![(0, 1.0), (10, 0.0)];
        let s = render_chart("t", &[("a", &a), ("b", &b)], 30, 6);
        assert!(s.contains('*') && s.contains('o'));
        assert!(s.contains("[*] a") && s.contains("[o] b"));
    }

    #[test]
    fn report_run_roundtrip() {
        let mut m = crate::metrics::Metrics::new();
        for i in 0..10u64 {
            m.log("train_loss", i * 10, 5.0 - i as f64 * 0.3);
            m.log("beta_l0h0", i * 10, 1.0 + i as f64 * 0.01);
            m.log("gamma_l0h0", i * 10, 100.0);
        }
        let dir = std::env::temp_dir().join("consmax_report_test");
        let path = dir.join("m.jsonl");
        m.save(&path).unwrap();
        let rep = report_run(&path).unwrap();
        assert!(rep.contains("loss"));
        assert!(rep.contains("beta[l0h0]"));
        assert!(rep.contains("low % change"));
        assert!(rep.contains("final train loss 2.3000"));
    }
}
