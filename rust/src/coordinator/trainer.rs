//! Training orchestrators: own the parameter state, run the train step,
//! and record the metrics behind the paper's software evaluation plots
//! (Fig 6 loss/perplexity curves, Fig 7 β/γ traces).
//!
//! Two interchangeable drivers share [`TrainOptions`], [`TrainReport`],
//! and the metric naming scheme (DESIGN.md §Training seam):
//!
//! * [`NativeTrainer`] — always available. Runs
//!   `NativeModel::forward_train` + `backward` plus the python-faithful
//!   [`adamw_step`] below (same β₁/β₂/ε, decay set, global-norm clip,
//!   and warmup+cosine [`lr_at`] schedule as `python/compile/model.py`),
//!   so `consmax train --backend native` reproduces Fig 6/7 from a bare
//!   checkout — no PJRT, no artifacts.
//! * [`Trainer`] (`--features pjrt`) — feeds the AOT fused
//!   fwd+bwd+AdamW `train_step` executable, keeping params + moments as
//!   PJRT literals across steps (only the scalar loss and, at log
//!   points, the tiny β/γ tensors are copied back).
//!
//! The two trainers follow the same update rule; they differ in where
//! the autodiff runs (hand-derived Rust kernels vs XLA), so their loss
//! curves agree statistically, not bitwise.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::ModelConfig;
use crate::coordinator::params::ParamStore;
use crate::data::BatchSampler;
use crate::metrics::{perplexity, Metrics};
use crate::runtime::backend::NativeModel;
use crate::runtime::HostTensor;
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;

/// Options for one training run.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: usize,
    pub log_every: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Record per-head β/γ series (Fig 7).
    pub trace_params: bool,
    pub checkpoint: Option<PathBuf>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 100,
            log_every: 10,
            eval_every: 0,
            eval_batches: 4,
            trace_params: true,
            checkpoint: None,
        }
    }
}

/// Result summary of a run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub final_loss: f64,
    pub final_ppl: f64,
    pub best_val_loss: Option<f64>,
    pub steps: usize,
    pub wall_s: f64,
    pub steps_per_s: f64,
}

#[cfg(feature = "pjrt")]
pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub cfg: ModelConfig,
    pub store: ParamStore,
    pub train_sampler: BatchSampler,
    pub val_sampler: Option<BatchSampler>,
    pub metrics: Metrics,
}

#[cfg(feature = "pjrt")]
impl<'e> Trainer<'e> {
    pub fn new(
        engine: &'e Engine,
        config_key: &str,
        store: ParamStore,
        train_sampler: BatchSampler,
        val_sampler: Option<BatchSampler>,
    ) -> Result<Trainer<'e>> {
        let cfg = engine.manifest.config(config_key)?.clone();
        Ok(Trainer {
            engine,
            cfg,
            store,
            train_sampler,
            val_sampler,
            metrics: Metrics::new(),
        })
    }

    fn entry(&self, which: &str) -> String {
        format!("{}_{which}", self.cfg.key)
    }

    /// Run the training loop.
    pub fn train(&mut self, opts: &TrainOptions) -> Result<TrainReport> {
        let entry = self.entry("train_step");
        let exe = self.engine.load(&entry)?;
        let n = self.store.order.len();
        let beta_idx = self.store.index_of("beta");
        let gamma_idx = self.store.index_of("gamma");

        // marshal state into literals once
        let mut state: Vec<xla::Literal> = Vec::with_capacity(3 * n);
        for group in [&self.store.params, &self.store.m, &self.store.v] {
            for t in group {
                state.push(t.to_literal()?);
            }
        }

        let t0 = Instant::now();
        let mut final_loss = f64::NAN;
        let mut best_val = None::<f64>;
        let start_step = self.store.step;

        for local in 0..opts.steps {
            let step = start_step + local as u64;
            let (x, y) = self.train_sampler.sample();
            let xt = HostTensor::from_i32(
                &x,
                &[self.cfg.train_batch, self.cfg.ctx],
            )
            .to_literal()?;
            let yt = HostTensor::from_i32(
                &y,
                &[self.cfg.train_batch, self.cfg.ctx],
            )
            .to_literal()?;
            let st = HostTensor::scalar_f32(step as f32).to_literal()?;

            let mut inputs: Vec<&xla::Literal> = state.iter().collect();
            inputs.push(&st);
            inputs.push(&xt);
            inputs.push(&yt);

            let mut outs =
                self.engine.execute_literal_refs(&entry, &exe, &inputs)?;
            // outputs: params'(n) | m'(n) | v'(n) | loss | gnorm
            let gnorm_lit = outs.pop().context("missing gnorm")?;
            let loss_lit = outs.pop().context("missing loss")?;
            let loss = HostTensor::from_literal(&loss_lit)?.scalar_as_f32()? as f64;
            let gnorm =
                HostTensor::from_literal(&gnorm_lit)?.scalar_as_f32()? as f64;
            state = outs;
            final_loss = loss;

            if !loss.is_finite() {
                anyhow::bail!("loss diverged (NaN/Inf) at step {step}");
            }

            if local % opts.log_every == 0 || local + 1 == opts.steps {
                self.metrics.log("train_loss", step, loss);
                self.metrics.log("train_ppl", step, perplexity(loss));
                self.metrics.log("grad_norm", step, gnorm);
                if opts.trace_params {
                    self.trace_beta_gamma(&state, step, beta_idx, gamma_idx)?;
                }
                log::info!(
                    "step {step}: loss {loss:.4} ppl {:.1} gnorm {gnorm:.2}",
                    perplexity(loss)
                );
            }

            if opts.eval_every > 0
                && local > 0
                && local % opts.eval_every == 0
            {
                let val = self.evaluate_with_state(&state, opts.eval_batches)?;
                self.metrics.log("val_loss", step, val);
                self.metrics.log("val_ppl", step, perplexity(val));
                best_val = Some(best_val.map_or(val, |b: f64| b.min(val)));
            }
        }

        // copy final state back to the store
        for (i, lit) in state.iter().enumerate() {
            let t = HostTensor::from_literal(lit)?;
            match i / n {
                0 => self.store.params[i % n] = t,
                1 => self.store.m[i % n] = t,
                _ => self.store.v[i % n] = t,
            }
        }
        self.store.step = start_step + opts.steps as u64;

        if let Some(path) = &opts.checkpoint {
            self.store.save(path)?;
        }

        let wall = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            final_loss,
            final_ppl: perplexity(final_loss),
            best_val_loss: best_val,
            steps: opts.steps,
            wall_s: wall,
            steps_per_s: opts.steps as f64 / wall,
        })
    }

    /// Log per-(layer, head) β and γ values (Fig 7 traces).
    fn trace_beta_gamma(
        &mut self,
        state: &[xla::Literal],
        step: u64,
        beta_idx: Option<usize>,
        gamma_idx: Option<usize>,
    ) -> Result<()> {
        for (name, idx) in [("beta", beta_idx), ("gamma", gamma_idx)] {
            let Some(idx) = idx else { continue };
            let t = HostTensor::from_literal(&state[idx])?;
            let vals = t.as_f32()?;
            let heads = self.cfg.n_head;
            for (i, v) in vals.iter().enumerate() {
                let (l, h) = (i / heads, i % heads);
                self.metrics
                    .log(&format!("{name}_l{l}h{h}"), step, *v as f64);
            }
        }
        Ok(())
    }

    /// Mean validation loss over up to `max_batches` deterministic batches.
    pub fn evaluate(&mut self, max_batches: usize) -> Result<f64> {
        let state: Vec<xla::Literal> = self
            .store
            .params
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<_>>()?;
        self.eval_params(&state, max_batches)
    }

    /// Deployment-form validation loss: the same weights scored through
    /// the INT8 bitwidth-split ConSmax hardware normalizer (the accuracy
    /// a Fig 4(b) accelerator delivers). Only exported for consmax
    /// configs.
    pub fn evaluate_quantized(&mut self, max_batches: usize) -> Result<f64> {
        let state: Vec<xla::Literal> = self
            .store
            .params
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<_>>()?;
        self.eval_params_with(&state, max_batches, "eval_quant")
    }

    fn evaluate_with_state(
        &self,
        state: &[xla::Literal],
        max_batches: usize,
    ) -> Result<f64> {
        let n = self.store.order.len();
        self.eval_params(&state[..n], max_batches)
    }

    fn eval_params(
        &self,
        params: &[xla::Literal],
        max_batches: usize,
    ) -> Result<f64> {
        self.eval_params_with(params, max_batches, "eval_step")
    }

    fn eval_params_with(
        &self,
        params: &[xla::Literal],
        max_batches: usize,
        which: &str,
    ) -> Result<f64> {
        let sampler = self
            .val_sampler
            .as_ref()
            .unwrap_or(&self.train_sampler);
        let entry = self.entry(which);
        let exe = self.engine.load(&entry)?;
        let batches = sampler.eval_batches(max_batches);
        anyhow::ensure!(!batches.is_empty(), "validation stream too small");
        let mut total = 0.0;
        for (x, y) in &batches {
            let xt = HostTensor::from_i32(x, &[self.cfg.train_batch, self.cfg.ctx])
                .to_literal()?;
            let yt = HostTensor::from_i32(y, &[self.cfg.train_batch, self.cfg.ctx])
                .to_literal()?;
            let mut inputs: Vec<&xla::Literal> = params.iter().collect();
            inputs.push(&xt);
            inputs.push(&yt);
            let outs = self.engine.execute_literal_refs(&entry, &exe, &inputs)?;
            total += HostTensor::from_literal(&outs[0])?.scalar_as_f32()? as f64;
        }
        Ok(total / batches.len() as f64)
    }
}

// ---- native training (no PJRT) ---------------------------------------------

/// AdamW hyperparameters shared with `python/compile/model.py`.
const ADAM_B1: f64 = 0.9;
const ADAM_B2: f64 = 0.95;
const ADAM_EPS: f64 = 1e-8;
const WEIGHT_DECAY: f64 = 0.1;
const LR_MAX: f64 = 1e-3;
const LR_MIN: f64 = 1e-4;

/// Parameters that get weight decay (matrices; everything else — biases,
/// LayerNorm gains, β/γ/ssmax_s — is decay-free, as in python).
const DECAY_SET: [&str; 5] =
    ["wte", "attn_qkv_w", "attn_proj_w", "mlp_fc_w", "mlp_proj_w"];

/// Linear-warmup + cosine-decay learning rate, python-identical:
/// warmup is `max(1, total/20)` steps ramping to `1e-3`, then cosine
/// down to `1e-4` over the remainder.
pub fn lr_at(step: u64, total_steps: usize) -> f64 {
    let warmup = (total_steps / 20).max(1) as f64;
    let s = step as f64;
    if s < warmup {
        LR_MAX * (s + 1.0) / warmup
    } else {
        let denom = (total_steps as f64 - warmup).max(1.0);
        let prog = ((s - warmup) / denom).clamp(0.0, 1.0);
        LR_MIN
            + 0.5 * (LR_MAX - LR_MIN) * (1.0 + (std::f64::consts::PI * prog).cos())
    }
}

/// One std-only AdamW update over the whole [`ParamStore`], faithful to
/// the python reference step: global-norm clip to 1.0, bias-corrected
/// moments, decoupled weight decay on [`DECAY_SET`] only. `grads` must
/// hold one canonical-shape gradient per store entry (the shape
/// [`NativeModel::backward`] returns). Returns the pre-clip global
/// gradient norm.
pub fn adamw_step(
    store: &mut ParamStore,
    grads: &std::collections::BTreeMap<String, Vec<f32>>,
    cfg: &ModelConfig,
    step: u64,
) -> Result<f64> {
    let mut sq = 0.0f64;
    for name in &store.order {
        let g = grads
            .get(name)
            .with_context(|| format!("missing gradient for {name}"))?;
        for &v in g {
            sq += (v as f64) * (v as f64);
        }
    }
    let gnorm = sq.sqrt();
    let clip = (1.0 / (gnorm + 1e-6)).min(1.0);
    let lr = lr_at(step, cfg.total_steps);
    let t = (step + 1) as i32;
    let bc1 = 1.0 - ADAM_B1.powi(t);
    let bc2 = 1.0 - ADAM_B2.powi(t);

    for i in 0..store.order.len() {
        let name = store.order[i].clone();
        let wd = if DECAY_SET.contains(&name.as_str()) { WEIGHT_DECAY } else { 0.0 };
        let g = &grads[&name];
        let mut p = store.params[i].as_f32()?;
        let mut m = store.m[i].as_f32()?;
        let mut v = store.v[i].as_f32()?;
        for j in 0..p.len() {
            let gj = g[j] as f64 * clip;
            let mj = ADAM_B1 * m[j] as f64 + (1.0 - ADAM_B1) * gj;
            let vj = ADAM_B2 * v[j] as f64 + (1.0 - ADAM_B2) * gj * gj;
            m[j] = mj as f32;
            v[j] = vj as f32;
            let mhat = mj / bc1;
            let vhat = vj / bc2;
            let upd = mhat / (vhat.sqrt() + ADAM_EPS) + wd * p[j] as f64;
            p[j] = (p[j] as f64 - lr * upd) as f32;
        }
        let shape = store.params[i].shape.clone();
        store.params[i] = HostTensor::from_f32(&p, &shape);
        store.m[i] = HostTensor::from_f32(&m, &shape);
        store.v[i] = HostTensor::from_f32(&v, &shape);
    }
    Ok(gnorm)
}

/// Pure-Rust training orchestrator: the same loop shape, metric names,
/// and [`TrainOptions`]/[`TrainReport`] contract as the PJRT
/// [`Trainer`], driven by the native tape + hand-derived backward
/// (`runtime::backend::train`) and [`adamw_step`]. Always compiled in —
/// `consmax train --backend native` works from a bare checkout.
pub struct NativeTrainer {
    pub cfg: ModelConfig,
    pub store: ParamStore,
    pub train_sampler: BatchSampler,
    pub val_sampler: Option<BatchSampler>,
    pub metrics: Metrics,
}

impl NativeTrainer {
    pub fn new(
        cfg: ModelConfig,
        store: ParamStore,
        train_sampler: BatchSampler,
        val_sampler: Option<BatchSampler>,
    ) -> NativeTrainer {
        NativeTrainer {
            cfg,
            store,
            train_sampler,
            val_sampler,
            metrics: Metrics::new(),
        }
    }

    fn model(&self) -> Result<NativeModel> {
        NativeModel::from_params(&self.cfg, &self.store.order, &self.store.params)
    }

    /// Run the training loop.
    pub fn train(&mut self, opts: &TrainOptions) -> Result<TrainReport> {
        let (b, t) = (self.cfg.train_batch, self.cfg.ctx);
        let t0 = Instant::now();
        let mut final_loss = f64::NAN;
        let mut best_val = None::<f64>;
        let start_step = self.store.step;

        for local in 0..opts.steps {
            let step = start_step + local as u64;
            let (x, y) = self.train_sampler.sample();
            let model = self.model()?;
            let tape = model.forward_train(&x, &y, b, t)?;
            let grads = model.backward(&tape, &x, &y)?;
            let gnorm = adamw_step(&mut self.store, &grads, &self.cfg, step)?;
            let loss = tape.loss;
            final_loss = loss;

            if !loss.is_finite() {
                anyhow::bail!("loss diverged (NaN/Inf) at step {step}");
            }

            if local % opts.log_every == 0 || local + 1 == opts.steps {
                self.metrics.log("train_loss", step, loss);
                self.metrics.log("train_ppl", step, perplexity(loss));
                self.metrics.log("grad_norm", step, gnorm);
                if opts.trace_params {
                    self.trace_learnables(step)?;
                }
                log::info!(
                    "step {step}: loss {loss:.4} ppl {:.1} gnorm {gnorm:.2}",
                    perplexity(loss)
                );
            }

            if opts.eval_every > 0 && local > 0 && local % opts.eval_every == 0 {
                let val = self.evaluate(opts.eval_batches)?;
                self.metrics.log("val_loss", step, val);
                self.metrics.log("val_ppl", step, perplexity(val));
                best_val = Some(best_val.map_or(val, |bv: f64| bv.min(val)));
            }
        }
        self.store.step = start_step + opts.steps as u64;

        if let Some(path) = &opts.checkpoint {
            self.store.save(path)?;
        }

        let wall = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            final_loss,
            final_ppl: perplexity(final_loss),
            best_val_loss: best_val,
            steps: opts.steps,
            wall_s: wall,
            steps_per_s: opts.steps as f64 / wall,
        })
    }

    /// Log per-(layer, head) normalizer learnables (Fig 7 traces): β/γ
    /// plus ssmax's scale when the schema carries it. Same metric names
    /// as the PJRT trainer (`beta_l{l}h{h}`, ...).
    fn trace_learnables(&mut self, step: u64) -> Result<()> {
        for name in ["beta", "gamma", "ssmax_s"] {
            let Some(t) = self.store.get(name) else { continue };
            let vals = t.as_f32()?;
            let heads = self.cfg.n_head;
            for (i, v) in vals.iter().enumerate() {
                let (l, h) = (i / heads, i % heads);
                self.metrics.log(&format!("{name}_l{l}h{h}"), step, *v as f64);
            }
        }
        Ok(())
    }

    /// Mean validation loss over up to `max_batches` deterministic
    /// batches through the native forward.
    pub fn evaluate(&self, max_batches: usize) -> Result<f64> {
        let sampler = self.val_sampler.as_ref().unwrap_or(&self.train_sampler);
        let batches = sampler.eval_batches(max_batches);
        anyhow::ensure!(!batches.is_empty(), "validation stream too small");
        let model = self.model()?;
        let (b, t) = (self.cfg.train_batch, self.cfg.ctx);
        let mut total = 0.0;
        for (x, y) in &batches {
            total += model.loss(x, y, b, t)?;
        }
        Ok(total / batches.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_matches_python_shape() {
        // tiny preset: 200 steps -> 10 warmup steps
        assert!((lr_at(0, 200) - 1e-4).abs() < 1e-12);
        assert!((lr_at(9, 200) - 1e-3).abs() < 1e-12);
        // cosine starts exactly at LR_MAX and ends at LR_MIN
        assert!((lr_at(10, 200) - 1e-3).abs() < 1e-9);
        assert!((lr_at(10_000, 200) - 1e-4).abs() < 1e-12);
        // monotone decay after warmup
        assert!(lr_at(50, 200) > lr_at(150, 200));
    }

    #[test]
    fn adamw_zero_grad_only_decays_the_decay_set() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let mut store = ParamStore::init(&cfg, 0).unwrap();
        let grads: std::collections::BTreeMap<String, Vec<f32>> = cfg
            .param_order
            .iter()
            .map(|n| {
                let sz: usize =
                    cfg.shape_of(n).unwrap().iter().product();
                (n.clone(), vec![0.0f32; sz])
            })
            .collect();
        let before_beta = store.get("beta").unwrap().as_f32().unwrap();
        let before_wte = store.get("wte").unwrap().as_f32().unwrap();
        let gnorm = adamw_step(&mut store, &grads, &cfg, 0).unwrap();
        assert_eq!(gnorm, 0.0);
        // no decay on the normalizer learnables
        assert_eq!(store.get("beta").unwrap().as_f32().unwrap(), before_beta);
        // decoupled weight decay still shrinks the matrices
        let after_wte = store.get("wte").unwrap().as_f32().unwrap();
        let lr = lr_at(0, cfg.total_steps);
        for (a, b) in before_wte.iter().zip(&after_wte) {
            let want = (*a as f64 * (1.0 - lr * WEIGHT_DECAY)) as f32;
            assert!((b - want).abs() <= 1e-7, "{a} -> {b}");
        }
    }

    #[test]
    fn adamw_moves_params_against_the_gradient() {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let mut store = ParamStore::init(&cfg, 1).unwrap();
        let mut grads: std::collections::BTreeMap<String, Vec<f32>> = cfg
            .param_order
            .iter()
            .map(|n| {
                let sz: usize =
                    cfg.shape_of(n).unwrap().iter().product();
                (n.clone(), vec![0.0f32; sz])
            })
            .collect();
        // positive gradient on beta -> beta must decrease (no decay term)
        grads.get_mut("beta").unwrap().fill(1.0);
        let before = store.get("beta").unwrap().as_f32().unwrap();
        let gnorm = adamw_step(&mut store, &grads, &cfg, 0).unwrap();
        assert!(gnorm > 0.0);
        let after = store.get("beta").unwrap().as_f32().unwrap();
        for (b, a) in before.iter().zip(&after) {
            assert!(a < b, "{b} -> {a}");
        }
    }
}
