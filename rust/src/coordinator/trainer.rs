//! Training orchestrator (`--features pjrt`): owns the parameter state,
//! feeds the AOT `train_step` executable, and records the metrics the
//! paper's software evaluation plots (Fig 6 loss/perplexity curves,
//! Fig 7 β/γ traces).
//!
//! This module is the one coordinator component pinned to the PJRT
//! backend: the fused fwd+bwd+AdamW step exists only as an AOT artifact
//! (the native backend is forward-only — see
//! `runtime::backend::NativeModel`). Evaluation of a trained checkpoint
//! does not need this module; `consmax eval --backend native` scores
//! checkpoints through the native forward pass.
//!
//! The hot loop keeps params + moments as PJRT literals: the train-step
//! outputs of step *t* are the inputs of step *t+1* without a host
//! round-trip; only the scalar loss (and, at log points, the tiny β/γ
//! tensors) are copied back.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::ModelConfig;
use crate::coordinator::params::ParamStore;
use crate::data::BatchSampler;
use crate::metrics::{perplexity, Metrics};
use crate::runtime::{Engine, HostTensor};

/// Options for one training run.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: usize,
    pub log_every: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Record per-head β/γ series (Fig 7).
    pub trace_params: bool,
    pub checkpoint: Option<PathBuf>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 100,
            log_every: 10,
            eval_every: 0,
            eval_batches: 4,
            trace_params: true,
            checkpoint: None,
        }
    }
}

/// Result summary of a run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub final_loss: f64,
    pub final_ppl: f64,
    pub best_val_loss: Option<f64>,
    pub steps: usize,
    pub wall_s: f64,
    pub steps_per_s: f64,
}

pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub cfg: ModelConfig,
    pub store: ParamStore,
    pub train_sampler: BatchSampler,
    pub val_sampler: Option<BatchSampler>,
    pub metrics: Metrics,
}

impl<'e> Trainer<'e> {
    pub fn new(
        engine: &'e Engine,
        config_key: &str,
        store: ParamStore,
        train_sampler: BatchSampler,
        val_sampler: Option<BatchSampler>,
    ) -> Result<Trainer<'e>> {
        let cfg = engine.manifest.config(config_key)?.clone();
        Ok(Trainer {
            engine,
            cfg,
            store,
            train_sampler,
            val_sampler,
            metrics: Metrics::new(),
        })
    }

    fn entry(&self, which: &str) -> String {
        format!("{}_{which}", self.cfg.key)
    }

    /// Run the training loop.
    pub fn train(&mut self, opts: &TrainOptions) -> Result<TrainReport> {
        let entry = self.entry("train_step");
        let exe = self.engine.load(&entry)?;
        let n = self.store.order.len();
        let beta_idx = self.store.index_of("beta");
        let gamma_idx = self.store.index_of("gamma");

        // marshal state into literals once
        let mut state: Vec<xla::Literal> = Vec::with_capacity(3 * n);
        for group in [&self.store.params, &self.store.m, &self.store.v] {
            for t in group {
                state.push(t.to_literal()?);
            }
        }

        let t0 = Instant::now();
        let mut final_loss = f64::NAN;
        let mut best_val = None::<f64>;
        let start_step = self.store.step;

        for local in 0..opts.steps {
            let step = start_step + local as u64;
            let (x, y) = self.train_sampler.sample();
            let xt = HostTensor::from_i32(
                &x,
                &[self.cfg.train_batch, self.cfg.ctx],
            )
            .to_literal()?;
            let yt = HostTensor::from_i32(
                &y,
                &[self.cfg.train_batch, self.cfg.ctx],
            )
            .to_literal()?;
            let st = HostTensor::scalar_f32(step as f32).to_literal()?;

            let mut inputs: Vec<&xla::Literal> = state.iter().collect();
            inputs.push(&st);
            inputs.push(&xt);
            inputs.push(&yt);

            let mut outs =
                self.engine.execute_literal_refs(&entry, &exe, &inputs)?;
            // outputs: params'(n) | m'(n) | v'(n) | loss | gnorm
            let gnorm_lit = outs.pop().context("missing gnorm")?;
            let loss_lit = outs.pop().context("missing loss")?;
            let loss = HostTensor::from_literal(&loss_lit)?.scalar_as_f32()? as f64;
            let gnorm =
                HostTensor::from_literal(&gnorm_lit)?.scalar_as_f32()? as f64;
            state = outs;
            final_loss = loss;

            if !loss.is_finite() {
                anyhow::bail!("loss diverged (NaN/Inf) at step {step}");
            }

            if local % opts.log_every == 0 || local + 1 == opts.steps {
                self.metrics.log("train_loss", step, loss);
                self.metrics.log("train_ppl", step, perplexity(loss));
                self.metrics.log("grad_norm", step, gnorm);
                if opts.trace_params {
                    self.trace_beta_gamma(&state, step, beta_idx, gamma_idx)?;
                }
                log::info!(
                    "step {step}: loss {loss:.4} ppl {:.1} gnorm {gnorm:.2}",
                    perplexity(loss)
                );
            }

            if opts.eval_every > 0
                && local > 0
                && local % opts.eval_every == 0
            {
                let val = self.evaluate_with_state(&state, opts.eval_batches)?;
                self.metrics.log("val_loss", step, val);
                self.metrics.log("val_ppl", step, perplexity(val));
                best_val = Some(best_val.map_or(val, |b: f64| b.min(val)));
            }
        }

        // copy final state back to the store
        for (i, lit) in state.iter().enumerate() {
            let t = HostTensor::from_literal(lit)?;
            match i / n {
                0 => self.store.params[i % n] = t,
                1 => self.store.m[i % n] = t,
                _ => self.store.v[i % n] = t,
            }
        }
        self.store.step = start_step + opts.steps as u64;

        if let Some(path) = &opts.checkpoint {
            self.store.save(path)?;
        }

        let wall = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            final_loss,
            final_ppl: perplexity(final_loss),
            best_val_loss: best_val,
            steps: opts.steps,
            wall_s: wall,
            steps_per_s: opts.steps as f64 / wall,
        })
    }

    /// Log per-(layer, head) β and γ values (Fig 7 traces).
    fn trace_beta_gamma(
        &mut self,
        state: &[xla::Literal],
        step: u64,
        beta_idx: Option<usize>,
        gamma_idx: Option<usize>,
    ) -> Result<()> {
        for (name, idx) in [("beta", beta_idx), ("gamma", gamma_idx)] {
            let Some(idx) = idx else { continue };
            let t = HostTensor::from_literal(&state[idx])?;
            let vals = t.as_f32()?;
            let heads = self.cfg.n_head;
            for (i, v) in vals.iter().enumerate() {
                let (l, h) = (i / heads, i % heads);
                self.metrics
                    .log(&format!("{name}_l{l}h{h}"), step, *v as f64);
            }
        }
        Ok(())
    }

    /// Mean validation loss over up to `max_batches` deterministic batches.
    pub fn evaluate(&mut self, max_batches: usize) -> Result<f64> {
        let state: Vec<xla::Literal> = self
            .store
            .params
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<_>>()?;
        self.eval_params(&state, max_batches)
    }

    /// Deployment-form validation loss: the same weights scored through
    /// the INT8 bitwidth-split ConSmax hardware normalizer (the accuracy
    /// a Fig 4(b) accelerator delivers). Only exported for consmax
    /// configs.
    pub fn evaluate_quantized(&mut self, max_batches: usize) -> Result<f64> {
        let state: Vec<xla::Literal> = self
            .store
            .params
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<_>>()?;
        self.eval_params_with(&state, max_batches, "eval_quant")
    }

    fn evaluate_with_state(
        &self,
        state: &[xla::Literal],
        max_batches: usize,
    ) -> Result<f64> {
        let n = self.store.order.len();
        self.eval_params(&state[..n], max_batches)
    }

    fn eval_params(
        &self,
        params: &[xla::Literal],
        max_batches: usize,
    ) -> Result<f64> {
        self.eval_params_with(params, max_batches, "eval_step")
    }

    fn eval_params_with(
        &self,
        params: &[xla::Literal],
        max_batches: usize,
        which: &str,
    ) -> Result<f64> {
        let sampler = self
            .val_sampler
            .as_ref()
            .unwrap_or(&self.train_sampler);
        let entry = self.entry(which);
        let exe = self.engine.load(&entry)?;
        let batches = sampler.eval_batches(max_batches);
        anyhow::ensure!(!batches.is_empty(), "validation stream too small");
        let mut total = 0.0;
        for (x, y) in &batches {
            let xt = HostTensor::from_i32(x, &[self.cfg.train_batch, self.cfg.ctx])
                .to_literal()?;
            let yt = HostTensor::from_i32(y, &[self.cfg.train_batch, self.cfg.ctx])
                .to_literal()?;
            let mut inputs: Vec<&xla::Literal> = params.iter().collect();
            inputs.push(&xt);
            inputs.push(&yt);
            let outs = self.engine.execute_literal_refs(&entry, &exe, &inputs)?;
            total += HostTensor::from_literal(&outs[0])?.scalar_as_f32()? as f64;
        }
        Ok(total / batches.len() as f64)
    }
}
