//! Parameter store: owns the model parameters and optimizer state as
//! host tensors, initializes them with the same scheme as
//! `model.init_params` (GPT-2 init, β ~ U[0.5, β_init], γ = γ_init), and
//! persists checkpoints.
//!
//! Checkpoint format: `<name>.ckpt` = JSON header line (shapes, step,
//! config key) + '\0' + concatenated little-endian f32 payloads in
//! `param_order` order, params then m then v. Self-describing and
//! mmap-friendly.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::runtime::HostTensor;
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Model parameters + AdamW moments, in canonical flattening order.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub config_key: String,
    pub order: Vec<String>,
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub step: u64,
}

impl ParamStore {
    /// Initialize like python's `init_params` (same distributions; the
    /// exact draws differ, which is fine — each language trains from its
    /// own seed and the claims are about convergence behaviour).
    pub fn init(cfg: &ModelConfig, seed: u64) -> Result<ParamStore> {
        let mut rng = Pcg32::seeded(seed);
        let std = 0.02f32;
        let rstd = std / (2.0 * cfg.n_layer as f32).sqrt();

        let mut params = Vec::with_capacity(cfg.param_order.len());
        for name in &cfg.param_order {
            let shape = cfg.shape_of(name)?.to_vec();
            let n: usize = shape.iter().product();
            let vals: Vec<f32> = match name.as_str() {
                "wte" | "wpe" | "attn_qkv_w" | "mlp_fc_w" => {
                    rng.normal_vec_f32(n, 0.0, std)
                }
                // residual projections scaled down (GPT-2)
                "attn_proj_w" | "mlp_proj_w" => rng.normal_vec_f32(n, 0.0, rstd),
                // layernorm gains
                "ln1_g" | "ln2_g" | "lnf_g" => vec![1.0; n],
                // biases / layernorm shifts
                "ln1_b" | "ln2_b" | "lnf_b" | "attn_qkv_b" | "attn_proj_b"
                | "mlp_fc_b" | "mlp_proj_b" => vec![0.0; n],
                "beta" => (0..n)
                    .map(|_| rng.range_f64(0.5, cfg.beta_init.max(0.5001)) as f32)
                    .collect(),
                "gamma" => vec![cfg.gamma_init as f32; n],
                // ssmax's learnable per-head scale: s·ln(n) ≈ 1 at the
                // tiny/paper context lengths, matching the paper's
                // reported trained value s ≈ 0.43
                "ssmax_s" => vec![0.43; n],
                other => bail!("no init rule for param {other:?}"),
            };
            params.push(HostTensor::from_f32(&vals, &shape));
        }
        let zeros: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::zeros(p.dtype, &p.shape))
            .collect();
        Ok(ParamStore {
            config_key: cfg.key.clone(),
            order: cfg.param_order.clone(),
            params,
            m: zeros.clone(),
            v: zeros,
            step: 0,
        })
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.order.iter().position(|n| n == name)
    }

    pub fn get(&self, name: &str) -> Option<&HostTensor> {
        self.index_of(name).map(|i| &self.params[i])
    }

    pub fn param_count(&self) -> usize {
        self.params.iter().map(HostTensor::elems).sum()
    }

    /// Overwrite every β/γ entry with fixed values (the `--beta0` /
    /// `--gamma0` sweep knobs): pins the whole per-(layer, head) grid so
    /// init-sensitivity runs start from a controlled point.
    pub fn pin_beta_gamma(&mut self, beta0: f32, gamma0: f32) {
        for (name, val) in [("beta", beta0), ("gamma", gamma0)] {
            if let Some(i) = self.index_of(name) {
                let shape = self.params[i].shape.clone();
                let vals = vec![val; self.params[i].elems()];
                self.params[i] = HostTensor::from_f32(&vals, &shape);
            }
        }
    }

    // ---- checkpointing -----------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut header = Json::obj();
        header.set("config_key", Json::from(self.config_key.as_str()));
        header.set("step", Json::from(self.step as f64));
        header.set(
            "order",
            Json::Arr(self.order.iter().map(|s| Json::from(s.as_str())).collect()),
        );
        let mut shapes = Json::obj();
        for (name, t) in self.order.iter().zip(&self.params) {
            shapes.set(name, Json::from(t.shape.clone()));
        }
        header.set("shapes", shapes);

        // Atomic (temp + rename): a `consmax train` killed mid-save must
        // never leave a truncated checkpoint for `--resume` to load.
        crate::util::atomicio::write_atomic(path, |f| {
            f.write_all(header.to_string().as_bytes())?;
            f.write_all(&[0u8])?;
            for group in [&self.params, &self.m, &self.v] {
                for t in group {
                    f.write_all(&t.data)?;
                }
            }
            Ok(())
        })
    }

    pub fn load(path: &Path, cfg: &ModelConfig) -> Result<ParamStore> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut bytes)?;
        let nul = bytes
            .iter()
            .position(|&b| b == 0)
            .context("missing header terminator")?;
        let header = Json::parse(std::str::from_utf8(&bytes[..nul])?)?;
        let key = header.get("config_key").as_str().context("config_key")?;
        if key != cfg.key {
            bail!("checkpoint is for {key:?}, engine config is {:?}", cfg.key);
        }
        let step = header.get("step").as_f64().context("step")? as u64;
        let order: Vec<String> = header
            .get("order")
            .as_arr()
            .context("order")?
            .iter()
            .map(|v| v.as_str().unwrap_or_default().to_string())
            .collect();
        if order != cfg.param_order {
            bail!("checkpoint param order mismatch");
        }

        let mut offset = nul + 1;
        let mut read_group = |shapes: &BTreeMap<String, Vec<usize>>| -> Result<Vec<HostTensor>> {
            let mut out = Vec::with_capacity(order.len());
            for name in &order {
                let shape = &shapes[name];
                let n: usize = shape.iter().product();
                let len = n * 4;
                if offset + len > bytes.len() {
                    bail!("checkpoint truncated at {name}");
                }
                out.push(HostTensor {
                    dtype: crate::runtime::DType::F32,
                    shape: shape.clone(),
                    data: bytes[offset..offset + len].to_vec(),
                });
                offset += len;
            }
            Ok(out)
        };
        let params = read_group(&cfg.param_shapes)?;
        let m = read_group(&cfg.param_shapes)?;
        let v = read_group(&cfg.param_shapes)?;
        if offset != bytes.len() {
            bail!("checkpoint has {} trailing bytes", bytes.len() - offset);
        }
        Ok(ParamStore {
            config_key: key.to_string(),
            order,
            params,
            m,
            v,
            step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Manifest;

    fn test_cfg() -> ModelConfig {
        // hand-built config mirroring the tiny model
        let json = r#"{
          "format": "hlo-text-v1", "entries": {},
          "configs": { "tiny_consmax": {
            "vocab": 256, "ctx": 64, "n_layer": 2, "n_head": 2,
            "n_embd": 64, "normalizer": "consmax", "beta_init": 2.5,
            "gamma_init": 100.0, "total_steps": 200, "train_batch": 4,
            "param_order": ["wte", "wpe", "ln1_g", "ln1_b", "attn_qkv_w",
              "attn_qkv_b", "attn_proj_w", "attn_proj_b", "beta", "gamma",
              "ln2_g", "ln2_b", "mlp_fc_w", "mlp_fc_b", "mlp_proj_w",
              "mlp_proj_b", "lnf_g", "lnf_b"],
            "param_shapes": {
              "wte": [256, 64], "wpe": [64, 64],
              "ln1_g": [2, 64], "ln1_b": [2, 64],
              "attn_qkv_w": [2, 64, 192], "attn_qkv_b": [2, 192],
              "attn_proj_w": [2, 64, 64], "attn_proj_b": [2, 64],
              "beta": [2, 2], "gamma": [2, 2],
              "ln2_g": [2, 64], "ln2_b": [2, 64],
              "mlp_fc_w": [2, 64, 256], "mlp_fc_b": [2, 256],
              "mlp_proj_w": [2, 256, 64], "mlp_proj_b": [2, 64],
              "lnf_g": [64], "lnf_b": [64]
            }
          }}}"#;
        let dir = std::env::temp_dir().join("consmax_params_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), json).unwrap();
        Manifest::load(&dir).unwrap().config("tiny_consmax").unwrap().clone()
    }

    #[test]
    fn init_respects_rules() {
        let cfg = test_cfg();
        let ps = ParamStore::init(&cfg, 0).unwrap();
        // gamma constant
        let gamma = ps.get("gamma").unwrap().as_f32().unwrap();
        assert!(gamma.iter().all(|&g| g == 100.0));
        // beta in range and varied
        let beta = ps.get("beta").unwrap().as_f32().unwrap();
        assert!(beta.iter().all(|&b| (0.5..=2.5).contains(&b)));
        assert!(beta.windows(2).any(|w| w[0] != w[1]));
        // ln gains are ones
        let g = ps.get("ln1_g").unwrap().as_f32().unwrap();
        assert!(g.iter().all(|&x| x == 1.0));
        // weights have plausible std
        let w = ps.get("attn_qkv_w").unwrap().as_f32().unwrap();
        let mean: f32 = w.iter().sum::<f32>() / w.len() as f32;
        let var: f32 =
            w.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / w.len() as f32;
        assert!((var.sqrt() - 0.02).abs() < 0.002, "{}", var.sqrt());
    }

    #[test]
    fn init_deterministic() {
        let cfg = test_cfg();
        let a = ParamStore::init(&cfg, 7).unwrap();
        let b = ParamStore::init(&cfg, 7).unwrap();
        assert_eq!(a.params[0].data, b.params[0].data);
        let c = ParamStore::init(&cfg, 8).unwrap();
        assert_ne!(a.params[0].data, c.params[0].data);
    }

    #[test]
    fn moments_start_zero() {
        let cfg = test_cfg();
        let ps = ParamStore::init(&cfg, 0).unwrap();
        for t in ps.m.iter().chain(&ps.v) {
            assert!(t.data.iter().all(|&b| b == 0));
        }
    }

    #[test]
    fn checkpoint_roundtrip() {
        let cfg = test_cfg();
        let mut ps = ParamStore::init(&cfg, 3).unwrap();
        ps.step = 42;
        let path = std::env::temp_dir().join("consmax_params_test/ck.ckpt");
        ps.save(&path).unwrap();
        let back = ParamStore::load(&path, &cfg).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.params.len(), ps.params.len());
        for (a, b) in back.params.iter().zip(&ps.params) {
            assert_eq!(a, b);
        }
        for (a, b) in back.v.iter().zip(&ps.v) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn checkpoint_rejects_wrong_config() {
        let cfg = test_cfg();
        let ps = ParamStore::init(&cfg, 0).unwrap();
        let path = std::env::temp_dir().join("consmax_params_test/ck2.ckpt");
        ps.save(&path).unwrap();
        let mut other = cfg.clone();
        other.key = "paper_softmax".into();
        assert!(ParamStore::load(&path, &other).is_err());
    }

    #[test]
    fn truncated_checkpoint_rejected() {
        let cfg = test_cfg();
        let ps = ParamStore::init(&cfg, 0).unwrap();
        let path = std::env::temp_dir().join("consmax_params_test/ck3.ckpt");
        ps.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 100]).unwrap();
        assert!(ParamStore::load(&path, &cfg).is_err());
    }

    #[test]
    fn param_count_matches_config() {
        let cfg = test_cfg();
        let ps = ParamStore::init(&cfg, 0).unwrap();
        assert_eq!(ps.param_count(), cfg.param_count());
    }
}
