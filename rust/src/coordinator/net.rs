//! Adapter gluing the coordinator's [`Server`] onto the runtime's
//! [`ServeEngine`] seam (DESIGN.md §Serving-robustness seam).
//!
//! The network front end (`runtime::serve_net`) is layered *below* the
//! coordinator and therefore defines its own request/event vocabulary;
//! [`EngineAdapter`] translates: `NetRequest` → [`GenRequest`] (wiring
//! the CLI's default deadline onto requests that carry none),
//! [`ServeEvent`] → `NetEvent`, admission and cancellation straight
//! through, and `GET /stats` onto [`Server::stats`] serialized with the
//! vendored JSON writer.
//!
//! The adapter owns the event-capture toggle: constructing one switches
//! the server to capture mode so every token/terminal event reaches the
//! wire; in-process callers that never build an adapter keep paying
//! nothing.

use anyhow::{ensure, Result};

use crate::coordinator::server::{
    Admission, GenRequest, ServeEvent, Server,
};
use crate::runtime::serve_net::{
    NetAdmission, NetEvent, NetRequest, ServeEngine,
};
use crate::util::json::Json;

/// [`ServeEngine`] over a continuous-batching [`Server`].
pub struct EngineAdapter<'e> {
    server: Server<'e>,
    /// Applied to requests that carry no deadline of their own
    /// (`--deadline-ms`; `None` = no default deadline).
    default_deadline_ms: Option<u64>,
}

impl<'e> EngineAdapter<'e> {
    /// Wrap `server` for network serving: enables lifecycle-event
    /// capture and installs the admission limits. Requires the
    /// continuous scheduler (the static batcher has no mid-flight
    /// cancellation to offer a network client).
    pub fn new(
        mut server: Server<'e>,
        queue_cap: Option<usize>,
        ttft_limit_ms: Option<f64>,
        default_deadline_ms: Option<u64>,
    ) -> Result<EngineAdapter<'e>> {
        ensure!(
            server.generator.supports_continuous(),
            "network serving needs the continuous scheduler \
             (native KV-cache decode); this generator cannot stream"
        );
        server.set_admission_limits(queue_cap, ttft_limit_ms);
        server.set_event_capture(true);
        Ok(EngineAdapter { server, default_deadline_ms })
    }

    /// The wrapped server (stats, KV gauges, recorders).
    pub fn server(&self) -> &Server<'e> {
        &self.server
    }

    /// Unwrap (drain-time inspection in tests and the CLI).
    pub fn into_server(self) -> Server<'e> {
        self.server
    }
}

fn to_net_event(ev: ServeEvent) -> NetEvent {
    match ev {
        ServeEvent::Token { id, token } => NetEvent::Token { id, token },
        ServeEvent::Completed(r) => NetEvent::Completed {
            id: r.id,
            text: r.text,
            tokens: r.new_tokens,
            latency_ms: r.latency_ms,
        },
        ServeEvent::TimedOut { id } => NetEvent::TimedOut { id },
        ServeEvent::Cancelled { id } => NetEvent::Cancelled { id },
    }
}

impl<'e> ServeEngine for EngineAdapter<'e> {
    fn try_admit(&mut self, req: NetRequest) -> NetAdmission {
        let mut gen =
            GenRequest::greedy(req.id, req.prompt, req.max_new_tokens);
        gen.temperature = req.temperature;
        gen.deadline_ms = req.deadline_ms.or(self.default_deadline_ms);
        match self.server.try_submit(gen) {
            Admission::Admitted => NetAdmission::Admitted,
            Admission::Shed { retry_after_ms } => {
                NetAdmission::Shed { retry_after_ms }
            }
        }
    }

    fn cancel(&mut self, id: u64) -> bool {
        self.server.cancel(id)
    }

    fn tick(&mut self) -> Result<Vec<NetEvent>> {
        if self.has_work() {
            self.server.step()?;
        }
        // cancellations/timeouts buffered between ticks flush here too
        Ok(self
            .server
            .drain_events()
            .into_iter()
            .map(to_net_event)
            .collect())
    }

    fn has_work(&self) -> bool {
        self.server.pending() + self.server.in_flight() > 0
    }

    fn live_ids(&self) -> Vec<u64> {
        self.server.live_ids()
    }

    fn stats_json(&self) -> String {
        let s = self.server.stats();
        let mut o = Json::obj();
        o.set("pending", Json::from(s.pending));
        o.set("in_flight", Json::from(s.in_flight));
        o.set("submitted", Json::from(s.submitted as usize));
        o.set("completed", Json::from(s.completed as usize));
        o.set("tokens_out", Json::from(s.tokens_out as usize));
        o.set("shed", Json::from(s.shed as usize));
        o.set("timed_out", Json::from(s.timed_out as usize));
        o.set("cancelled", Json::from(s.cancelled as usize));
        o.set("panics_recovered", Json::from(s.panics_recovered as usize));
        o.set("preemptions", Json::from(s.preemptions as usize));
        o.set("kv_paged", Json::from(s.kv_paged));
        o.set("kv_total_blocks", Json::from(s.kv_total_blocks));
        o.set("kv_free_blocks", Json::from(s.kv_free_blocks));
        o.set("kv_shared_blocks", Json::from(s.kv_shared_blocks));
        o.set("kv_block_tokens", Json::from(s.kv_block_tokens));
        o.to_string()
    }
}
