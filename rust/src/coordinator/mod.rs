//! Layer-3 coordinator: everything that runs at request time.
//!
//! * [`params`] — parameter/optimizer state + checkpoints.
//! * [`trainer`] — the training loop over the AOT `train_step` (Fig 6/7).
//! * [`sweep`] — β/γ initialization grid search (Fig 8).
//! * [`server`] — batched KV-cached generation service.
//!
//! The paper's contribution lives at L1/L2 (the normalizer) and in the
//! `hw`/`sim` substrates; this layer is the thin-but-real driver the
//! system prompt's architecture calls for: CLI, process lifecycle,
//! training/serving loops, metrics.

pub mod params;
pub mod report;
pub mod server;
pub mod sweep;
pub mod trainer;

pub use params::ParamStore;
pub use report::{report_compare, report_run};
pub use server::{GenRequest, GenResponse, Generator, Server};
pub use sweep::{best_point, sweep_init, SweepOptions, SweepPoint};
pub use trainer::{TrainOptions, TrainReport, Trainer};
