//! Layer-3 coordinator: everything that runs at request time.
//!
//! * [`params`] — parameter/optimizer state + checkpoints (all backends).
//! * [`server`] — batched generation service over the pluggable
//!   [`Generator`] (native KV-cached decode with a recompute oracle
//!   escape hatch, or PJRT KV-cached decode).
//! * [`trainer`] — the training loops (Fig 6/7): the always-available
//!   [`NativeTrainer`] over the hand-derived native backward + AdamW
//!   (DESIGN.md §Training seam), and the PJRT [`Trainer`] over the AOT
//!   fused `train_step` (`--features pjrt`).
//! * [`sweep`] (`--features pjrt`) — β/γ initialization grid (Fig 8).
//!
//! The paper's contribution lives at L1/L2 (the normalizer) and in the
//! `hw`/`sim` substrates; this layer is the thin-but-real driver: CLI,
//! process lifecycle, training/serving loops, metrics.

pub mod net;
pub mod params;
pub mod report;
pub mod server;
#[cfg(feature = "pjrt")]
pub mod sweep;
pub mod trainer;

pub use net::EngineAdapter;
pub use params::ParamStore;
pub use report::{report_compare, report_run};
pub use server::{
    Admission, DecodeMode, GenOutput, GenRequest, GenResponse, Generator,
    ServeEvent, ServeStats, Server, SpecConfig,
};
#[cfg(feature = "pjrt")]
pub use sweep::{best_point, sweep_init, SweepOptions, SweepPoint};
#[cfg(feature = "pjrt")]
pub use trainer::Trainer;
pub use trainer::{NativeTrainer, TrainOptions, TrainReport};
