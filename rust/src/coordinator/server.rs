//! Generation server: request queue → static batcher → KV-cached decode
//! loop over the AOT `decode_b{N}` executables, with per-request latency
//! accounting. This is the "LLM inference" face of the coordinator — the
//! place where ConSmax's merged β/γ constants actually serve requests.
//!
//! Batching policy is static (vLLM-v0-style): up to the largest exported
//! decode batch size, prompts left-aligned by feeding them through the
//! decode path position by position (prefill), shorter prompts padded
//! with spaces. Responses return per-request generated text plus timing.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::coordinator::params::ParamStore;
use crate::data::ByteTokenizer;
use crate::metrics::LatencyRecorder;
use crate::runtime::{Engine, HostTensor};
use crate::util::rng::Pcg32;

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling.
    pub temperature: f32,
}

/// A completed response.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub prompt_tokens: usize,
    pub new_tokens: usize,
    pub latency_ms: f64,
    pub batch_size: usize,
}

/// Low-level batched generator over the decode artifacts.
pub struct Generator<'e> {
    engine: &'e Engine,
    pub cfg: ModelConfig,
    /// Parameters cached as device buffers: uploaded once at construction
    /// instead of on every decode step (§Perf: removes the dominant
    /// per-step cost, a full-model host->device copy).
    params: Vec<xla::PjRtBuffer>,
    /// Decode batch sizes available in the manifest, descending.
    batch_sizes: Vec<usize>,
    rng: Pcg32,
}

impl<'e> Generator<'e> {
    pub fn new(engine: &'e Engine, store: &ParamStore, seed: u64) -> Result<Generator<'e>> {
        let cfg = engine.manifest.config(&store.config_key)?.clone();
        let params = store
            .params
            .iter()
            .map(|t| engine.upload(t))
            .collect::<Result<_>>()?;
        let mut batch_sizes: Vec<usize> = engine
            .manifest
            .entries
            .keys()
            .filter_map(|name| {
                name.strip_prefix(&format!("{}_decode_b", cfg.key))
                    .and_then(|b| b.parse().ok())
            })
            .collect();
        batch_sizes.sort_unstable_by(|a, b| b.cmp(a));
        if batch_sizes.is_empty() {
            bail!("no decode artifacts for {} (re-run `make artifacts`)", cfg.key);
        }
        Ok(Generator { engine, cfg, params, batch_sizes, rng: Pcg32::seeded(seed) })
    }

    pub fn max_batch(&self) -> usize {
        self.batch_sizes[0]
    }

    /// Smallest exported batch size that fits `n` requests.
    fn pick_batch(&self, n: usize) -> usize {
        *self
            .batch_sizes
            .iter()
            .filter(|&&b| b >= n)
            .min()
            .unwrap_or(&self.batch_sizes[0])
    }

    /// Generate continuations for up to `max_batch()` prompts at once.
    /// All prompts are processed in lock-step; the returned strings
    /// contain only the newly generated text.
    pub fn generate_batch(
        &mut self,
        prompts: &[String],
        max_new: usize,
        temperature: f32,
    ) -> Result<Vec<String>> {
        anyhow::ensure!(!prompts.is_empty(), "empty batch");
        let b = self.pick_batch(prompts.len());
        anyhow::ensure!(
            prompts.len() <= b,
            "batch of {} exceeds max decode batch {b}",
            prompts.len()
        );
        let entry = format!("{}_decode_b{}", self.cfg.key, b);
        let exe = self.engine.load(&entry)?;
        let tok = ByteTokenizer;

        // Left-pad prompts with spaces to a common length; clamp so that
        // prompt + generation fits the KV cache (ctx).
        let budget = self.cfg.ctx.saturating_sub(max_new).max(1);
        let mut encoded: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| {
                let mut t = tok.encode(p);
                if t.len() > budget {
                    t = t.split_off(t.len() - budget);
                }
                t
            })
            .collect();
        let plen = encoded.iter().map(Vec::len).max().unwrap();
        for t in &mut encoded {
            while t.len() < plen {
                t.insert(0, b' ' as i32);
            }
        }
        // rows beyond the real prompts replicate row 0 (ignored outputs)
        while encoded.len() < b {
            encoded.push(encoded[0].clone());
        }

        // KV caches start zeroed (device-resident; re-uploaded per step
        // because the output tuple only materializes on the host)
        let cache_shape = vec![
            self.cfg.n_layer,
            b,
            self.cfg.n_head,
            self.cfg.ctx,
            self.cfg.head_dim(),
        ];
        let mut kc = self.engine.upload(&HostTensor::zeros(
            crate::runtime::DType::F32,
            &cache_shape,
        ))?;
        let mut vc = self.engine.upload(&HostTensor::zeros(
            crate::runtime::DType::F32,
            &cache_shape,
        ))?;

        let steps = plen + max_new - 1;
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        let mut last_tokens: Vec<i32> = encoded.iter().map(|t| t[0]).collect();

        for pos in 0..=steps {
            if pos >= self.cfg.ctx {
                break;
            }
            let toks: Vec<i32> = (0..b)
                .map(|r| {
                    if pos < plen {
                        encoded[r][pos]
                    } else {
                        last_tokens[r]
                    }
                })
                .collect();
            let tok_buf = self
                .engine
                .upload(&HostTensor::from_i32(&toks, &[b]))?;
            let pos_buf = self
                .engine
                .upload(&HostTensor::scalar_i32(pos as i32))?;
            let inputs: Vec<&xla::PjRtBuffer> = self
                .params
                .iter()
                .chain([&kc, &vc, &pos_buf, &tok_buf])
                .collect();
            let mut outs =
                self.engine.execute_buffer_refs(&entry, &exe, &inputs)?;
            vc = self.engine.upload_literal(&outs.pop().context("vc")?)?;
            kc = self.engine.upload_literal(&outs.pop().context("kc")?)?;
            let logits_t = HostTensor::from_literal(&outs.pop().context("logits")?)?;
            let logits = logits_t.as_f32()?;
            let vocab = self.cfg.vocab;

            if pos + 1 >= plen {
                // sample the next token per row
                for r in 0..prompts.len() {
                    let row = &logits[r * vocab..(r + 1) * vocab];
                    let next = if temperature <= 0.0 {
                        argmax(row)
                    } else {
                        sample_temperature(row, temperature, &mut self.rng)
                    };
                    last_tokens[r] = next as i32;
                    if generated[r].len() < max_new {
                        generated[r].push(next as i32);
                    }
                }
            }
        }
        Ok(generated.iter().map(|g| tok.decode(g)).collect())
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

fn sample_temperature(logits: &[f32], temp: f32, rng: &mut Pcg32) -> usize {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - m) / temp) as f64).exp())
        .collect();
    rng.weighted(&weights)
}

/// Static-batching server over a [`Generator`].
pub struct Server<'e> {
    pub generator: Generator<'e>,
    queue: VecDeque<GenRequest>,
    pub latencies: LatencyRecorder,
    pub completed: u64,
    pub tokens_out: u64,
}

impl<'e> Server<'e> {
    pub fn new(generator: Generator<'e>) -> Server<'e> {
        Server {
            generator,
            queue: VecDeque::new(),
            latencies: LatencyRecorder::default(),
            completed: 0,
            tokens_out: 0,
        }
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Serve one batch from the queue (up to the largest decode batch);
    /// returns the completed responses. No-op on an empty queue.
    pub fn run_once(&mut self) -> Result<Vec<GenResponse>> {
        if self.queue.is_empty() {
            return Ok(Vec::new());
        }
        let b = self.generator.max_batch().min(self.queue.len());
        let batch: Vec<GenRequest> = (0..b).map(|_| self.queue.pop_front().unwrap()).collect();
        let prompts: Vec<String> = batch.iter().map(|r| r.prompt.clone()).collect();
        let max_new = batch.iter().map(|r| r.max_new_tokens).max().unwrap().max(1);
        let temp = batch[0].temperature;

        let t0 = Instant::now();
        let texts = self.generator.generate_batch(&prompts, max_new, temp)?;
        let dt_ms = t0.elapsed().as_secs_f64() * 1e3;

        let mut out = Vec::with_capacity(b);
        for (req, text) in batch.into_iter().zip(texts) {
            let clipped: String = text
                .chars()
                .take(req.max_new_tokens)
                .collect();
            self.latencies.record_us(dt_ms * 1e3);
            self.completed += 1;
            self.tokens_out += clipped.len() as u64;
            out.push(GenResponse {
                id: req.id,
                prompt_tokens: req.prompt.len(),
                new_tokens: clipped.len(),
                text: clipped,
                latency_ms: dt_ms,
                batch_size: b,
            });
        }
        Ok(out)
    }

    /// Drain the whole queue.
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResponse>> {
        let mut all = Vec::new();
        while !self.queue.is_empty() {
            all.extend(self.run_once()?);
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_finds_max() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 3.0]), 1); // first max wins
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn temperature_sampling_respects_distribution() {
        let mut rng = Pcg32::seeded(0);
        let logits = vec![0.0f32, 5.0, 0.0, 0.0];
        let mut hits = 0;
        for _ in 0..200 {
            if sample_temperature(&logits, 1.0, &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 180, "{hits}");
    }

    #[test]
    fn high_temperature_flattens() {
        let mut rng = Pcg32::seeded(1);
        let logits = vec![0.0f32, 5.0, 0.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[sample_temperature(&logits, 50.0, &mut rng)] += 1;
        }
        // near uniform at T=50
        for c in counts {
            assert!(c > 300, "{counts:?}");
        }
    }
}
