//! Generation server: request queue → static batcher → batched decode
//! loop, with per-request latency accounting. This is the "LLM inference"
//! face of the coordinator — the place where ConSmax's merged β/γ
//! constants actually serve requests.
//!
//! The [`Generator`] is backend-pluggable (the multi-backend seam of
//! DESIGN.md §4):
//!
//! * **native** — recompute decode over [`NativeModel`]; always
//!   available, needs no artifacts. `consmax serve-demo --backend native`
//!   runs end-to-end on a machine with nothing but this crate.
//! * **pjrt** (`--features pjrt`) — KV-cached decode over the AOT
//!   `decode_b{N}` executables, parameters uploaded to device buffers
//!   once at construction.
//!
//! Batching policy is static (vLLM-v0-style): up to the backend's
//! largest decode batch, prompts left-aligned by padding with spaces.
//! Responses return per-request generated text plus timing.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::time::Instant;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::{bail, Context};

use crate::config::ModelConfig;
use crate::coordinator::params::ParamStore;
use crate::data::ByteTokenizer;
use crate::metrics::LatencyRecorder;
use crate::runtime::backend::NativeModel;
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, HostTensor};
use crate::util::rng::Pcg32;

/// Largest batch the native recompute decoder serves at once (a knob,
/// not an export constraint like the PJRT decode artifacts).
pub const NATIVE_MAX_BATCH: usize = 8;

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling.
    pub temperature: f32,
}

/// A completed response.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub prompt_tokens: usize,
    pub new_tokens: usize,
    pub latency_ms: f64,
    pub batch_size: usize,
}

/// Backend-specific decode state.
enum GenExec<'e> {
    /// Recompute decode over the pure-Rust forward pass.
    Native(Box<NativeModel>, PhantomData<&'e ()>),
    /// KV-cached decode over the AOT `decode_b{N}` executables.
    #[cfg(feature = "pjrt")]
    Pjrt {
        engine: &'e Engine,
        /// Parameters cached as device buffers: uploaded once at
        /// construction instead of on every decode step (§Perf: removes
        /// the dominant per-step cost, a full-model host→device copy).
        params: Vec<xla::PjRtBuffer>,
        /// Decode batch sizes available in the manifest, descending.
        batch_sizes: Vec<usize>,
    },
}

/// Batched generator over a decode backend.
pub struct Generator<'e> {
    pub cfg: ModelConfig,
    exec: GenExec<'e>,
    rng: Pcg32,
}

impl<'e> Generator<'e> {
    /// PJRT-backed generator over an engine's decode artifacts.
    #[cfg(feature = "pjrt")]
    pub fn new(engine: &'e Engine, store: &ParamStore, seed: u64) -> Result<Generator<'e>> {
        let cfg = engine.manifest.config(&store.config_key)?.clone();
        let params = store
            .params
            .iter()
            .map(|t| engine.upload(t))
            .collect::<Result<_>>()?;
        let mut batch_sizes: Vec<usize> = engine
            .manifest
            .entries
            .keys()
            .filter_map(|name| {
                name.strip_prefix(&format!("{}_decode_b", cfg.key))
                    .and_then(|b| b.parse().ok())
            })
            .collect();
        batch_sizes.sort_unstable_by(|a, b| b.cmp(a));
        if batch_sizes.is_empty() {
            bail!("no decode artifacts for {} (re-run `make artifacts`)", cfg.key);
        }
        Ok(Generator {
            cfg,
            exec: GenExec::Pjrt { engine, params, batch_sizes },
            rng: Pcg32::seeded(seed),
        })
    }

    /// Native generator: pure-Rust decode, no artifacts required.
    pub fn native(
        cfg: &ModelConfig,
        store: &ParamStore,
        seed: u64,
    ) -> Result<Generator<'static>> {
        let model = NativeModel::from_params(cfg, &store.order, &store.params)?;
        Ok(Generator {
            cfg: cfg.clone(),
            exec: GenExec::Native(Box::new(model), PhantomData),
            rng: Pcg32::seeded(seed),
        })
    }

    /// Which backend this generator decodes on ("native" / "pjrt").
    pub fn backend_name(&self) -> &'static str {
        match &self.exec {
            GenExec::Native(..) => "native",
            #[cfg(feature = "pjrt")]
            GenExec::Pjrt { .. } => "pjrt",
        }
    }

    pub fn max_batch(&self) -> usize {
        match &self.exec {
            GenExec::Native(..) => NATIVE_MAX_BATCH,
            #[cfg(feature = "pjrt")]
            GenExec::Pjrt { batch_sizes, .. } => batch_sizes[0],
        }
    }

    /// Encode prompts, clamp to the KV/ctx budget and left-pad with
    /// spaces to a common length (shared by both decode backends).
    fn encode_prompts(&self, prompts: &[String], max_new: usize) -> Vec<Vec<i32>> {
        let tok = ByteTokenizer;
        let budget = self.cfg.ctx.saturating_sub(max_new).max(1);
        let mut encoded: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| {
                let mut t = tok.encode(p);
                if t.len() > budget {
                    t = t.split_off(t.len() - budget);
                }
                t
            })
            .collect();
        let plen = encoded.iter().map(Vec::len).max().unwrap_or(1).max(1);
        for t in &mut encoded {
            while t.len() < plen {
                t.insert(0, b' ' as i32);
            }
        }
        encoded
    }

    /// Generate continuations for up to `max_batch()` prompts at once.
    /// All prompts are processed in lock-step; the returned strings
    /// contain only the newly generated text.
    pub fn generate_batch(
        &mut self,
        prompts: &[String],
        max_new: usize,
        temperature: f32,
    ) -> Result<Vec<String>> {
        anyhow::ensure!(!prompts.is_empty(), "empty batch");
        anyhow::ensure!(
            prompts.len() <= self.max_batch(),
            "batch of {} exceeds max decode batch {}",
            prompts.len(),
            self.max_batch()
        );
        let encoded = self.encode_prompts(prompts, max_new);
        let tok = ByteTokenizer;
        match &mut self.exec {
            GenExec::Native(model, _) => {
                let mut seqs = encoded;
                let mut generated: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
                for _ in 0..max_new {
                    let logits = model.next_logits(&seqs)?;
                    let vocab = self.cfg.vocab;
                    for (r, seq) in seqs.iter_mut().enumerate() {
                        let row = &logits[r * vocab..(r + 1) * vocab];
                        let next = if temperature <= 0.0 {
                            argmax(row)
                        } else {
                            sample_temperature(row, temperature, &mut self.rng)
                        };
                        seq.push(next as i32);
                        generated[r].push(next as i32);
                    }
                }
                Ok(generated.iter().map(|g| tok.decode(g)).collect())
            }
            #[cfg(feature = "pjrt")]
            GenExec::Pjrt { engine, params, batch_sizes } => {
                // smallest exported batch size that fits the request count
                let b = *batch_sizes
                    .iter()
                    .filter(|&&bs| bs >= prompts.len())
                    .min()
                    .unwrap_or(&batch_sizes[0]);
                let entry = format!("{}_decode_b{}", self.cfg.key, b);
                let exe = engine.load(&entry)?;

                // rows beyond the real prompts replicate row 0 (outputs
                // ignored)
                let mut encoded = encoded;
                let plen = encoded[0].len();
                while encoded.len() < b {
                    encoded.push(encoded[0].clone());
                }

                // KV caches start zeroed (device-resident; re-uploaded per
                // step because the output tuple only materializes on host)
                let cache_shape = vec![
                    self.cfg.n_layer,
                    b,
                    self.cfg.n_head,
                    self.cfg.ctx,
                    self.cfg.head_dim(),
                ];
                let mut kc = engine.upload(&HostTensor::zeros(
                    crate::runtime::DType::F32,
                    &cache_shape,
                ))?;
                let mut vc = engine.upload(&HostTensor::zeros(
                    crate::runtime::DType::F32,
                    &cache_shape,
                ))?;

                let steps = plen + max_new - 1;
                let mut generated: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
                let mut last_tokens: Vec<i32> =
                    encoded.iter().map(|t| t[0]).collect();

                for pos in 0..=steps {
                    if pos >= self.cfg.ctx {
                        break;
                    }
                    let toks: Vec<i32> = (0..b)
                        .map(|r| {
                            if pos < plen {
                                encoded[r][pos]
                            } else {
                                last_tokens[r]
                            }
                        })
                        .collect();
                    let tok_buf =
                        engine.upload(&HostTensor::from_i32(&toks, &[b]))?;
                    let pos_buf =
                        engine.upload(&HostTensor::scalar_i32(pos as i32))?;
                    let inputs: Vec<&xla::PjRtBuffer> = params
                        .iter()
                        .chain([&kc, &vc, &pos_buf, &tok_buf])
                        .collect();
                    let mut outs =
                        engine.execute_buffer_refs(&entry, &exe, &inputs)?;
                    vc = engine.upload_literal(&outs.pop().context("vc")?)?;
                    kc = engine.upload_literal(&outs.pop().context("kc")?)?;
                    let logits_t =
                        HostTensor::from_literal(&outs.pop().context("logits")?)?;
                    let logits = logits_t.as_f32()?;
                    let vocab = self.cfg.vocab;

                    if pos + 1 >= plen {
                        // sample the next token per row
                        for r in 0..prompts.len() {
                            let row = &logits[r * vocab..(r + 1) * vocab];
                            let next = if temperature <= 0.0 {
                                argmax(row)
                            } else {
                                sample_temperature(row, temperature, &mut self.rng)
                            };
                            last_tokens[r] = next as i32;
                            if generated[r].len() < max_new {
                                generated[r].push(next as i32);
                            }
                        }
                    }
                }
                Ok(generated.iter().map(|g| tok.decode(g)).collect())
            }
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

fn sample_temperature(logits: &[f32], temp: f32, rng: &mut Pcg32) -> usize {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - m) / temp) as f64).exp())
        .collect();
    rng.weighted(&weights)
}

/// Static-batching server over a [`Generator`].
pub struct Server<'e> {
    pub generator: Generator<'e>,
    queue: VecDeque<GenRequest>,
    pub latencies: LatencyRecorder,
    pub completed: u64,
    pub tokens_out: u64,
}

impl<'e> Server<'e> {
    pub fn new(generator: Generator<'e>) -> Server<'e> {
        Server {
            generator,
            queue: VecDeque::new(),
            latencies: LatencyRecorder::default(),
            completed: 0,
            tokens_out: 0,
        }
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Serve one batch from the queue (up to the largest decode batch);
    /// returns the completed responses. No-op on an empty queue.
    pub fn run_once(&mut self) -> Result<Vec<GenResponse>> {
        if self.queue.is_empty() {
            return Ok(Vec::new());
        }
        let b = self.generator.max_batch().min(self.queue.len());
        let batch: Vec<GenRequest> = (0..b).map(|_| self.queue.pop_front().unwrap()).collect();
        let prompts: Vec<String> = batch.iter().map(|r| r.prompt.clone()).collect();
        let max_new = batch.iter().map(|r| r.max_new_tokens).max().unwrap().max(1);
        let temp = batch[0].temperature;

        let t0 = Instant::now();
        let texts = self.generator.generate_batch(&prompts, max_new, temp)?;
        let dt_ms = t0.elapsed().as_secs_f64() * 1e3;

        let mut out = Vec::with_capacity(b);
        for (req, text) in batch.into_iter().zip(texts) {
            let clipped: String = text
                .chars()
                .take(req.max_new_tokens)
                .collect();
            self.latencies.record_us(dt_ms * 1e3);
            self.completed += 1;
            self.tokens_out += clipped.len() as u64;
            out.push(GenResponse {
                id: req.id,
                prompt_tokens: req.prompt.len(),
                new_tokens: clipped.len(),
                text: clipped,
                latency_ms: dt_ms,
                batch_size: b,
            });
        }
        Ok(out)
    }

    /// Drain the whole queue.
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResponse>> {
        let mut all = Vec::new();
        while !self.queue.is_empty() {
            all.extend(self.run_once()?);
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_finds_max() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 3.0]), 1); // first max wins
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn temperature_sampling_respects_distribution() {
        let mut rng = Pcg32::seeded(0);
        let logits = vec![0.0f32, 5.0, 0.0, 0.0];
        let mut hits = 0;
        for _ in 0..200 {
            if sample_temperature(&logits, 1.0, &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 180, "{hits}");
    }

    #[test]
    fn high_temperature_flattens() {
        let mut rng = Pcg32::seeded(1);
        let logits = vec![0.0f32, 5.0, 0.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[sample_temperature(&logits, 50.0, &mut rng)] += 1;
        }
        // near uniform at T=50
        for c in counts {
            assert!(c > 300, "{counts:?}");
        }
    }

    fn native_generator() -> Generator<'static> {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let store = ParamStore::init(&cfg, 5).unwrap();
        Generator::native(&cfg, &store, 0).unwrap()
    }

    #[test]
    fn native_greedy_generation_is_deterministic() {
        let mut g1 = native_generator();
        let mut g2 = native_generator();
        let a = g1.generate_batch(&["hello ".into()], 8, 0.0).unwrap();
        let b = g2.generate_batch(&["hello ".into()], 8, 0.0).unwrap();
        assert_eq!(a, b);
        assert_eq!(a[0].len(), 8);
        assert_eq!(g1.backend_name(), "native");
    }

    #[test]
    fn native_generation_respects_context_budget() {
        let mut g = native_generator();
        let long = "x".repeat(g.cfg.ctx * 2);
        let out = g.generate_batch(&[long], 6, 0.0).unwrap();
        assert_eq!(out[0].len(), 6);
    }

    #[test]
    fn native_server_serves_all_requests() {
        let mut server = Server::new(native_generator());
        for id in 0..3 {
            server.submit(GenRequest {
                id,
                prompt: format!("prompt {id} "),
                max_new_tokens: 4,
                temperature: 0.0,
            });
        }
        let responses = server.run_to_completion().unwrap();
        assert_eq!(responses.len(), 3);
        assert_eq!(server.pending(), 0);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);
        for r in &responses {
            assert_eq!(r.new_tokens, 4);
            assert!(r.latency_ms > 0.0);
        }
        assert_eq!(server.latencies.len(), 3);
    }

    #[test]
    fn oversize_batch_rejected() {
        let mut g = native_generator();
        let prompts: Vec<String> =
            (0..NATIVE_MAX_BATCH + 1).map(|i| format!("p{i}")).collect();
        assert!(g.generate_batch(&prompts, 2, 0.0).is_err());
    }
}
