//! Generation server: request queue → static batcher → batched decode
//! loop, with per-request latency accounting. This is the "LLM inference"
//! face of the coordinator — the place where ConSmax's merged β/γ
//! constants actually serve requests.
//!
//! The [`Generator`] is backend-pluggable (the multi-backend seam of
//! DESIGN.md §4):
//!
//! * **native** — KV-cached incremental decode over a
//!   [`DecodeSession`] (one O(T) step per token); always available,
//!   needs no artifacts. `consmax serve-demo --backend native` runs
//!   end-to-end on a machine with nothing but this crate. Rows of a
//!   batch decode **in parallel** across the worker pool
//!   (`runtime::parallel`, sized by `--threads` / `CONSMAX_THREADS`)
//!   with an allocation-free per-row compute path and identical
//!   logits at any thread count. The O(T²) recompute decoder is kept
//!   as the reference oracle and reachable with `--decode recompute`
//!   ([`DecodeMode`]).
//! * **pjrt** (`--features pjrt`) — KV-cached decode over the AOT
//!   `decode_b{N}` executables, parameters uploaded to device buffers
//!   once at construction.
//!
//! Batching policy is static (vLLM-v0-style) up to the backend's largest
//! decode batch. Native batches are **ragged**: each row prefills at its
//! own prompt length and is masked to its own cached positions, so a
//! short prompt next to a long one decodes exactly as it would alone
//! (no left-padding, no pad pollution). Requests keep their own
//! temperature and `max_new_tokens`; accounting is in token space.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::time::Instant;

use anyhow::{bail, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

use crate::config::ModelConfig;
use crate::coordinator::params::ParamStore;
use crate::data::ByteTokenizer;
use crate::metrics::LatencyRecorder;
use crate::runtime::backend::{DecodeSession, NativeModel};
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, HostTensor};
use crate::util::rng::Pcg32;

/// Largest batch the native decode engine serves at once (a knob, not
/// an export constraint like the PJRT decode artifacts). Sized for the
/// threaded decode loop: rows are the unit of parallelism, so wider
/// batches keep every worker busy.
pub const NATIVE_MAX_BATCH: usize = 16;

/// Which native decode engine drives generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeMode {
    /// KV-cached incremental decode (the default): prefill once, then
    /// one O(T) `decode_step` per token.
    Kv,
    /// Recompute the ctx-bounded window every step (O(T²) per token) —
    /// the reference oracle, kept as an escape hatch and test anchor.
    Recompute,
}

impl DecodeMode {
    pub fn parse(s: &str) -> Result<DecodeMode> {
        Ok(match s {
            "kv" => DecodeMode::Kv,
            "recompute" => DecodeMode::Recompute,
            other => bail!("unknown decode mode {other:?} (kv|recompute)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DecodeMode::Kv => "kv",
            DecodeMode::Recompute => "recompute",
        }
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling.
    pub temperature: f32,
}

/// A completed response.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    /// Post-clamp encoded prompt length (tokens actually attended).
    pub prompt_tokens: usize,
    /// Generated tokens (== `text` in bytes for the byte tokenizer,
    /// but counted in token space, never `chars()`).
    pub new_tokens: usize,
    pub latency_ms: f64,
    pub batch_size: usize,
}

/// One batch's generation output, in token space.
pub struct GenOutput {
    /// Newly generated token ids per row (exactly `max_new[r]` each).
    pub tokens: Vec<Vec<i32>>,
    /// The same tokens decoded to text per row.
    pub texts: Vec<String>,
    /// Post-clamp encoded prompt length per row.
    pub prompt_tokens: Vec<usize>,
}

/// Backend-specific decode state.
enum GenExec<'e> {
    /// Native decode over the pure-Rust model (KV-cached or recompute).
    Native {
        model: Box<NativeModel>,
        mode: DecodeMode,
        _lt: PhantomData<&'e ()>,
    },
    /// KV-cached decode over the AOT `decode_b{N}` executables.
    #[cfg(feature = "pjrt")]
    Pjrt {
        engine: &'e Engine,
        /// Parameters cached as device buffers: uploaded once at
        /// construction instead of on every decode step (§Perf: removes
        /// the dominant per-step cost, a full-model host→device copy).
        params: Vec<xla::PjRtBuffer>,
        /// Decode batch sizes available in the manifest, descending.
        batch_sizes: Vec<usize>,
    },
}

/// Batched generator over a decode backend.
pub struct Generator<'e> {
    pub cfg: ModelConfig,
    exec: GenExec<'e>,
    rng: Pcg32,
}

impl<'e> Generator<'e> {
    /// PJRT-backed generator over an engine's decode artifacts.
    #[cfg(feature = "pjrt")]
    pub fn new(engine: &'e Engine, store: &ParamStore, seed: u64) -> Result<Generator<'e>> {
        let cfg = engine.manifest.config(&store.config_key)?.clone();
        let params = store
            .params
            .iter()
            .map(|t| engine.upload(t))
            .collect::<Result<_>>()?;
        let mut batch_sizes: Vec<usize> = engine
            .manifest
            .entries
            .keys()
            .filter_map(|name| {
                name.strip_prefix(&format!("{}_decode_b", cfg.key))
                    .and_then(|b| b.parse().ok())
            })
            .collect();
        batch_sizes.sort_unstable_by(|a, b| b.cmp(a));
        if batch_sizes.is_empty() {
            bail!("no decode artifacts for {} (re-run `make artifacts`)", cfg.key);
        }
        Ok(Generator {
            cfg,
            exec: GenExec::Pjrt { engine, params, batch_sizes },
            rng: Pcg32::seeded(seed),
        })
    }

    /// Native generator with the default KV-cached decode engine.
    pub fn native(
        cfg: &ModelConfig,
        store: &ParamStore,
        seed: u64,
    ) -> Result<Generator<'static>> {
        Generator::native_with(cfg, store, seed, DecodeMode::Kv)
    }

    /// Native generator with an explicit decode engine (`--decode`).
    pub fn native_with(
        cfg: &ModelConfig,
        store: &ParamStore,
        seed: u64,
        mode: DecodeMode,
    ) -> Result<Generator<'static>> {
        let model = NativeModel::from_params(cfg, &store.order, &store.params)?;
        Ok(Generator {
            cfg: cfg.clone(),
            exec: GenExec::Native {
                model: Box::new(model),
                mode,
                _lt: PhantomData,
            },
            rng: Pcg32::seeded(seed),
        })
    }

    /// Which backend this generator decodes on ("native" / "pjrt").
    pub fn backend_name(&self) -> &'static str {
        match &self.exec {
            GenExec::Native { .. } => "native",
            #[cfg(feature = "pjrt")]
            GenExec::Pjrt { .. } => "pjrt",
        }
    }

    /// Which decode engine runs under the backend ("kv" / "recompute").
    pub fn decode_name(&self) -> &'static str {
        match &self.exec {
            GenExec::Native { mode, .. } => mode.name(),
            #[cfg(feature = "pjrt")]
            GenExec::Pjrt { .. } => "kv",
        }
    }

    pub fn max_batch(&self) -> usize {
        match &self.exec {
            GenExec::Native { .. } => NATIVE_MAX_BATCH,
            #[cfg(feature = "pjrt")]
            GenExec::Pjrt { batch_sizes, .. } => batch_sizes[0],
        }
    }

    /// Encode prompts in token space, clamping each row to its own
    /// KV/ctx budget (`ctx - max_new[r]`). Rows stay **ragged** — no
    /// padding; per-row lengths are respected by the decode engines.
    /// Returns the rows plus each row's post-clamp token count (what
    /// accounting must report, not the prompt's byte length). An empty
    /// prompt is seeded with a single space so decoding has a position
    /// to condition on.
    fn encode_prompts(
        &self,
        prompts: &[String],
        max_new: &[usize],
    ) -> (Vec<Vec<i32>>, Vec<usize>) {
        let tok = ByteTokenizer;
        let mut encoded = Vec::with_capacity(prompts.len());
        let mut prompt_tokens = Vec::with_capacity(prompts.len());
        for (p, &mn) in prompts.iter().zip(max_new) {
            let budget = self.cfg.ctx.saturating_sub(mn).max(1);
            let mut t = tok.encode(p);
            if t.len() > budget {
                t = t.split_off(t.len() - budget);
            }
            if t.is_empty() {
                t.push(b' ' as i32);
            }
            prompt_tokens.push(t.len());
            encoded.push(t);
        }
        (encoded, prompt_tokens)
    }

    /// Generate continuations for up to `max_batch()` prompts at once,
    /// one shared `max_new`/temperature (convenience wrapper over
    /// [`Generator::generate_batch_ext`]). The returned strings contain
    /// only the newly generated text.
    pub fn generate_batch(
        &mut self,
        prompts: &[String],
        max_new: usize,
        temperature: f32,
    ) -> Result<Vec<String>> {
        let out = self.generate_batch_ext(
            prompts,
            &vec![max_new; prompts.len()],
            &vec![temperature; prompts.len()],
        )?;
        Ok(out.texts)
    }

    /// Generate continuations with **per-row** token budgets and
    /// temperatures — the serving entry point. Row `r` receives exactly
    /// `max_new[r]` tokens sampled at `temperature[r]`; accounting in
    /// the returned [`GenOutput`] is entirely in token space.
    pub fn generate_batch_ext(
        &mut self,
        prompts: &[String],
        max_new: &[usize],
        temperature: &[f32],
    ) -> Result<GenOutput> {
        anyhow::ensure!(!prompts.is_empty(), "empty batch");
        anyhow::ensure!(
            prompts.len() == max_new.len() && prompts.len() == temperature.len(),
            "per-row max_new/temperature must match the prompt count"
        );
        anyhow::ensure!(
            prompts.len() <= self.max_batch(),
            "batch of {} exceeds max decode batch {}",
            prompts.len(),
            self.max_batch()
        );
        #[cfg_attr(not(feature = "pjrt"), allow(unused_mut))]
        let (encoded, mut prompt_tokens) = self.encode_prompts(prompts, max_new);
        let tok = ByteTokenizer;
        let b = prompts.len();
        let vocab = self.cfg.vocab;
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); b];
        match &mut self.exec {
            GenExec::Native { model, mode, .. } => match *mode {
                DecodeMode::Kv => {
                    let mut sess = DecodeSession::new(&self.cfg, b);
                    let logits = model.prefill(&mut sess, &encoded)?;
                    let mut last = vec![0i32; b];
                    for r in 0..b {
                        if max_new[r] == 0 {
                            continue;
                        }
                        let row = &logits[r * vocab..(r + 1) * vocab];
                        let next = pick_token(row, temperature[r], &mut self.rng);
                        generated[r].push(next);
                        last[r] = next;
                    }
                    loop {
                        let active: Vec<bool> =
                            (0..b).map(|r| generated[r].len() < max_new[r]).collect();
                        if !active.iter().any(|&a| a) {
                            break;
                        }
                        let logits =
                            model.decode_step_active(&mut sess, &last, &active)?;
                        for r in 0..b {
                            if !active[r] {
                                continue;
                            }
                            let row = &logits[r * vocab..(r + 1) * vocab];
                            let next =
                                pick_token(row, temperature[r], &mut self.rng);
                            generated[r].push(next);
                            last[r] = next;
                        }
                    }
                }
                DecodeMode::Recompute => {
                    // the oracle path: rows decode independently, so a
                    // ragged batch needs no padding here either
                    for r in 0..b {
                        let mut seq = encoded[r].clone();
                        for _ in 0..max_new[r] {
                            let logits =
                                model.next_logits(std::slice::from_ref(&seq))?;
                            let next =
                                pick_token(&logits, temperature[r], &mut self.rng);
                            seq.push(next);
                            generated[r].push(next);
                        }
                    }
                }
            },
            #[cfg(feature = "pjrt")]
            GenExec::Pjrt { engine, params, batch_sizes } => {
                // smallest exported batch size that fits the request count
                let bq = *batch_sizes
                    .iter()
                    .filter(|&&bs| bs >= b)
                    .min()
                    .unwrap_or(&batch_sizes[0]);
                let entry = format!("{}_decode_b{}", self.cfg.key, bq);
                let exe = engine.load(&entry)?;

                // the AOT decode step is lock-step, so the deepest
                // generation budget in the batch defines the shared
                // prompt window: without this re-clamp, a long prompt
                // (clamped only by its own small max_new) would push
                // plen + max_new_cap past ctx and silently truncate the
                // high-budget rows
                let max_new_cap = max_new.iter().copied().max().unwrap_or(0);
                let cap_budget =
                    self.cfg.ctx.saturating_sub(max_new_cap).max(1);
                let mut encoded = encoded;
                for (t, pt) in encoded.iter_mut().zip(prompt_tokens.iter_mut())
                {
                    if t.len() > cap_budget {
                        *t = t.split_off(t.len() - cap_budget);
                        *pt = t.len();
                    }
                }

                // left-pad to a common length (per-row masking is a
                // native-engine feature); rows beyond the real prompts
                // replicate row 0 (outputs ignored)
                let plen = encoded.iter().map(Vec::len).max().unwrap_or(1).max(1);
                for t in encoded.iter_mut() {
                    while t.len() < plen {
                        t.insert(0, b' ' as i32);
                    }
                }
                while encoded.len() < bq {
                    encoded.push(encoded[0].clone());
                }

                // KV caches start zeroed (device-resident; re-uploaded per
                // step because the output tuple only materializes on host)
                let cache_shape = vec![
                    self.cfg.n_layer,
                    bq,
                    self.cfg.n_head,
                    self.cfg.ctx,
                    self.cfg.head_dim(),
                ];
                let mut kc = engine.upload(&HostTensor::zeros(
                    crate::runtime::DType::F32,
                    &cache_shape,
                ))?;
                let mut vc = engine.upload(&HostTensor::zeros(
                    crate::runtime::DType::F32,
                    &cache_shape,
                ))?;

                // plen <= ctx - max_new_cap, so every row completes its
                // budget before the ctx guard below can fire
                let steps = plen + max_new_cap.max(1) - 1;
                let mut last_tokens: Vec<i32> =
                    encoded.iter().map(|t| t[0]).collect();

                for pos in 0..=steps {
                    if pos >= self.cfg.ctx {
                        break;
                    }
                    let toks: Vec<i32> = (0..bq)
                        .map(|r| {
                            if pos < plen {
                                encoded[r][pos]
                            } else {
                                last_tokens[r]
                            }
                        })
                        .collect();
                    let tok_buf =
                        engine.upload(&HostTensor::from_i32(&toks, &[bq]))?;
                    let pos_buf =
                        engine.upload(&HostTensor::scalar_i32(pos as i32))?;
                    let inputs: Vec<&xla::PjRtBuffer> = params
                        .iter()
                        .chain([&kc, &vc, &pos_buf, &tok_buf])
                        .collect();
                    let mut outs =
                        engine.execute_buffer_refs(&entry, &exe, &inputs)?;
                    vc = engine.upload_literal(&outs.pop().context("vc")?)?;
                    kc = engine.upload_literal(&outs.pop().context("kc")?)?;
                    let logits_t =
                        HostTensor::from_literal(&outs.pop().context("logits")?)?;
                    let logits = logits_t.as_f32()?;

                    if pos + 1 >= plen {
                        // sample the next token per row, at that row's
                        // own temperature, up to its own budget
                        for r in 0..b {
                            let row = &logits[r * vocab..(r + 1) * vocab];
                            let next =
                                pick_token(row, temperature[r], &mut self.rng);
                            last_tokens[r] = next;
                            if generated[r].len() < max_new[r] {
                                generated[r].push(next);
                            }
                        }
                    }
                }
            }
        }
        Ok(GenOutput {
            texts: generated.iter().map(|g| tok.decode(g)).collect(),
            tokens: generated,
            prompt_tokens,
        })
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

fn sample_temperature(logits: &[f32], temp: f32, rng: &mut Pcg32) -> usize {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - m) / temp) as f64).exp())
        .collect();
    rng.weighted(&weights)
}

/// Sample one token: greedy at `temperature <= 0`, else softmax-tempered.
fn pick_token(row: &[f32], temperature: f32, rng: &mut Pcg32) -> i32 {
    if temperature <= 0.0 {
        argmax(row) as i32
    } else {
        sample_temperature(row, temperature, rng) as i32
    }
}

/// Static-batching server over a [`Generator`].
pub struct Server<'e> {
    pub generator: Generator<'e>,
    queue: VecDeque<GenRequest>,
    pub latencies: LatencyRecorder,
    pub completed: u64,
    pub tokens_out: u64,
}

impl<'e> Server<'e> {
    pub fn new(generator: Generator<'e>) -> Server<'e> {
        Server {
            generator,
            queue: VecDeque::new(),
            latencies: LatencyRecorder::default(),
            completed: 0,
            tokens_out: 0,
        }
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Serve one batch from the queue (up to the largest decode batch);
    /// returns the completed responses. No-op on an empty queue.
    ///
    /// Every request keeps its own temperature and `max_new_tokens`;
    /// accounting is in token space (`new_tokens` counts generated
    /// tokens, `prompt_tokens` the post-clamp encoded prompt length).
    pub fn run_once(&mut self) -> Result<Vec<GenResponse>> {
        if self.queue.is_empty() {
            return Ok(Vec::new());
        }
        let b = self.generator.max_batch().min(self.queue.len());
        let batch: Vec<GenRequest> = (0..b).map(|_| self.queue.pop_front().unwrap()).collect();
        let prompts: Vec<String> = batch.iter().map(|r| r.prompt.clone()).collect();
        let max_new: Vec<usize> = batch.iter().map(|r| r.max_new_tokens).collect();
        let temps: Vec<f32> = batch.iter().map(|r| r.temperature).collect();

        let t0 = Instant::now();
        let gen = self.generator.generate_batch_ext(&prompts, &max_new, &temps)?;
        let dt_ms = t0.elapsed().as_secs_f64() * 1e3;

        let mut out = Vec::with_capacity(b);
        let rows = batch
            .into_iter()
            .zip(gen.texts)
            .zip(gen.tokens)
            .zip(gen.prompt_tokens);
        for (((req, text), toks), prompt_tokens) in rows {
            let new_tokens = toks.len();
            self.latencies.record_us(dt_ms * 1e3);
            self.completed += 1;
            self.tokens_out += new_tokens as u64;
            out.push(GenResponse {
                id: req.id,
                text,
                prompt_tokens,
                new_tokens,
                latency_ms: dt_ms,
                batch_size: b,
            });
        }
        Ok(out)
    }

    /// Drain the whole queue.
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResponse>> {
        let mut all = Vec::new();
        while !self.queue.is_empty() {
            all.extend(self.run_once()?);
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_finds_max() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 3.0]), 1); // first max wins
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn temperature_sampling_respects_distribution() {
        let mut rng = Pcg32::seeded(0);
        let logits = vec![0.0f32, 5.0, 0.0, 0.0];
        let mut hits = 0;
        for _ in 0..200 {
            if sample_temperature(&logits, 1.0, &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 180, "{hits}");
    }

    #[test]
    fn high_temperature_flattens() {
        let mut rng = Pcg32::seeded(1);
        let logits = vec![0.0f32, 5.0, 0.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[sample_temperature(&logits, 50.0, &mut rng)] += 1;
        }
        // near uniform at T=50
        for c in counts {
            assert!(c > 300, "{counts:?}");
        }
    }

    #[test]
    fn decode_mode_parses() {
        assert_eq!(DecodeMode::parse("kv").unwrap(), DecodeMode::Kv);
        assert_eq!(
            DecodeMode::parse("recompute").unwrap(),
            DecodeMode::Recompute
        );
        assert!(DecodeMode::parse("flash").is_err());
        assert_eq!(DecodeMode::Kv.name(), "kv");
    }

    fn native_generator() -> Generator<'static> {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let store = ParamStore::init(&cfg, 5).unwrap();
        Generator::native(&cfg, &store, 0).unwrap()
    }

    fn recompute_generator() -> Generator<'static> {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let store = ParamStore::init(&cfg, 5).unwrap();
        Generator::native_with(&cfg, &store, 0, DecodeMode::Recompute).unwrap()
    }

    #[test]
    fn native_greedy_generation_is_deterministic() {
        let mut g1 = native_generator();
        let mut g2 = native_generator();
        let a = g1.generate_batch(&["hello ".into()], 8, 0.0).unwrap();
        let b = g2.generate_batch(&["hello ".into()], 8, 0.0).unwrap();
        assert_eq!(a, b);
        assert_eq!(a[0].len(), 8);
        assert_eq!(g1.backend_name(), "native");
        assert_eq!(g1.decode_name(), "kv");
    }

    #[test]
    fn kv_and_recompute_greedy_agree() {
        let mut kv = native_generator();
        let mut rc = recompute_generator();
        let a = kv.generate_batch(&["hello ".into()], 10, 0.0).unwrap();
        let b = rc.generate_batch(&["hello ".into()], 10, 0.0).unwrap();
        assert_eq!(a, b);
        assert_eq!(rc.decode_name(), "recompute");
    }

    #[test]
    fn native_generation_respects_context_budget() {
        let mut g = native_generator();
        let long = "x".repeat(g.cfg.ctx * 2);
        let out = g.generate_batch(&[long], 6, 0.0).unwrap();
        assert_eq!(out[0].len(), 6);
    }

    #[test]
    fn prompt_tokens_report_post_clamp_length() {
        let mut g = native_generator();
        // multi-byte UTF-8: 5 chars but 7 bytes => 7 byte-tokens
        let out = g
            .generate_batch_ext(&["héllö".into()], &[3], &[0.0])
            .unwrap();
        assert_eq!(out.prompt_tokens, vec![7]);
        assert_eq!(out.tokens[0].len(), 3);

        // over-long prompt clamps to ctx - max_new
        let long = "y".repeat(g.cfg.ctx * 3);
        let out = g.generate_batch_ext(&[long], &[4], &[0.0]).unwrap();
        assert_eq!(out.prompt_tokens, vec![g.cfg.ctx - 4]);
    }

    #[test]
    fn native_server_serves_all_requests() {
        let mut server = Server::new(native_generator());
        for id in 0..3 {
            server.submit(GenRequest {
                id,
                prompt: format!("prompt {id} "),
                max_new_tokens: 4,
                temperature: 0.0,
            });
        }
        let responses = server.run_to_completion().unwrap();
        assert_eq!(responses.len(), 3);
        assert_eq!(server.pending(), 0);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);
        for r in &responses {
            assert_eq!(r.new_tokens, 4);
            assert!(r.latency_ms > 0.0);
        }
        assert_eq!(server.latencies.len(), 3);
        assert_eq!(server.tokens_out, 12); // token-space accounting
    }

    #[test]
    fn per_request_budgets_are_respected() {
        let mut server = Server::new(native_generator());
        for (id, max_new) in [(0u64, 2usize), (1, 7), (2, 4)] {
            server.submit(GenRequest {
                id,
                prompt: "shared prompt ".into(),
                max_new_tokens: max_new,
                temperature: 0.0,
            });
        }
        let mut responses = server.run_to_completion().unwrap();
        responses.sort_by_key(|r| r.id);
        let counts: Vec<usize> = responses.iter().map(|r| r.new_tokens).collect();
        assert_eq!(counts, vec![2, 7, 4]);
        assert_eq!(server.tokens_out, 13);
    }

    #[test]
    fn oversize_batch_rejected() {
        let mut g = native_generator();
        let prompts: Vec<String> =
            (0..NATIVE_MAX_BATCH + 1).map(|i| format!("p{i}")).collect();
        assert!(g.generate_batch(&prompts, 2, 0.0).is_err());
    }
}
